//! Quickstart: the paper's Figure-1 DAG, executed for real.
//!
//! Builds the 7-task example DAG from §2 with real kernel payloads
//! (matmul / sort / copy), runs it on the real-thread XiTAO engine with
//! the performance-based scheduler on a TX2-shaped 6-core topology, and
//! prints what the scheduler did: which tasks were critical, where each
//! TAO ran, at what width, and what the PTT learned.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;
use xitao::coordinator::dag::TaoDag;
use xitao::coordinator::ptt::Ptt;
use xitao::coordinator::PerformanceBased;
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name};
use xitao::kernels::{CopyTao, KernelSizes, MatMulTao, SortTao};
use xitao::platform::{KernelClass, scenarios};

fn main() {
    // The TX2 platform from the scenario registry: 2 Denver-class cores +
    // 4 A57-class cores, one shared L2 per cluster. (On this host the
    // workers time-share whatever cores exist — functionality, not speed.)
    let plat = scenarios::by_name("tx2").expect("registered scenario");
    let topo = &plat.topo;
    let sizes = KernelSizes::small();

    // Figure 1: A→C→G→D→F critical path, B and E off-path.
    let mut dag = TaoDag::new();
    let mk_mm = |seed| Arc::new(MatMulTao::new(sizes.matmul_n, seed));
    let mk_sort = |seed| Arc::new(SortTao::new(sizes.sort_len, seed));
    let mk_copy = |seed| Arc::new(CopyTao::new(sizes.copy_bytes, seed));
    let a = dag.add_task_payload(KernelClass::MatMul, 0, 1.0, Some(mk_mm(1)));
    let b = dag.add_task_payload(KernelClass::Sort, 1, 1.0, Some(mk_sort(2)));
    let c = dag.add_task_payload(KernelClass::Copy, 2, 1.0, Some(mk_copy(3)));
    let e = dag.add_task_payload(KernelClass::Sort, 1, 1.0, Some(mk_sort(4)));
    let g = dag.add_task_payload(KernelClass::MatMul, 0, 1.0, Some(mk_mm(5)));
    let d = dag.add_task_payload(KernelClass::Copy, 2, 1.0, Some(mk_copy(6)));
    let f = dag.add_task_payload(KernelClass::MatMul, 0, 1.0, Some(mk_mm(7)));
    for (x, y) in [(a, c), (a, e), (b, g), (c, g), (e, d), (g, d), (d, f)] {
        dag.add_edge(x, y);
    }
    dag.finalize().expect("acyclic");

    println!("Figure-1 DAG: {} tasks, critical path {}, parallelism {:.2}", dag.len(), dag.critical_path_len(), dag.parallelism());
    println!("criticalities: {:?}\n", dag.nodes.iter().map(|n| n.criticality).collect::<Vec<_>>());

    let ptt = Ptt::new(dag.n_types(), topo);
    let backend = backend_by_name("real").expect("registered backend");
    let result =
        backend.run(&dag, &plat, &PerformanceBased, Some(&ptt), &RunOpts::default()).result;

    let names = ["A", "B", "C", "E", "G", "D", "F"];
    println!("execution trace (wall time):");
    for r in &result.records {
        println!(
            "  {:>2}  {:6}  crit={}  leader=core{} width={}  [{:.4}s → {:.4}s]",
            names[r.task],
            r.class.name(),
            if r.critical { "yes" } else { "no " },
            r.partition.leader,
            r.partition.width,
            r.t_start,
            r.t_end,
        );
    }
    println!("\nmakespan: {:.4}s", result.makespan);
    println!("\nwhat the PTT learned (type 0 = matmul):");
    for (core, width, val) in ptt.dump(0, topo) {
        if val > 0.0 {
            println!("  core {core} width {width}: {val:.6}s");
        }
    }
    // Criticality sanity: C, G, D, F were woken over the critical path.
    let crit: Vec<&str> =
        result.records.iter().filter(|r| r.critical).map(|r| names[r.task]).collect();
    println!("\ncritical tasks observed: {crit:?} (expected C, G, D, F in some order)");
}
