//! The paper's random-DAG benchmark (§4.2, §5.1–5.2) end to end.
//!
//! 1. Generates a seeded random TAO-DAG mixing the three kernels with the
//!    paper's generator (level widths, edge rate, data-reuse memory
//!    assignment).
//! 2. Runs it for real (real threads, real matmul/sort/copy payloads)
//!    under both the performance-based and the homogeneous scheduler.
//! 3. Replays the same workload shape on the simulated Jetson TX2 model,
//!    reproducing the paper's comparison where the hardware heterogeneity
//!    actually exists.
//!
//!     cargo run --release --example random_dag_mix -- [tasks] [parallelism]

use xitao::coordinator::{HomogeneousWs, PerformanceBased};
use xitao::dag_gen::{DagParams, generate};
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name};
use xitao::kernels::KernelSizes;
use xitao::platform::Platform;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tasks: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let par: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4.0);

    // --- real execution on the host -----------------------------------
    let params = DagParams::mix(tasks, par, 0xbeef).with_payloads(KernelSizes::small());
    let (dag, stats) = generate(&params);
    println!(
        "random DAG: {} tasks ({} levels, parallelism {:.2}, {} edges)",
        stats.tasks, stats.levels, stats.parallelism, stats.edges
    );
    println!("data locations per kernel: {:?}\n", stats.data_locations);

    let host = Platform::from_topology(xitao::platform::detect::detect());
    let real = backend_by_name("real").expect("registered backend");
    println!("real execution on host topology ({} cores):", host.topo.n_cores());
    for (name, policy) in [
        ("performance-based", &PerformanceBased as &dyn xitao::coordinator::Policy),
        ("homogeneous-ws", &HomogeneousWs),
    ] {
        let res = real.run(&dag, &host, policy, None, &RunOpts::default()).result;
        println!(
            "  {:18} makespan {:.3}s  throughput {:7.1} tasks/s  widths {:?}",
            name,
            res.makespan,
            res.throughput(),
            res.width_histogram()
        );
    }

    // --- simulated TX2 (the paper's platform) -------------------------
    println!("\nsimulated Jetson TX2 (2× Denver2 + 4× A57):");
    let plat = xitao::platform::scenarios::by_name("tx2").expect("registered scenario");
    let sim = backend_by_name("sim").expect("registered backend");
    let (sim_dag, _) = generate(&DagParams::mix(tasks, par, 0xbeef));
    let mut thr = Vec::new();
    for (name, policy) in [
        ("performance-based", &PerformanceBased as &dyn xitao::coordinator::Policy),
        ("homogeneous-ws", &HomogeneousWs),
    ] {
        let run = sim.run(&sim_dag, &plat, policy, None, &RunOpts::default());
        println!(
            "  {:18} makespan {:.4}s  throughput {:7.1} tasks/s  utilisation {:.2}  widths {:?}",
            name,
            run.result.makespan,
            run.result.throughput(),
            run.result.utilisation(plat.topo.n_cores()),
            run.result.width_histogram()
        );
        thr.push(run.result.throughput());
    }
    println!("\nspeedup (performance-based / homogeneous): {:.2}×", thr[0] / thr[1]);
    println!("(paper Fig 7 reports 2.2–3.3× at parallelism 1, decaying toward 1 at 16)");
}
