//! Interference adaptation walkthrough (§5.3, Fig 8).
//!
//! Simulates the paper's experiment: a highly parallel random DAG on the
//! 20-core Haswell model while a background process time-shares cores 0–1
//! for a window in the middle of the run. Shows, phase by phase, how the
//! PTT's inflated observations steer critical tasks away from the victim
//! cores, and that non-critical tasks keep landing there (which is what
//! keeps the PTT current — the paper's §5.3 point). Ends with a DVFS
//! episode variant (dynamic heterogeneity of the second kind).
//!
//!     cargo run --release --example interference_demo

use xitao::bench::figures::{fig8_run, fig8_scenario};
use xitao::coordinator::PerformanceBased;
use xitao::dag_gen::{DagParams, generate};
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name};
use xitao::platform::{Episode, EpisodeSchedule, Platform};

fn main() {
    let scen = fig8_scenario();
    println!(
        "scenario: haswell20, background process on cores {:?} during [{}, {})s\n",
        scen.victim_cores, scen.window.0, scen.window.1
    );

    let (run, probe) = fig8_run(true, 11);
    let (clean, _) = fig8_run(false, 11);

    let phases = [
        ("before", 0.0, scen.window.0),
        ("during", scen.window.0, scen.window.1),
        ("after", scen.window.1, run.makespan),
    ];
    println!("critical-task placement (the Fig 8 black-dot trace, summarised):");
    for (name, a, b) in phases {
        let crit: Vec<_> = run
            .records
            .iter()
            .filter(|r| r.critical && r.t_start >= a && r.t_start < b)
            .collect();
        let on_victims = crit
            .iter()
            .filter(|r| r.partition.cores().any(|c| scen.victim_cores.contains(&c)))
            .count();
        let noncrit_on_victims = run
            .records
            .iter()
            .filter(|r| {
                !r.critical
                    && r.t_start >= a
                    && r.t_start < b
                    && r.partition.cores().any(|c| scen.victim_cores.contains(&c))
            })
            .count();
        println!(
            "  {name:6} [{a:.2}-{b:.2}s]: {:3} critical TAOs, {on_victims:2} on victim cores; \
             {noncrit_on_victims:3} non-critical TAOs still ran there",
            crit.len()
        );
    }

    println!("\nPTT probe at (matmul, core 1, width 1) — watch it spike in the window:");
    let step = (probe.len() / 20).max(1);
    for (t, v) in probe.iter().step_by(step) {
        let bar = "#".repeat(((v / 1.5e-3) * 40.0).min(60.0) as usize);
        println!("  t={t:.3}s  {v:.6}s {bar}");
    }

    println!(
        "\nwall time: interfered {:.3}s vs clean {:.3}s (+{:.1}%) — the paper calls this marginal",
        run.makespan,
        clean.makespan,
        100.0 * (run.makespan / clean.makespan - 1.0)
    );

    // --- DVFS variant ---------------------------------------------------
    println!("\nDVFS episode variant: cores 0-3 throttled to 40% for the whole run:");
    let plat = Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![Episode::dvfs(
        vec![0, 1, 2, 3],
        0.0,
        1e9,
        0.4,
    )]));
    let (dag, _) = generate(&DagParams::mix(2000, 8.0, 5));
    let sim = backend_by_name("sim").expect("registered backend");
    let run = sim.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default());
    let crit_on_throttled = run
        .result
        .records
        .iter()
        .filter(|r| r.critical && r.partition.leader < 4)
        .count();
    let crit_total = run.result.critical_records().len();
    println!(
        "  critical TAOs on throttled cores: {crit_on_throttled}/{crit_total} \
         (PTT learns the throttled cores are slow without being told about DVFS)"
    );
}
