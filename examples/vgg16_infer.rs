//! END-TO-END driver: VGG-16 inference through the full three-layer stack.
//!
//! This is the repository's integration proof (§4.3 / §5.4 of the paper,
//! EXPERIMENTS.md §E2E): the L1 Pallas GEMM kernel was AOT-lowered to HLO
//! text at build time (`make artifacts`), the L2 JAX model likewise; here
//! the L3 Rust coordinator loads both with the PJRT CPU client and runs
//! one real inference three ways on identical weights:
//!
//!   1. whole-model — the single JAX/Pallas executable;
//!   2. pipeline    — Rust layer loop over the tiled Pallas GEMM artifact;
//!   3. TAO-DAG     — the same GEMMs as XiTAO tasks under the
//!                    performance-based scheduler on real worker threads.
//!
//! All three must agree (allclose) — that single assertion exercises the
//! kernel, the AOT path, the runtime service, the im2col/pool glue, the
//! DAG builder, the scheduler and the worker engine at once.
//!
//!     make artifacts && cargo run --release --example vgg16_infer

use std::sync::Arc;
use std::time::Instant;
use xitao::coordinator::PerformanceBased;
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name};
use xitao::platform::Platform;
use xitao::runtime::{PjrtService, VggWeights, build_real_dag, pipeline_infer, synthetic_image};

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let t0 = Instant::now();
    let svc = PjrtService::start(artifacts).expect("start PJRT service");
    let spec = svc.manifest().vgg.clone().expect("VGG artifact in manifest");
    println!(
        "[{:.1}s] PJRT service up: {} GEMM tiles compiled, VGG at {}×{} input",
        t0.elapsed().as_secs_f64(),
        svc.manifest().gemm_tiles.len(),
        spec.input_hw,
        spec.input_hw
    );

    let hw = spec.input_hw;
    let weights = Arc::new(VggWeights::synthetic(hw, 1));
    let image = synthetic_image(hw, 2);
    let h = svc.handle();

    // Path 1: whole-model (L2 artifact).
    h.vgg_load(weights.flat()).expect("load weights");
    let t = Instant::now();
    let logits_whole = h.vgg_infer(&image).expect("whole-model inference");
    let t_whole = t.elapsed().as_secs_f64();
    println!("[whole-model] {t_whole:.2}s  argmax={}", argmax(&logits_whole));

    // Path 2: Rust pipeline over the tiled Pallas GEMM (L1 artifact).
    let t = Instant::now();
    let logits_pipe = pipeline_infer(&weights, &image, &h).expect("pipeline inference");
    let t_pipe = t.elapsed().as_secs_f64();
    println!("[pipeline   ] {t_pipe:.2}s  argmax={}", argmax(&logits_pipe));

    // Path 3: the XiTAO TAO-DAG on real worker threads.
    let (dag, out) = build_real_dag(weights.clone(), image.clone(), h.clone(), 128);
    println!(
        "[tao-dag    ] DAG: {} TAOs ({} GEMM + prep), critical path {}",
        dag.len(),
        dag.nodes.iter().filter(|n| n.class == xitao::platform::KernelClass::Gemm).count(),
        dag.critical_path_len()
    );
    let plat = Platform::homogeneous(4);
    let backend = backend_by_name("real").expect("registered backend");
    let t = Instant::now();
    let res = backend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default()).result;
    let t_dag = t.elapsed().as_secs_f64();
    let logits_dag = out.snapshot();
    println!(
        "[tao-dag    ] {t_dag:.2}s  argmax={}  widths {:?}",
        argmax(&logits_dag),
        res.width_histogram()
    );

    // The cross-language assertion.
    let scale = logits_whole.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
    let d1 = max_diff(&logits_whole, &logits_pipe) / scale;
    let d2 = max_diff(&logits_whole, &logits_dag) / scale;
    println!("\nrelative max deviation: pipeline {d1:.2e}, tao-dag {d2:.2e}");
    assert!(d1 < 1e-2 && d2 < 1e-2, "paths disagree!");
    assert_eq!(argmax(&logits_whole), argmax(&logits_pipe));
    assert_eq!(argmax(&logits_whole), argmax(&logits_dag));
    println!("E2E VALIDATION OK — JAX/Pallas whole model ≡ Rust tiled pipeline ≡ XiTAO TAO-DAG");
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().fold((0, f32::NEG_INFINITY), |a, (i, &v)| if v > a.1 { (i, v) } else { a }).0
}

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs()))
}
