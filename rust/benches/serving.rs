//! `cargo bench --bench serving` — the continuous multi-tenant serving
//! ramp, full scale.
//!
//! Delegates to the same harness as `repro bench-serving`
//! (`xitao::bench::serving`), so the two measurement paths cannot drift:
//! per-step sustained admissions/sec, p99 slowdown over admitted apps,
//! per-QoS-class SLO attainment and the fairness loop's Jain index, as the
//! tenant count ramps under a fixed per-tenant arrival rate. Set
//! `BENCH_QUICK=1` for the CI smoke scale.
//!
//! Results feed EXPERIMENTS.md §Serving mode and `BENCH_serving.json`.

use xitao::bench::{ServingBenchOpts, emit_serving};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    emit_serving(&ServingBenchOpts { quick, ..Default::default() });
}
