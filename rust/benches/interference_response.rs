//! `cargo bench --bench interference_response` — the §5.3
//! dynamic-heterogeneity response analysis, full scale.
//!
//! Delegates to the same harness as `repro bench-interference`
//! (`xitao::bench::interference_response`), so the two measurement paths
//! cannot drift: per-interval PTT values, change-detector flag state and
//! critical-task placements on the interfered cores, for the plain
//! `performance-based` policy vs `ptt-adaptive`, on both execution
//! backends. Set `BENCH_QUICK=1` for the CI smoke scale.
//!
//! Results feed EXPERIMENTS.md §Interference response and
//! `BENCH_interference_response.json`.

use xitao::bench::{InterferenceOpts, emit_interference};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    emit_interference(&InterferenceOpts { quick, ..Default::default() });
}
