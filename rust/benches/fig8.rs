//! `cargo bench --bench fig8` — regenerates the paper's Figure 8 on the
//! modelled platform and writes bench_out/fig8*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig8", &bench::fig8(&opts));
    eprintln!("[fig8] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
