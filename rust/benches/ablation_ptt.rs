//! `cargo bench --bench ablation_ptt` — PTT history-weight ablation (§3.2's
//! 4:1 moving average vs alternatives).
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    bench::emit("ablation_ptt", &bench::ablation_ptt(&opts));
}
