//! `cargo bench --bench fig6` — regenerates the paper's Figure 6 on the
//! modelled platform and writes bench_out/fig6*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig6", &bench::fig6(&opts));
    eprintln!("[fig6] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
