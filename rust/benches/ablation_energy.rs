//! `cargo bench --bench ablation_energy` — §3.3's alternative objective:
//! energy-per-task placement vs the performance objective.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    bench::emit("ablation_energy", &bench::ablation_energy(&opts));
}
