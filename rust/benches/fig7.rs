//! `cargo bench --bench fig7` — regenerates the paper's Figure 7 on the
//! modelled platform and writes bench_out/fig7*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig7", &bench::fig7(&opts));
    eprintln!("[fig7] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
