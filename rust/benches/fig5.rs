//! `cargo bench --bench fig5` — regenerates the paper's Figure 5 on the
//! modelled platform and writes bench_out/fig5*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig5", &bench::fig5(&opts));
    eprintln!("[fig5] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
