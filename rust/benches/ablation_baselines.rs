//! `cargo bench --bench ablation_baselines` — the §6 baselines (CATS-like,
//! dHEFT-like) against the paper's two schedulers.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    bench::emit("ablation_baselines", &bench::ablation_baselines(&opts));
}
