//! `cargo bench --bench stream_interference` — the paper's §5.3 Haswell
//! interference experiment grown to multi-tenant form: two applications
//! co-run while a background process squeezes cores 0–1; reports per-app
//! slowdown vs. isolated runs, Jain fairness, and critical-task placement
//! around the episode. See bench::figures::stream_interference.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("stream_interference", &bench::stream_interference(&opts));
    eprintln!("[stream_interference] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
