//! `cargo bench --bench fig10` — regenerates the paper's Figure 10 on the
//! modelled platform and writes bench_out/fig10*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig10", &bench::fig10(&opts));
    eprintln!("[fig10] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
