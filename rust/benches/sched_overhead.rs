//! `cargo bench --bench sched_overhead` — L3 hot-path micro-benchmarks.
//!
//! The paper claims the PTT's overhead is negligible ("the number of
//! entries in the PTT is only 2×N−1 for each NUMA node"); this harness
//! measures it, plus every other operation on the scheduling hot path:
//!
//!   - PTT read / update / global search / local width search
//!   - policy placement decisions (all four policies)
//!   - lock-free WSQ push/pop/steal and AQ push/pop
//!   - end-to-end real-engine scheduling overhead per TAO (nop payloads)
//!   - simulator event rate (simulated TAOs per wall second)
//!   - the full mutex-vs-lockfree overhead harness
//!     (`xitao::bench::overhead`, same code as `repro bench-overhead`)
//!
//! Results feed EXPERIMENTS.md §Perf and `BENCH_sched_overhead.json`.

use std::time::Instant;
use xitao::bench::overhead::time_ns;
use xitao::coordinator::aq::AssemblyQueue;
use xitao::coordinator::dag::TaoDag;
use xitao::coordinator::ptt::Ptt;
use xitao::coordinator::scheduler::{EngineView, PlaceCtx, QosClass, TaskView, policy_by_name};
use xitao::coordinator::wsq::WsQueue;
use xitao::coordinator::{NopPayload, RealEngineOpts, run_dag_real};
use xitao::dag_gen::{DagParams, generate};
use xitao::platform::{KernelClass, Platform, Topology};
use xitao::sim::{SimOpts, run_dag_sim};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let iters = if quick { 20_000 } else { 200_000 };
    println!("== sched_overhead (iters={iters}) ==");

    // --- PTT operations on the two paper topologies ---------------------
    for topo in [
        Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)]),
        Topology::from_clusters("haswell20", &[(10, "haswell", 25 << 20), (10, "haswell", 25 << 20)]),
    ] {
        let ptt = Ptt::new(4, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        let read = time_ns(iters, || {
            std::hint::black_box(ptt.read(0, 0, 1));
        });
        let update = time_ns(iters, || {
            ptt.update(0, 0, 1, std::hint::black_box(0.5));
        });
        let global = time_ns(iters, || {
            std::hint::black_box(ptt.best_global(0, &topo));
        });
        let local = time_ns(iters, || {
            std::hint::black_box(ptt.best_width_for(0, topo.n_cores() - 1, &topo));
        });
        println!(
            "[{:9}] ptt.read {read:7.1} ns | update {update:7.1} ns | global search {global:8.1} ns | local search {local:7.1} ns",
            topo.name
        );
    }

    // --- policy placement ------------------------------------------------
    let topo = Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)]);
    let ptt = Ptt::new(1, &topo);
    for p in topo.all_partitions() {
        ptt.update(0, p.leader, p.width, 1.0);
    }
    for name in ["performance", "homogeneous", "cats", "dheft", "elastic"] {
        let policy = policy_by_name(name, topo.n_cores()).unwrap();
        for critical in [true, false] {
            let ns = time_ns(iters, || {
                let ctx = PlaceCtx::new(
                    TaskView {
                        task: 0,
                        type_id: 0,
                        critical,
                        max_width: 4,
                        app_id: 0,
                        qos: QosClass::default(),
                    },
                    EngineView { core: 3, ptt: &ptt, topo: &topo, now: 0.0 },
                );
                std::hint::black_box(policy.place(&ctx));
            });
            println!("[place] {name:12} critical={critical:5}: {ns:7.1} ns");
        }
    }

    // --- queues -----------------------------------------------------------
    let wsq: WsQueue<usize> = WsQueue::new();
    let push_pop = time_ns(iters, || {
        wsq.push(1);
        std::hint::black_box(wsq.pop());
    });
    let aq: AssemblyQueue<usize> = AssemblyQueue::new();
    let aq_pp = time_ns(iters, || {
        aq.push(1);
        std::hint::black_box(aq.pop());
    });
    println!("[queues] wsq push+pop {push_pop:6.1} ns | aq push+pop {aq_pp:6.1} ns");

    // --- end-to-end real-engine overhead per TAO --------------------------
    // Nop payloads: the measured time is pure runtime overhead.
    let n_tasks = if quick { 2_000 } else { 20_000 };
    let mut dag = TaoDag::new();
    for _ in 0..n_tasks {
        dag.add_task_payload(
            KernelClass::MatMul,
            0,
            1.0,
            Some(std::sync::Arc::new(NopPayload(KernelClass::MatMul))),
        );
    }
    dag.finalize().unwrap();
    let host_cores = xitao::platform::detect::online_cpus();
    let topo_r = Topology::homogeneous(host_cores.min(4));
    for name in ["performance", "homogeneous"] {
        let policy = policy_by_name(name, topo_r.n_cores()).unwrap();
        let t = Instant::now();
        let res = run_dag_real(&dag, &topo_r, policy.as_ref(), None, &RealEngineOpts::default())
            .unwrap();
        let per_tao = t.elapsed().as_nanos() as f64 / res.n_tasks() as f64;
        println!(
            "[real-engine] {name:12}: {per_tao:8.1} ns/TAO over {} nop TAOs ({} workers)",
            res.n_tasks(),
            topo_r.n_cores()
        );
    }

    // --- simulator throughput ----------------------------------------------
    let (sim_dag, _) = generate(&DagParams::mix(if quick { 2_000 } else { 20_000 }, 8.0, 3));
    let plat = Platform::tx2();
    let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
    let t = Instant::now();
    let run = run_dag_sim(&sim_dag, &plat, policy.as_ref(), None, &SimOpts::default()).unwrap();
    let dt = t.elapsed().as_secs_f64();
    println!(
        "[simulator] {:.0} simulated TAOs/s wall ({} TAOs in {dt:.2}s)",
        run.result.n_tasks() as f64 / dt,
        run.result.n_tasks()
    );

    // --- mutex-vs-lockfree overhead harness --------------------------------
    // Same code as `repro bench-overhead --compare`; prints the comparison
    // tables (steal-heavy throughput, steal latency, speedup).
    println!();
    xitao::bench::emit_overhead(&xitao::bench::OverheadOpts {
        quick,
        compare: true,
        ..Default::default()
    });
}
