//! `cargo bench --bench fig9` — regenerates the paper's Figure 9 on the
//! modelled platform and writes bench_out/fig9*.csv. See bench::figures.
use xitao::bench::{self, BenchOpts};

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::default() };
    let t = std::time::Instant::now();
    bench::emit("fig9", &bench::fig9(&opts));
    eprintln!("[fig9] regenerated in {:.1}s", t.elapsed().as_secs_f64());
}
