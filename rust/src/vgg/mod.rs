//! VGG-16 as a TAO-DAG (§4.3).
//!
//! Following the paper's port of Darknet's VGG-16: every convolutional and
//! fully-connected layer is expressed as GEMM (conv via im2col), the work
//! inside a layer is partitioned across TAOs by output-channel blocks
//! (`block_len` channels per TAO), and consecutive layers are separated by
//! a barrier ("each layer is dependent on the previous layer, we therefore
//! synchronize all TAOs at the end of each layer") — realised as dense
//! edges from every TAO of layer *l* to every TAO of layer *l+1*.
//!
//! Two levels of parallelism result: TAO-level (channel blocks within a
//! layer) and intra-TAO (the width the scheduler picks at runtime).
//!
//! Each layer gets its own PTT type id: layer shapes differ wildly, so
//! sharing latency estimates across layers would poison the table.

use crate::coordinator::dag::TaoDag;
use crate::coordinator::tao::TaoPayload;
use crate::platform::KernelClass;
use std::sync::Arc;

/// One VGG-16 layer in GEMM form.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 3×3 convolution: GEMM of M=c_out, K=c_in·9, N=h·w.
    Conv { c_in: usize, c_out: usize, hw: usize },
    /// 2×2 max-pool (streaming pass over c·hw·4 values).
    Pool { c: usize, hw_out: usize },
    /// Fully connected: GEMM of M=c_out, K=c_in, N=1.
    Fc { c_in: usize, c_out: usize },
}

#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
}

impl LayerSpec {
    /// GEMM dimensions `(m, k, n)`; pools report a pseudo-GEMM of their
    /// touched elements for work accounting.
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        match &self.kind {
            LayerKind::Conv { c_in, c_out, hw } => (*c_out, c_in * 9, hw * hw),
            LayerKind::Pool { c, hw_out } => (*c, 4, hw_out * hw_out),
            LayerKind::Fc { c_in, c_out } => (*c_out, *c_in, 1),
        }
    }

    pub fn flops(&self) -> f64 {
        let (m, k, n) = self.gemm_dims();
        2.0 * m as f64 * k as f64 * n as f64
    }

    /// Output channels — the axis we block across TAOs.
    pub fn out_channels(&self) -> usize {
        match &self.kind {
            LayerKind::Conv { c_out, .. } => *c_out,
            LayerKind::Pool { c, .. } => *c,
            LayerKind::Fc { c_out, .. } => *c_out,
        }
    }
}

/// Model configuration.
#[derive(Debug, Clone)]
pub struct VggConfig {
    /// Input spatial size (224 in the paper; smaller for real-mode runs).
    pub input_hw: usize,
    /// Output channels per TAO ("the parameter block length refers to the
    /// number of channels assigned to each TAO").
    pub block_len: usize,
    /// Number of consecutive inferences chained into one DAG (the paper's
    /// scalability study predicts repeatedly; more repeats = more PTT
    /// training data).
    pub repeats: usize,
}

impl Default for VggConfig {
    fn default() -> Self {
        VggConfig { input_hw: 224, block_len: 64, repeats: 1 }
    }
}

/// Reference FLOP count that corresponds to one `KernelClass::Gemm` work
/// unit (`base_work`) in the platform model — i.e. the modelled reference
/// core sustains `REF_FLOPS / base_work` FLOP/s on GEMM.
pub const REF_FLOPS: f64 = 200.0e6;

/// The 16 weight layers of VGG-16 (configuration D) plus pools, scaled to
/// `input_hw`.
pub fn vgg16_layers(input_hw: usize) -> Vec<LayerSpec> {
    assert!(input_hw >= 32 && input_hw % 32 == 0, "input must be a multiple of 32");
    let mut layers = Vec::new();
    let mut hw = input_hw;
    let mut c_in = 3;
    let blocks: &[(usize, usize)] = &[(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    for (bi, &(c_out, reps)) in blocks.iter().enumerate() {
        for r in 0..reps {
            layers.push(LayerSpec {
                name: format!("conv{}_{}-{}", bi + 1, r + 1, c_out),
                kind: LayerKind::Conv { c_in, c_out, hw },
            });
            c_in = c_out;
        }
        hw /= 2;
        layers.push(LayerSpec {
            name: format!("pool{}", bi + 1),
            kind: LayerKind::Pool { c: c_in, hw_out: hw },
        });
    }
    let flat = c_in * hw * hw;
    layers.push(LayerSpec { name: "fc6-4096".into(), kind: LayerKind::Fc { c_in: flat, c_out: 4096 } });
    layers.push(LayerSpec { name: "fc7-4096".into(), kind: LayerKind::Fc { c_in: 4096, c_out: 4096 } });
    layers.push(LayerSpec { name: "fc8-1000".into(), kind: LayerKind::Fc { c_in: 4096, c_out: 1000 } });
    layers
}

/// Total model FLOPs at `input_hw` (sanity anchor: ~15.5 GFLOP at 224).
pub fn total_flops(input_hw: usize) -> f64 {
    vgg16_layers(input_hw).iter().map(|l| l.flops()).sum()
}

/// A factory producing the real payload for one TAO: layer + channel range.
pub type PayloadFactory<'a> =
    &'a dyn Fn(&LayerSpec, std::ops::Range<usize>) -> Arc<dyn TaoPayload>;

/// Build the VGG-16 TAO-DAG.
///
/// Sim-only when `factory` is `None`; each TAO's `work_scale` is its GEMM
/// FLOPs over [`REF_FLOPS`]. Layer *i* uses PTT type id *i* (repeats share
/// types — that is the point: later inferences reuse what the PTT learned
/// on earlier ones).
pub fn build_dag(cfg: &VggConfig, factory: Option<PayloadFactory<'_>>) -> TaoDag {
    assert!(cfg.repeats >= 1);
    let layers = vgg16_layers(cfg.input_hw);
    let mut dag = TaoDag::new();
    let mut prev_layer: Vec<usize> = Vec::new();
    for _rep in 0..cfg.repeats {
        for (li, layer) in layers.iter().enumerate() {
            let out_c = layer.out_channels();
            let n_taos = out_c.div_ceil(cfg.block_len);
            let (_, k, n) = layer.gemm_dims();
            let mut this_layer = Vec::with_capacity(n_taos);
            for b in 0..n_taos {
                let lo = b * cfg.block_len;
                let hi = ((b + 1) * cfg.block_len).min(out_c);
                let block_flops = 2.0 * (hi - lo) as f64 * k as f64 * n as f64;
                let class = match layer.kind {
                    LayerKind::Pool { .. } => KernelClass::Copy,
                    _ => KernelClass::Gemm,
                };
                let payload = factory.map(|f| f(layer, lo..hi));
                let id = dag.add_task_payload(
                    class,
                    li, // PTT type per layer
                    block_flops / REF_FLOPS,
                    payload,
                );
                this_layer.push(id);
            }
            // Layer barrier: dense edges from the previous layer.
            for &p in &prev_layer {
                for &t in &this_layer {
                    dag.add_edge(p, t);
                }
            }
            prev_layer = this_layer;
        }
    }
    dag.finalize().expect("layered VGG DAG is acyclic");
    dag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_table_matches_vgg16_d() {
        let layers = vgg16_layers(224);
        let convs = layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv { .. })).count();
        let fcs = layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc { .. })).count();
        let pools = layers.iter().filter(|l| matches!(l.kind, LayerKind::Pool { .. })).count();
        assert_eq!(convs, 13);
        assert_eq!(fcs, 3);
        assert_eq!(pools, 5);
        // 13 conv + 3 fc = 16 weight layers.
    }

    #[test]
    fn total_flops_anchor() {
        // VGG-16 forward ≈ 15.5 GFLOP at 224² (2 FLOP per MAC).
        let g = total_flops(224) / 1e9;
        assert!((28.0..34.0).contains(&g), "got {g} GFLOP"); // 2×MACs ≈ 31G
    }

    #[test]
    fn fc6_input_dimension() {
        let layers = vgg16_layers(224);
        let fc6 = layers.iter().find(|l| l.name.starts_with("fc6")).unwrap();
        match fc6.kind {
            LayerKind::Fc { c_in, c_out } => {
                assert_eq!(c_in, 512 * 7 * 7); // 25088
                assert_eq!(c_out, 4096);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn dag_layer_structure() {
        let cfg = VggConfig { input_hw: 224, block_len: 64, repeats: 1 };
        let dag = build_dag(&cfg, None);
        // conv1: 64/64 = 1 TAO; conv3 block: 256/64 = 4; conv5: 512/64 = 8.
        // Total TAOs: conv 1+1+2+2+4+4+4+8+8+8+8+8+8=66, pools 1+2+4+8+8=23,
        // fc 64+64+16=144. (fc6: 4096/64=64 etc, fc8: 1000/64=16)
        assert_eq!(dag.len(), 66 + 23 + 144);
        // Critical path = number of layers (barriers serialise layers).
        assert_eq!(dag.critical_path_len() as usize, vgg16_layers(224).len());
    }

    #[test]
    fn repeats_extend_chain() {
        let cfg = VggConfig { input_hw: 224, block_len: 64, repeats: 3 };
        let dag = build_dag(&cfg, None);
        let single = build_dag(&VggConfig { repeats: 1, ..cfg.clone() }, None);
        assert_eq!(dag.len(), 3 * single.len());
        assert_eq!(dag.critical_path_len(), 3 * single.critical_path_len());
    }

    #[test]
    fn work_scale_proportional_to_flops() {
        let cfg = VggConfig::default();
        let dag = build_dag(&cfg, None);
        let total_work: f64 = dag.nodes.iter().map(|n| n.work_scale).sum::<f64>() * REF_FLOPS;
        let expect = total_flops(224);
        let ratio = total_work / expect;
        assert!((0.95..1.05).contains(&ratio), "work {total_work:.3e} vs {expect:.3e}");
    }

    #[test]
    fn type_ids_are_per_layer() {
        let dag = build_dag(&VggConfig::default(), None);
        let n_layers = vgg16_layers(224).len();
        assert_eq!(dag.n_types(), n_layers);
    }

    #[test]
    fn small_input_scales() {
        let layers = vgg16_layers(64);
        let fc6 = layers.iter().find(|l| l.name.starts_with("fc6")).unwrap();
        match fc6.kind {
            LayerKind::Fc { c_in, .. } => assert_eq!(c_in, 512 * 2 * 2),
            _ => panic!(),
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_multiple_input() {
        vgg16_layers(100);
    }
}
