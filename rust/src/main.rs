//! `repro` — the CLI driver for the XiTAO/PTT reproduction.
//!
//! Figure regeneration:
//!   repro fig5|fig6|fig7|fig8|fig9|fig10 [--quick] [--seeds N]
//!   repro ablation-ptt | ablation-baselines | stream-interference | all
//!
//! Single experiments:
//!   repro run-dag [--config f.json] [--platform tx2] [--policy performance]
//!                 [--backend sim|real] [--tasks 1000] [--parallelism 4]
//!                 [--kernel mix] [--seed 42] [--quick]
//!   repro stream  [--scenario stream-pois8] [--policy performance]
//!                 [--backend sim|real] [--seed 42] [--baseline] [--quick]
//!                 (custom: --scenario custom --platform hom8 --apps 4
//!                  --tasks 200 --parallelism 4 --mean-gap 0.02)
//!   repro vgg16 [--threads 8] [--repeats 3] [--block-len 64]
//!   repro vgg16-infer [--mode pipeline|whole|dag] [--hw 64] [--block-len 64]
//!   repro serve [--backend sim|real] [--scenario hom4] [--policy ptt-serving]
//!               [--tenants 3] [--rate 40] [--horizon 1.0] [--seed 42]
//!               [--baseline] [--quick]
//!   repro ptt-dump [--platform tx2] [--tasks 500] ...
//!   repro scenarios                 # list platform + stream scenarios
//!   repro policies                  # list scheduling policies + aliases
//!   repro bench-overhead [--quick] [--json] [--compare] [--pressure]  # perf harness
//!   repro bench-serving [--quick] [--json]                # serving ramp
//!   repro bench-faults [--quick] [--json] [--backend sim|real|both]
//!                                                         # fault-injection chaos harness
//!   repro bench-elastic [--quick] [--json]                # moldable-width ablation
//!   repro experiment [--quick] [--json] [--backend sim|real|both]
//!                                                         # policy × scenario matrix
//!
//! Platforms resolve through the scenario registry
//! (`platform::scenarios`), execution substrates through the
//! `ExecutionBackend` registry (`exec`): the simulator reproduces the
//! paper's platforms in virtual time (see DESIGN.md), `--backend real`
//! runs the identical scheduling code on host threads.

use xitao::bench::{self, BenchOpts};
use xitao::cli::Args;
use xitao::config::RunConfig;
use xitao::coordinator::ptt::Ptt;
use xitao::coordinator::scheduler::policy_by_name;
use xitao::dag_gen::{DagParams, generate};
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name, policy_for_run};
use xitao::kernels::KernelSizes;
use xitao::platform::{Platform, scenarios};
use xitao::runtime::{PjrtService, VggWeights, build_real_dag, pipeline_infer, synthetic_image};
use xitao::vgg::{VggConfig, build_dag as build_vgg_dag};
use xitao::coordinator::{QosClass, ServingOpts};
use xitao::workload::scenarios::{stream_by_name, stream_scenarios};
use xitao::workload::{ServingStream, WorkloadStream};

fn main() {
    let args = Args::from_env();
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "fig5" | "fig6" | "fig7" | "fig8" | "fig9" | "fig10" | "ablation-ptt"
        | "ablation-baselines" | "ablation-energy" | "stream-interference" | "all" => {
            cmd_figures(&cmd, &args)
        }
        "run-dag" => cmd_run_dag(&args),
        "bench-overhead" => cmd_bench_overhead(&args),
        "bench-interference" => cmd_bench_interference(&args),
        "bench-serving" => cmd_bench_serving(&args),
        "bench-faults" => cmd_bench_faults(&args),
        "bench-elastic" => cmd_bench_elastic(&args),
        "experiment" => cmd_experiment(&args),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "vgg16" => cmd_vgg16(&args),
        "vgg16-infer" => cmd_vgg16_infer(&args),
        "ptt-dump" => cmd_ptt_dump(&args),
        "scenarios" => cmd_scenarios(),
        "policies" => cmd_policies(),
        "help" | "--help" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
repro — XiTAO + Performance Trace Table reproduction

figures:    fig5 fig6 fig7 fig8 fig9 fig10 ablation-ptt ablation-baselines
            ablation-energy stream-interference all
            options: --quick --seeds N
single run: run-dag [--config f.json] [--platform <scenario>|hom<N>]
                    [--policy performance|homogeneous|cats|dheft|energy
                              |heft|peft|dls|portfolio]
                    [--backend sim|real] [--tasks N] [--parallelism P]
                    [--kernel mix|matmul|sort|copy] [--seed S] [--quick]
streams:    stream [--scenario stream-pois8|duet-tx2|bg-interferer-haswell20]
                   [--policy ...] [--backend sim|real] [--seed S]
                   [--baseline] [--quick]
            stream --scenario custom --platform hom8 --apps 4 --tasks 200
                   --parallelism 4 --mean-gap 0.02
serving:    serve [--backend sim|real] [--scenario hom4]
                  [--policy ptt-serving] [--tenants 3] [--rate 40]
                  [--horizon 1.0] [--seed S] [--baseline] [--quick]
            (continuous multi-tenant window: open-loop Poisson arrivals
             over the tenants, QoS classes round-robin, admission
             backpressure on, clean drain at the horizon)
platforms:  run `repro scenarios` for the registered list; hom<N> for
            any homogeneous core count
policies:   run `repro policies` for the registered list with aliases
            and descriptions

perf:       bench-overhead [--quick] [--json] [--compare] [--pressure]
            (lock-free hot-path overhead incl. many-core hom64/hom128 and
             single-vs-batched steal pressure; --json writes
             BENCH_sched_overhead.json at the repo root, --compare prints
             the mutex-vs-lockfree speedup, --pressure sweeps thief-pack
             sizes against the batched steal_half path)
            bench-interference [--quick] [--json] [--backend sim|real|both]
            [--scenario interference20] [--seed S]
            (the §5.3 dynamic-heterogeneity response: per-interval PTT
             values, change-detector flags and critical placements on the
             interfered cores, ptt vs ptt-adaptive, both backends; --json
             writes BENCH_interference_response.json at the repo root)
            bench-serving [--quick] [--json] [--scenario hom4]
            [--policy ptt-serving] [--seed S]
            (serving tenant ramp on the sim backend: sustained
             admissions/sec, p99 slowdown, per-QoS SLO attainment, Jain
             fairness; --json writes BENCH_serving.json at the repo root)
            bench-faults [--quick] [--json] [--backend sim|real|both]
            [--seeds N] [--seed S]
            (chaos harness: every registered fault scenario — core
             fail-stop with and without recovery, fail-slow — × policy ×
             backend, each cell against its fault-free twin; reports
             makespan inflation, recovery latency and tasks lost (must be
             0, exits non-zero otherwise); --json writes
             BENCH_fault_recovery.json at the repo root)
            bench-elastic [--quick] [--json] [--seeds N] [--seed S]
            (moldable-width ablation: ptt-elastic against a width-1-forced
             twin of the same DAG/seed — scaling (hom64, biglittle44),
             interference (interference20, dvfs8) and bandwidth-starved
             (commbound-tx2) scenarios, sim backend; --json writes
             BENCH_elastic.json at the repo root)
            experiment [--quick] [--json] [--backend sim|real|both]
            [--seeds N] [--tasks N] [--parallelism P] [--seed S]
            (the full policy × scenario matrix: every registered policy on
             every platform scenario, each row anchored to its
             critical-path/area makespan lower bound as pct_of_bound;
             --json writes BENCH_experiment.json at the repo root)

vgg:        vgg16 [--threads N] [--repeats R] [--block-len B] [--policy ...]
            vgg16-infer [--mode pipeline|whole|dag|validate] [--hw 64]
diag:       ptt-dump [--platform ...] [--tasks N]
";

fn cmd_policies() -> i32 {
    println!("registered scheduling policies (run-dag/stream --policy <name-or-alias>):");
    println!(
        "(widths: 1 = fixed width 1; all = PTT width search, moldability ignored; \
         elastic = moldability-capped + narrowing; plan = offline plan fixes partitions)"
    );
    for p in xitao::coordinator::scheduler::POLICIES {
        println!(
            "  {:18} widths: {:8} aliases: {:22} — {}",
            p.name,
            p.widths,
            p.aliases.join(", "),
            p.description
        );
    }
    0
}

fn cmd_scenarios() -> i32 {
    println!("registered platform scenarios (plus dynamic hom<N>):");
    for s in scenarios::scenarios() {
        let p = s.platform();
        println!(
            "  {:24} {:2} cores, {:1} cluster(s), {:2} episode(s) — {}",
            s.name,
            p.topo.n_cores(),
            p.topo.clusters.len(),
            p.episodes.episodes.len(),
            s.description,
        );
    }
    println!("\nregistered workload streams (repro stream --scenario <name>):");
    for s in stream_scenarios() {
        let stream = s.stream(0, true);
        println!(
            "  {:24} {:2} app(s) on {:20} — {}",
            s.name,
            stream.n_submissions(),
            s.platform,
            s.description,
        );
    }
    0
}

fn bench_opts(args: &Args) -> BenchOpts {
    let mut opts = if args.switch("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seeds = args.get("seeds", opts.seeds);
    if let Some(b) = args.flag("backend") {
        if backend_by_name(b).is_none() {
            eprintln!("unknown backend '{b}' (sim|real)");
            std::process::exit(2);
        }
        opts.backend = b.to_string();
    }
    opts
}

fn cmd_figures(cmd: &str, args: &Args) -> i32 {
    let opts = bench_opts(args);
    let run = |name: &str| {
        let tables = match name {
            "fig5" => bench::fig5(&opts),
            "fig6" => bench::fig6(&opts),
            "fig7" => bench::fig7(&opts),
            "fig8" => bench::fig8(&opts),
            "fig9" => bench::fig9(&opts),
            "fig10" => bench::fig10(&opts),
            "ablation-ptt" => bench::ablation_ptt(&opts),
            "ablation-energy" => bench::ablation_energy(&opts),
            "ablation-baselines" => bench::ablation_baselines(&opts),
            "stream-interference" => bench::stream_interference(&opts),
            _ => unreachable!(),
        };
        bench::emit(name, &tables);
    };
    if cmd == "all" {
        for name in [
            "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "ablation-ptt",
            "ablation-baselines", "ablation-energy", "stream-interference",
        ] {
            run(name);
        }
    } else {
        run(cmd);
    }
    0
}

fn cmd_run_dag(args: &Args) -> i32 {
    let mut cfg = match RunConfig::from_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    if args.switch("quick") {
        // Smoke-test scale: enough tasks to exercise every queue path.
        cfg.tasks = cfg.tasks.min(48);
    }
    let plat = cfg.make_platform().expect("validated");
    let backend = backend_by_name(&cfg.backend).expect("validated");
    let params = match cfg.kernel_class() {
        Some(class) => DagParams::single(class, cfg.tasks, cfg.parallelism, cfg.seed),
        None => DagParams::mix(cfg.tasks, cfg.parallelism, cfg.seed),
    };
    if policy_by_name(&cfg.policy, plat.topo.n_cores()).is_none() {
        eprintln!("unknown policy '{}'", cfg.policy);
        return 2;
    }
    // Real threads execute actual kernel payloads; the simulator drives the
    // analytic model instead.
    let params = if backend.name() == "real" {
        params.with_payloads(KernelSizes::small())
    } else {
        params
    };
    let (dag, stats) = generate(&params);
    println!(
        "generated DAG: {} tasks, {} levels, parallelism {:.2} ({} backend on {})",
        stats.tasks,
        stats.levels,
        stats.parallelism,
        backend.name(),
        plat.topo.name
    );
    // Plan-ahead policies (heft/peft/dls/portfolio) rank the concrete DAG;
    // everything else resolves straight from the registry.
    let policy = policy_for_run(&cfg.policy, &plat, &dag).expect("validated above");
    let opts = RunOpts { seed: cfg.seed, ..Default::default() };
    // Scheduling errors (deadlock, all cores fail-stopped) surface as a
    // message and a non-zero exit, not a panic.
    let result = match backend.run(&dag, &plat, policy.as_ref(), None, &opts) {
        Ok(run) => run.result,
        Err(e) => {
            eprintln!("run failed: {e}");
            return 1;
        }
    };
    println!(
        "policy={} makespan={:.4}s throughput={:.1} tasks/s utilisation={:.2}",
        result.policy,
        result.makespan,
        result.throughput(),
        result.utilisation(plat.topo.n_cores()),
    );
    let bound = if backend.name() == "real" {
        xitao::coordinator::observed_cp_bound(&dag, &result.records)
    } else {
        xitao::coordinator::model_bound(&dag, &plat)
    };
    match bound.pct_of(result.makespan) {
        Some(pct) => println!(
            "lower bound: cp={:.4}s area={:.4}s combined={:.4}s → makespan at {:.1}% of bound",
            bound.cp,
            bound.area,
            bound.combined(),
            pct
        ),
        None => println!("lower bound: unavailable (no trace records)"),
    }
    println!("width histogram: {:?}", result.width_histogram());
    let crit = result.critical_records().len();
    println!(
        "critical tasks: {} / {} ({:.1}%)",
        crit,
        result.n_tasks(),
        100.0 * crit as f64 / result.n_tasks() as f64
    );
    let busy = result.core_busy_time(plat.topo.n_cores());
    println!("per-core busy [s]: {:?}", busy.iter().map(|b| (b * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    0
}

fn cmd_bench_overhead(args: &Args) -> i32 {
    let opts = xitao::bench::OverheadOpts {
        quick: args.switch("quick"),
        compare: args.switch("compare"),
        json: args.switch("json"),
        pressure: args.switch("pressure"),
    };
    let run = bench::emit_overhead(&opts);
    if run.regressions > 0 {
        eprintln!(
            "bench-overhead: {} hot-path metric(s) regressed below the committed measured \
             baseline (details above)",
            run.regressions
        );
        return 1;
    }
    0
}

fn cmd_bench_interference(args: &Args) -> i32 {
    let backend = args.get_str("backend", "both");
    if !["sim", "real", "both"].contains(&backend.as_str()) {
        eprintln!("unknown backend '{backend}' (sim|real|both)");
        return 2;
    }
    let scenario = args.get_str("scenario", "interference20");
    let plat = match scenarios::by_name(&scenario) {
        Some(p) => p,
        None => {
            eprintln!("unknown platform scenario '{scenario}'");
            return 2;
        }
    };
    if plat.episodes.is_empty() {
        eprintln!("scenario '{scenario}' has no episodes — nothing to respond to");
        return 2;
    }
    let opts = xitao::bench::InterferenceOpts {
        quick: args.switch("quick"),
        json: args.switch("json"),
        backend,
        scenario,
        seed: args.get("seed", 7),
    };
    xitao::bench::emit_interference(&opts);
    0
}

fn cmd_bench_serving(args: &Args) -> i32 {
    let scenario = args.get_str("scenario", "hom4");
    if scenarios::by_name(&scenario).is_none() {
        eprintln!("unknown platform scenario '{scenario}'");
        return 2;
    }
    let policy = args.get_str("policy", "ptt-serving");
    let n_cores = scenarios::by_name(&scenario).expect("validated").topo.n_cores();
    if policy_by_name(&policy, n_cores).is_none() {
        eprintln!("unknown policy '{policy}'");
        return 2;
    }
    let opts = xitao::bench::ServingBenchOpts {
        quick: args.switch("quick"),
        json: args.switch("json"),
        scenario,
        policy,
        seed: args.get("seed", 11),
    };
    xitao::bench::emit_serving(&opts);
    0
}

fn cmd_bench_faults(args: &Args) -> i32 {
    let backend = args.get_str("backend", "both");
    if !["sim", "real", "both"].contains(&backend.as_str()) {
        eprintln!("unknown backend '{backend}' (sim|real|both)");
        return 2;
    }
    let opts = xitao::bench::FaultBenchOpts {
        quick: args.switch("quick"),
        json: args.switch("json"),
        backend,
        seeds: args.get("seeds", 2),
        seed: args.get("seed", 0xFA),
    };
    let result = xitao::bench::emit_faults(&opts);
    // The exactly-once reclamation guarantee is the acceptance criterion:
    // any lost or duplicated task fails the harness, not just the report.
    let (mut lost, mut dup) = (0.0, 0.0);
    if let Some(rows) = result.get("rows").and_then(xitao::util::json::Json::as_arr) {
        for r in rows {
            lost += r.get("tasks_lost").and_then(xitao::util::json::Json::as_f64).unwrap_or(0.0);
            dup += r.get("duplicates").and_then(xitao::util::json::Json::as_f64).unwrap_or(0.0);
        }
    }
    if lost > 0.0 || dup > 0.0 {
        eprintln!(
            "bench-faults: exactly-once violated — {lost:.0} task(s) lost, {dup:.0} duplicate \
             commit(s) (details above)"
        );
        return 1;
    }
    0
}

fn cmd_bench_elastic(args: &Args) -> i32 {
    let opts = xitao::bench::ElasticOpts {
        quick: args.switch("quick"),
        json: args.switch("json"),
        seeds: args.get("seeds", 3),
        seed: args.get("seed", 0xE7),
        ..Default::default()
    };
    xitao::bench::emit_elastic(&opts);
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let backend = args.get_str("backend", "both");
    if !["sim", "real", "both"].contains(&backend.as_str()) {
        eprintln!("unknown backend '{backend}' (sim|real|both)");
        return 2;
    }
    let opts = xitao::bench::ExperimentOpts {
        quick: args.switch("quick"),
        json: args.switch("json"),
        backend,
        seeds: args.get("seeds", 3),
        tasks: args.get("tasks", 120),
        parallelism: args.get("parallelism", 4.0),
        seed: args.get("seed", 0xE1),
    };
    xitao::bench::emit_experiment(&opts);
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let backend = args.get_str("backend", "sim");
    let scenario = args.get_str("scenario", "hom4");
    let policy = args.get_str("policy", "ptt-serving");
    let tenants: usize = args.get("tenants", 3);
    let rate: f64 = args.get("rate", 40.0);
    let horizon: f64 = args.get("horizon", 1.0);
    let seed: u64 = args.get("seed", 42);
    let quick = args.switch("quick");
    let baseline = args.switch("baseline");
    if tenants == 0 {
        eprintln!("serve needs --tenants ≥ 1");
        return 2;
    }
    if !(rate > 0.0 && rate.is_finite()) || !(horizon > 0.0 && horizon.is_finite()) {
        eprintln!("serve needs --rate > 0 and --horizon > 0");
        return 2;
    }
    let resolved = match backend_by_name(&backend) {
        Some(b) => b,
        None => {
            eprintln!("unknown backend '{backend}' (sim|real)");
            return 2;
        }
    };
    // Smoke scale: a window short enough for CI, same admission machinery.
    let horizon = if quick { horizon.min(0.3) } else { horizon };
    let mut mix = xitao::bench::serving::ramp_tenants(tenants, quick, seed);
    // Real threads execute actual kernel payloads, as in run-dag/stream.
    if resolved.name() == "real" {
        for t in &mut mix {
            t.params = t.params.clone().with_payloads(KernelSizes::small());
        }
    }
    let stream = ServingStream::new(mix, rate, seed);
    let report = match xitao::exec::run_serving_triple(
        &backend,
        &scenario,
        &policy,
        &stream,
        horizon,
        &RunOpts { seed, ..Default::default() },
        &ServingOpts::default(),
        baseline,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve error: {e}");
            return 2;
        }
    };
    println!(
        "serving window: {tenants} tenant(s) at {rate:.1} apps/s for {horizon}s on \
         {scenario} — {} backend, policy {}",
        resolved.name(),
        report.run.result.policy
    );
    println!(
        "offered {} apps, admitted {} ({:.1} apps/s sustained), drained in {:.4}s",
        report.offered(),
        report.apps.len(),
        report.admissions_per_sec(),
        report.run.result.makespan
    );
    println!("{:>12} {:>9} {:>7} {:>7} {:>9}", "class", "admitted", "delays", "sheds", "slo");
    let slo = report.slo_attainment();
    for q in QosClass::ALL {
        let i = q.index();
        println!(
            "{:>12} {:>9} {:>7} {:>7} {:>9}",
            q.name(),
            report.run.counters.admitted[i],
            report.run.counters.delays[i],
            report.run.counters.sheds[i],
            slo[i].map_or("-".into(), |v| format!("{v:.3}")),
        );
    }
    println!(
        "p99 slowdown: {}  Jain fairness: {}",
        report.p99_slowdown().map_or("- (run with --baseline)".into(), |v| format!("{v:.3}")),
        report.jain().map_or("n/a (nothing admitted)".into(), |j| format!("{j:.4}")),
    );
    println!(
        "lane high-water: {}  wsq retired buffers: {}  fairness samples: {}",
        report.run.lane_high_water,
        report.run.wsq_retired,
        report.run.fairness.len()
    );
    0
}

fn cmd_stream(args: &Args) -> i32 {
    let scenario = args.get_str("scenario", "stream-pois8");
    let policy = args.get_str("policy", "performance");
    let backend = args.get_str("backend", "sim");
    let seed: u64 = args.get("seed", 42);
    let quick = args.switch("quick");
    let baseline = args.switch("baseline");

    let (mut stream, platform) = if scenario == "custom" {
        let platform = args.get_str("platform", "hom8");
        let apps: usize = args.get("apps", 4);
        let tasks: usize = args.get("tasks", 200);
        let parallelism: f64 = args.get("parallelism", 4.0);
        let mean_gap: f64 = args.get("mean-gap", 0.02);
        if apps == 0 || tasks == 0 || parallelism < 1.0 || mean_gap <= 0.0 {
            eprintln!("custom stream needs --apps ≥ 1, --tasks ≥ 1, --parallelism ≥ 1, --mean-gap > 0");
            return 2;
        }
        let tasks = if quick { tasks.min(48) } else { tasks };
        let template = DagParams::mix(tasks, parallelism, seed);
        let stream = WorkloadStream::poisson(apps, mean_gap, seed, move |_i, s| {
            template.clone().with_seed(s)
        });
        (stream, platform)
    } else {
        // Custom-shape flags only apply with --scenario custom; ignoring
        // them silently would mislabel the experiment.
        for flag in ["platform", "apps", "tasks", "parallelism", "mean-gap"] {
            if args.flag(flag).is_some() {
                eprintln!(
                    "warning: --{flag} is ignored for the named scenario '{scenario}' \
                     (use --scenario custom to shape the stream)"
                );
            }
        }
        match stream_by_name(&scenario) {
            Some(s) => (s.stream(seed, quick), s.platform.to_string()),
            None => {
                eprintln!(
                    "unknown stream scenario '{scenario}' (one of {:?} or 'custom')",
                    xitao::workload::scenarios::stream_names()
                );
                return 2;
            }
        }
    };
    let resolved = match backend_by_name(&backend) {
        Some(b) => b,
        None => {
            eprintln!("unknown backend '{backend}' (sim|real)");
            return 2;
        }
    };
    // Real threads execute actual kernel payloads, as in run-dag.
    if resolved.name() == "real" {
        for app in &mut stream.apps {
            app.params = app.params.clone().with_payloads(KernelSizes::small());
        }
    }

    let run = match xitao::exec::run_stream_triple(
        &backend,
        &platform,
        &policy,
        &stream,
        &RunOpts { seed, ..Default::default() },
        baseline,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("stream error: {e}");
            return 2;
        }
    };

    println!(
        "stream '{scenario}' ({} apps) on {platform} — {backend} backend, policy {}",
        run.apps.len(),
        run.result.policy
    );
    println!(
        "{:>4} {:20} {:>9} {:>7} {:>11} {:>11} {:>9}",
        "app", "name", "arrival", "tasks", "makespan", "isolated", "slowdown"
    );
    for a in &run.apps {
        println!(
            "{:>4} {:20} {:>9.4} {:>7} {:>11.4} {:>11} {:>9}",
            a.app_id,
            a.name,
            a.arrival,
            a.n_tasks,
            a.makespan(),
            a.isolated_makespan.map_or("-".into(), |v| format!("{v:.4}")),
            a.slowdown.map_or("-".into(), |v| format!("{v:.3}")),
        );
    }
    let total_tasks: usize = run.apps.iter().map(|a| a.n_tasks).sum();
    println!(
        "aggregate: makespan={:.4}s tasks={} throughput={:.1} tasks/s",
        run.result.makespan,
        total_tasks,
        run.result.throughput()
    );
    println!(
        "Jain fairness index: {}",
        run.jain_fairness().map_or("n/a (no apps ran)".into(), |j| format!("{j:.4}")),
    );
    0
}

fn cmd_vgg16(args: &Args) -> i32 {
    let threads: usize = args.get("threads", 8);
    let repeats: usize = args.get("repeats", 3);
    let block_len: usize = args.get("block-len", 64);
    let policy_name = args.get_str("policy", "performance");
    let plat = Platform::homogeneous(threads);
    let policy = match policy_by_name(&policy_name, threads) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy '{policy_name}'");
            return 2;
        }
    };
    let dag = build_vgg_dag(&VggConfig { input_hw: 224, block_len, repeats }, None);
    println!("VGG-16 DAG: {} TAOs, critical path {}", dag.len(), dag.critical_path_len());
    let backend = backend_by_name("sim").expect("registered backend");
    let run = match backend.run(&dag, &plat, policy.as_ref(), None, &RunOpts::default()) {
        Ok(run) => run,
        Err(e) => {
            eprintln!("run failed: {e}");
            return 1;
        }
    };
    println!(
        "threads={} makespan={:.4}s throughput={:.1} TAO/s",
        threads,
        run.result.makespan,
        run.result.throughput()
    );
    println!("width %: {:?}", run.result.width_percentages());
    0
}

fn cmd_vgg16_infer(args: &Args) -> i32 {
    let mode = args.get_str("mode", "validate");
    let hw: usize = args.get("hw", 64);
    let block_len: usize = args.get("block-len", 64);
    let artifacts = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let t0 = std::time::Instant::now();
    let svc = match PjrtService::start(&artifacts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("PJRT service failed to start: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!("[{:.2}s] PJRT service up (tiles compiled)", t0.elapsed().as_secs_f64());
    let weights = std::sync::Arc::new(VggWeights::synthetic(hw, 1));
    let image = synthetic_image(hw, 2);
    let h = svc.handle();

    let top = |logits: &[f32]| -> (usize, f32) {
        logits
            .iter()
            .enumerate()
            .fold((0, f32::NEG_INFINITY), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc })
    };

    let run_pipeline = || {
        let t = std::time::Instant::now();
        let logits = pipeline_infer(&weights, &image, &h).expect("pipeline inference");
        (logits, t.elapsed().as_secs_f64())
    };
    let run_whole = || {
        h.vgg_load(weights.flat()).expect("vgg_load");
        let t = std::time::Instant::now();
        let logits = h.vgg_infer(&image).expect("whole-model inference");
        (logits, t.elapsed().as_secs_f64())
    };
    let run_dag = || {
        let (dag, out) = build_real_dag(weights.clone(), image.clone(), h.clone(), block_len);
        let plat = Platform::homogeneous(4);
        let backend = backend_by_name("real").expect("registered backend");
        let t = std::time::Instant::now();
        let res = backend
            .run(&dag, &plat, &xitao::coordinator::PerformanceBased, None, &RunOpts::default())
            .expect("fault-free DAG run")
            .result;
        let dt = t.elapsed().as_secs_f64();
        println!(
            "DAG run: {} TAOs, makespan {:.2}s, width histogram {:?}",
            res.n_tasks(),
            res.makespan,
            res.width_histogram()
        );
        (out.snapshot(), dt)
    };

    match mode.as_str() {
        "pipeline" => {
            let (logits, dt) = run_pipeline();
            let (idx, val) = top(&logits);
            println!("pipeline: {dt:.2}s, argmax={idx} ({val:.4})");
        }
        "whole" => {
            let (logits, dt) = run_whole();
            let (idx, val) = top(&logits);
            println!("whole-model: {dt:.2}s, argmax={idx} ({val:.4})");
        }
        "dag" => {
            let (logits, dt) = run_dag();
            let (idx, val) = top(&logits);
            println!("TAO-DAG: {dt:.2}s, argmax={idx} ({val:.4})");
        }
        "validate" => {
            // The E2E cross-check: all three paths on the same weights.
            let (a, ta) = run_pipeline();
            let (b, tb) = run_whole();
            let (c, tc) = run_dag();
            let diff_ab = max_abs_diff(&a, &b);
            let diff_ac = max_abs_diff(&a, &c);
            println!("pipeline {ta:.2}s | whole-model {tb:.2}s | TAO-DAG {tc:.2}s");
            println!("max |pipeline − whole|  = {diff_ab:.4}");
            println!("max |pipeline − TAO-DAG| = {diff_ac:.4}");
            let (idx, _) = top(&a);
            println!("argmax (all paths) = {idx} / {} / {}", top(&b).0, top(&c).0);
            let scale = a.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
            if diff_ab / scale > 1e-2 || diff_ac / scale > 1e-2 {
                eprintln!("VALIDATION FAILED: paths disagree");
                return 1;
            }
            println!("VALIDATION OK: rust pipeline ≡ JAX whole model ≡ XiTAO DAG");
        }
        other => {
            eprintln!("unknown mode '{other}'");
            return 2;
        }
    }
    0
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs()))
}

fn cmd_ptt_dump(args: &Args) -> i32 {
    let cfg = match RunConfig::from_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let plat = cfg.make_platform().unwrap();
    let params = DagParams::mix(cfg.tasks, cfg.parallelism, cfg.seed);
    let (dag, _) = generate(&params);
    let ptt = Ptt::new(dag.n_types(), &plat.topo);
    let backend = backend_by_name("sim").expect("registered backend");
    if let Err(e) = backend.run(
        &dag,
        &plat,
        &xitao::coordinator::PerformanceBased,
        Some(&ptt),
        &RunOpts { seed: cfg.seed, ..Default::default() },
    ) {
        eprintln!("run failed: {e}");
        return 1;
    }
    for ty in 0..dag.n_types() {
        println!("== PTT type {ty} ==");
        for (core, width, val) in ptt.dump(ty, &plat.topo) {
            if val > 0.0 {
                println!("  core {core:2} width {width:2}: {val:.6}s (cost {:.6})", val * width as f64);
            }
        }
    }
    0
}
