//! Run configuration: JSON config files merged with CLI flags.
//!
//! `repro run-dag --config my.json --policy cats` loads `my.json` and lets
//! the explicit flags win. The JSON schema mirrors the flag names:
//!
//! ```json
//! {
//!   "platform": "tx2",          // any registered scenario | hom<N>
//!   "backend": "sim",           // sim | real
//!   "policy": "performance",    // see `repro policies`: performance | ptt-adaptive |
//!                               // homogeneous | cats | dheft | energy (+ aliases)
//!   "tasks": 1000,
//!   "parallelism": 4.0,
//!   "kernel": "mix",            // mix | matmul | sort | copy
//!   "edge_rate": 1.5,
//!   "seed": 42,
//!   "artifacts": "artifacts"
//! }
//! ```
//!
//! Platform names resolve through [`crate::platform::scenarios`]; backend
//! names through [`crate::exec::backend_by_name`].

use crate::cli::Args;
use crate::platform::{KernelClass, Platform, scenarios};
use crate::util::Json;
use std::path::PathBuf;

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub platform: String,
    pub backend: String,
    pub policy: String,
    pub tasks: usize,
    pub parallelism: f64,
    pub kernel: String,
    pub edge_rate: f64,
    pub seed: u64,
    pub artifacts: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            platform: "tx2".into(),
            backend: "sim".into(),
            policy: "performance".into(),
            tasks: 1000,
            parallelism: 4.0,
            kernel: "mix".into(),
            edge_rate: 1.5,
            seed: 42,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

impl RunConfig {
    /// Load from a JSON file; unknown keys are rejected (typo safety).
    pub fn from_json(text: &str) -> Result<RunConfig, String> {
        let json = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = json.as_obj().ok_or("config must be a JSON object")?;
        let mut cfg = RunConfig::default();
        for (k, v) in obj {
            match k.as_str() {
                "platform" => cfg.platform = v.as_str().ok_or("platform: string")?.into(),
                "backend" => cfg.backend = v.as_str().ok_or("backend: string")?.into(),
                "policy" => cfg.policy = v.as_str().ok_or("policy: string")?.into(),
                "tasks" => cfg.tasks = v.as_usize().ok_or("tasks: integer")?,
                "parallelism" => cfg.parallelism = v.as_f64().ok_or("parallelism: number")?,
                "kernel" => cfg.kernel = v.as_str().ok_or("kernel: string")?.into(),
                "edge_rate" => cfg.edge_rate = v.as_f64().ok_or("edge_rate: number")?,
                "seed" => cfg.seed = v.as_u64().ok_or("seed: integer")?,
                "artifacts" => cfg.artifacts = v.as_str().ok_or("artifacts: string")?.into(),
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Start from `--config file` (if given), then apply explicit flags.
    pub fn from_args(args: &Args) -> Result<RunConfig, String> {
        let mut cfg = match args.flag("config") {
            Some(path) => {
                let text =
                    std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
                RunConfig::from_json(&text)?
            }
            None => RunConfig::default(),
        };
        if let Some(v) = args.flag("platform") {
            cfg.platform = v.into();
        }
        if let Some(v) = args.flag("backend") {
            cfg.backend = v.into();
        }
        if args.switch("real") {
            // Legacy spelling of `--backend real`.
            cfg.backend = "real".into();
        }
        if let Some(v) = args.flag("policy") {
            cfg.policy = v.into();
        }
        if let Some(v) = args.flag("tasks") {
            cfg.tasks = v.parse().map_err(|_| "tasks: integer")?;
        }
        if let Some(v) = args.flag("parallelism") {
            cfg.parallelism = v.parse().map_err(|_| "parallelism: number")?;
        }
        if let Some(v) = args.flag("kernel") {
            cfg.kernel = v.into();
        }
        if let Some(v) = args.flag("edge-rate") {
            cfg.edge_rate = v.parse().map_err(|_| "edge-rate: number")?;
        }
        if let Some(v) = args.flag("seed") {
            cfg.seed = v.parse().map_err(|_| "seed: integer")?;
        }
        if let Some(v) = args.flag("artifacts") {
            cfg.artifacts = v.into();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn validate(&self) -> Result<(), String> {
        self.make_platform()?;
        if crate::exec::backend_by_name(&self.backend).is_none() {
            return Err(format!("unknown backend '{}' (sim|real)", self.backend));
        }
        if self.kernel != "mix" && KernelClass::from_name(&self.kernel).is_none() {
            return Err(format!("unknown kernel '{}'", self.kernel));
        }
        if self.tasks == 0 {
            return Err("tasks must be positive".into());
        }
        if self.parallelism < 1.0 {
            return Err("parallelism must be ≥ 1".into());
        }
        Ok(())
    }

    /// Resolve the platform name through the scenario registry.
    pub fn make_platform(&self) -> Result<Platform, String> {
        scenarios::by_name(&self.platform).ok_or_else(|| {
            format!(
                "unknown platform '{}' (one of {:?} or hom<N>)",
                self.platform,
                scenarios::names()
            )
        })
    }

    /// Kernel selection for the DAG generator (`None` = mix).
    pub fn kernel_class(&self) -> Option<KernelClass> {
        KernelClass::from_name(&self.kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let cfg = RunConfig::from_json(
            r#"{"platform": "haswell20", "tasks": 99, "parallelism": 2.5, "policy": "cats"}"#,
        )
        .unwrap();
        assert_eq!(cfg.platform, "haswell20");
        assert_eq!(cfg.tasks, 99);
        assert_eq!(cfg.parallelism, 2.5);
        assert_eq!(cfg.policy, "cats");
        // Unspecified keys keep defaults.
        assert_eq!(cfg.kernel, "mix");
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RunConfig::from_json(r#"{"platfrom": "tx2"}"#).is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_json(r#"{"tasks": 0}"#).is_err());
        assert!(RunConfig::from_json(r#"{"parallelism": 0.5}"#).is_err());
        assert!(RunConfig::from_json(r#"{"kernel": "nope"}"#).is_err());
        assert!(RunConfig::from_json(r#"{"platform": "riscv"}"#).is_err());
    }

    #[test]
    fn hom_platform_parses() {
        let cfg = RunConfig::from_json(r#"{"platform": "hom8"}"#).unwrap();
        assert_eq!(cfg.make_platform().unwrap().topo.n_cores(), 8);
        assert!(RunConfig::from_json(r#"{"platform": "hom0"}"#).is_err());
    }

    #[test]
    fn registered_scenarios_all_accepted() {
        for name in crate::platform::scenarios::names() {
            let cfg =
                RunConfig::from_json(&format!(r#"{{"platform": "{name}"}}"#)).unwrap();
            assert!(cfg.make_platform().is_ok(), "{name}");
        }
    }

    #[test]
    fn backend_parses_and_validates() {
        let cfg = RunConfig::from_json(r#"{"backend": "real"}"#).unwrap();
        assert_eq!(cfg.backend, "real");
        assert!(RunConfig::from_json(r#"{"backend": "quantum"}"#).is_err());
        // --real switch is a legacy alias for --backend real.
        let args = Args::parse(["run", "--real"].iter().map(|s| s.to_string()));
        assert_eq!(RunConfig::from_args(&args).unwrap().backend, "real");
        // Explicit --backend flag wins over the config default.
        let args = Args::parse(["run", "--backend", "sim"].iter().map(|s| s.to_string()));
        assert_eq!(RunConfig::from_args(&args).unwrap().backend, "sim");
    }

    #[test]
    fn flags_override_config() {
        use crate::cli::Args;
        let dir = std::env::temp_dir().join("xitao_cfg_test.json");
        std::fs::write(&dir, r#"{"tasks": 10, "policy": "cats"}"#).unwrap();
        let args = Args::parse(
            ["run", "--config", dir.to_str().unwrap(), "--tasks", "77"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.tasks, 77); // flag wins
        assert_eq!(cfg.policy, "cats"); // file value kept
    }
}
