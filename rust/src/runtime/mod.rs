//! The PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (JAX/Pallas, build-time only) and executes them
//! from the Rust hot path.
//!
//! - [`manifest`] — the artifact manifest contract.
//! - [`engine`] — the thread-confined PJRT service with its tiled-GEMM
//!   executor over the Pallas `gemm_acc` tile.
//! - [`vgg`] — VGG-16 weights, glue (im2col/pool), the sequential pipeline
//!   and the real TAO-DAG whose payloads call the service.

pub mod engine;
pub mod manifest;
pub mod vgg;

pub use engine::{GemmHandle, PjrtService};
pub use manifest::Manifest;
pub use vgg::{VggWeights, build_real_dag, pipeline_infer, synthetic_image};
