//! VGG-16 on the PJRT runtime: weights, the im2col/pool glue, the
//! sequential pipeline, and the real TAO-DAG whose GEMM payloads execute
//! through the AOT-compiled Pallas artifacts.
//!
//! Two independent execution paths exist on purpose:
//! 1. **Whole-model** (`GemmHandle::vgg_infer`) — one PJRT executable for
//!    the entire forward pass (lowered from the JAX model).
//! 2. **Pipeline / TAO-DAG** — layer-by-layer GEMMs through the tiled
//!    Pallas `gemm_acc` executable, either sequentially
//!    ([`pipeline_infer`]) or as a XiTAO DAG ([`build_real_dag`]) under
//!    any scheduling policy.
//!
//! Running both on the same weights and asserting allclose validates that
//! the Rust im2col/pool/layer plumbing exactly matches the JAX model —
//! the cross-language integration test of the whole stack.

use super::engine::GemmHandle;
use crate::coordinator::dag::TaoDag;
use crate::coordinator::tao::TaoPayload;
use crate::kernels::shared_buf::SharedBuf;
use crate::platform::KernelClass;
use crate::util::Pcg32;
use crate::vgg::{LayerKind, LayerSpec, vgg16_layers};
use std::sync::Arc;

/// Weight-layer view (convs and FCs only, pools carry no weights).
fn weight_layers(input_hw: usize) -> Vec<LayerSpec> {
    vgg16_layers(input_hw)
        .into_iter()
        .filter(|l| !matches!(l.kind, LayerKind::Pool { .. }))
        .collect()
}

/// Synthetic VGG-16 weights in the Rust/JAX shared layout:
/// conv W `[c_out, c_in·9]` (column order `c·9 + ky·3 + kx`), FC W
/// `[c_out, c_in]`, biases `[c_out]`.
pub struct VggWeights {
    pub input_hw: usize,
    /// `(W, b)` per weight layer, model order.
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl VggWeights {
    /// He-style deterministic init (accuracy is irrelevant — the
    /// experiments measure scheduling; see DESIGN.md §Substitutions).
    pub fn synthetic(input_hw: usize, seed: u64) -> VggWeights {
        let mut rng = Pcg32::seeded(seed);
        let mut layers = Vec::new();
        for spec in weight_layers(input_hw) {
            let (m, k, _) = spec.gemm_dims();
            // Uniform(-s, s) has variance s²/3; s = √(6/k) gives He's 2/k.
            let scale = (6.0 / k as f64).sqrt();
            let w: Vec<f32> = (0..m * k)
                .map(|_| ((rng.gen_f64() * 2.0 - 1.0) * scale) as f32)
                .collect();
            let b = vec![0f32; m];
            layers.push((w, b));
        }
        VggWeights { input_hw, layers }
    }

    /// Flat parameter list (W, b interleaved) for `GemmHandle::vgg_load`.
    pub fn flat(&self) -> Vec<Vec<f32>> {
        self.layers.iter().flat_map(|(w, b)| [w.clone(), b.clone()]).collect()
    }

    pub fn specs(&self) -> Vec<LayerSpec> {
        weight_layers(self.input_hw)
    }
}

/// Deterministic test image in `[0, 1)`.
pub fn synthetic_image(input_hw: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..3 * input_hw * input_hw).map(|_| rng.gen_f64() as f32).collect()
}

/// 3×3 SAME im2col matching `python/compile/kernels/ref.py::im2col_3x3`:
/// `[c, h, w]` → `[c·9, h·w]`, row index `c·9 + (ky·3 + kx)`.
pub fn im2col_3x3(x: &[f32], c: usize, h: usize, w: usize) -> Vec<f32> {
    assert_eq!(x.len(), c * h * w);
    let n = h * w;
    let mut out = vec![0f32; c * 9 * n];
    for ci in 0..c {
        for ky in 0..3usize {
            for kx in 0..3usize {
                let row = ci * 9 + ky * 3 + kx;
                let dst = &mut out[row * n..(row + 1) * n];
                for y in 0..h {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue; // zero padding
                    }
                    for xx in 0..w {
                        let sx = xx as isize + kx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        dst[y * w + xx] = x[ci * n + sy as usize * w + sx as usize];
                    }
                }
            }
        }
    }
    out
}

/// 2×2 max-pool stride 2: `[c, hw, hw]` → `[c, hw/2, hw/2]`.
pub fn maxpool2(x: &[f32], c: usize, hw: usize) -> Vec<f32> {
    assert_eq!(x.len(), c * hw * hw);
    let ho = hw / 2;
    let mut out = vec![0f32; c * ho * ho];
    for ci in 0..c {
        for y in 0..ho {
            for xx in 0..ho {
                let base = ci * hw * hw + 2 * y * hw + 2 * xx;
                let m = x[base]
                    .max(x[base + 1])
                    .max(x[base + hw])
                    .max(x[base + hw + 1]);
                out[ci * ho * ho + y * ho + xx] = m;
            }
        }
    }
    out
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Sequential layer-by-layer inference through the tiled-GEMM service.
pub fn pipeline_infer(weights: &VggWeights, image: &[f32], h: &GemmHandle) -> anyhow::Result<Vec<f32>> {
    let hw0 = weights.input_hw;
    assert_eq!(image.len(), 3 * hw0 * hw0);
    let specs = weights.specs();
    let mut act = image.to_vec();
    let mut conv_idx_after_pool = [2usize, 4, 7, 10, 13]; // layer indices where a pool precedes
    conv_idx_after_pool.sort_unstable();
    let mut hw = hw0;
    let mut c = 3usize;
    for (li, spec) in specs.iter().enumerate() {
        let (w, b) = &weights.layers[li];
        match spec.kind {
            LayerKind::Conv { c_in, c_out, hw: shw } => {
                // Pool boundary: the previous block ended.
                if c_in != c {
                    unreachable!("layer table is consistent");
                }
                if shw != hw {
                    act = maxpool2(&act, c, hw);
                    hw = shw;
                }
                let cols = im2col_3x3(&act, c, hw, hw);
                let n = hw * hw;
                let mut out = h.gemm(w, &cols, c_out, c_in * 9, n)?;
                for (row, bias) in out.chunks_mut(n).zip(b) {
                    for v in row.iter_mut() {
                        *v += bias;
                    }
                }
                relu(&mut out);
                act = out;
                c = c_out;
            }
            LayerKind::Fc { c_in, c_out } => {
                if act.len() != c_in {
                    // First FC: pool then flatten.
                    act = maxpool2(&act, c, hw);
                    hw /= 2;
                    assert_eq!(act.len(), c_in, "flatten size");
                }
                let mut out = h.gemm(w, &act, c_out, c_in, 1)?;
                for (v, bias) in out.iter_mut().zip(b) {
                    *v += bias;
                }
                if li + 1 < specs.len() {
                    relu(&mut out);
                }
                act = out;
                c = c_out;
            }
            LayerKind::Pool { .. } => unreachable!("weight layers only"),
        }
    }
    Ok(act)
}

// ---------------------------------------------------------------------------
// The real TAO-DAG
// ---------------------------------------------------------------------------

struct Stage {
    spec: LayerSpec,
    /// im2col / flattened input, written by the prep TAO.
    cols: Arc<SharedBuf<f32>>,
    /// Raw (pre-ReLU) GEMM output `[c_out × n]`.
    out: Arc<SharedBuf<f32>>,
    n: usize,
    k: usize,
}

/// Build a XiTAO DAG that performs one VGG-16 inference with GEMM TAOs
/// executing through the PJRT service. Returns the DAG and the logits
/// buffer (read it after the run).
///
/// Per layer: one *prep* TAO (ReLU of the previous raw output, pool at
/// block boundaries, im2col/flatten) followed by `⌈c_out/block_len⌉` GEMM
/// TAOs, each computing a channel block, rank-sliced by the width the
/// scheduler picks. Layer barriers are dense edges, like the sim DAG.
pub fn build_real_dag(
    weights: Arc<VggWeights>,
    image: Vec<f32>,
    handle: GemmHandle,
    block_len: usize,
) -> (TaoDag, Arc<SharedBuf<f32>>) {
    let hw0 = weights.input_hw;
    assert_eq!(image.len(), 3 * hw0 * hw0);
    let specs = weights.specs();
    // Precompute stage geometry.
    let mut stages: Vec<Stage> = Vec::new();
    for spec in &specs {
        let (_, k, n) = spec.gemm_dims();
        let m = spec.out_channels();
        stages.push(Stage {
            spec: spec.clone(),
            cols: Arc::new(SharedBuf::zeroed(k * n)),
            out: Arc::new(SharedBuf::zeroed(m * n)),
            n,
            k,
        });
    }
    let stages = Arc::new(stages);
    let image = Arc::new(image);

    let mut dag = TaoDag::new();
    let mut prev_gemm_ids: Vec<usize> = Vec::new();
    for li in 0..stages.len() {
        // ---- prep TAO -----------------------------------------------------
        let prep_payload: Arc<dyn TaoPayload> = {
            let stages = stages.clone();
            let weights = weights.clone();
            let image = image.clone();
            crate::coordinator::tao::payload_fn(KernelClass::Copy, move |rank, _width| {
                if rank != 0 {
                    return; // prep is cheap; only rank 0 works
                }
                let stage = &stages[li];
                // Input activation: image for layer 0, else the previous
                // layer's raw output with ReLU applied.
                let (mut act, mut c, mut hw) = if li == 0 {
                    ((*image).clone(), 3usize, weights.input_hw)
                } else {
                    let prev = &stages[li - 1];
                    let mut a = prev.out.snapshot();
                    relu(&mut a);
                    let c = prev.spec.out_channels();
                    let hw = match prev.spec.kind {
                        LayerKind::Conv { hw, .. } => hw,
                        _ => 1,
                    };
                    (a, c, hw)
                };
                match stage.spec.kind {
                    LayerKind::Conv { c_in, hw: shw, .. } => {
                        if shw != hw {
                            act = maxpool2(&act, c, hw);
                            hw = shw;
                        }
                        debug_assert_eq!(c, c_in);
                        let cols = im2col_3x3(&act, c, hw, hw);
                        let dst = unsafe { stage.cols.slice_mut(0, cols.len()) };
                        dst.copy_from_slice(&cols);
                    }
                    LayerKind::Fc { c_in, .. } => {
                        if act.len() != c_in {
                            act = maxpool2(&act, c, hw);
                        }
                        debug_assert_eq!(act.len(), c_in);
                        let dst = unsafe { stage.cols.slice_mut(0, c_in) };
                        dst.copy_from_slice(&act);
                    }
                    LayerKind::Pool { .. } => unreachable!(),
                }
                c = c.max(1); // silence unused on non-debug builds
                let _ = c;
            })
        };
        // Prep uses the *layer* type id space shifted: types 0..L are GEMM
        // layers, L..2L the preps (distinct latencies).
        let prep_id = dag.add_task_payload(
            KernelClass::Copy,
            stages.len() + li,
            0.05,
            Some(prep_payload),
        );
        for &p in &prev_gemm_ids {
            dag.add_edge(p, prep_id);
        }

        // ---- GEMM TAOs ----------------------------------------------------
        let stage_m = stages[li].spec.out_channels();
        let n_taos = stage_m.div_ceil(block_len);
        let mut gemm_ids = Vec::with_capacity(n_taos);
        for bi in 0..n_taos {
            let lo = bi * block_len;
            let hi = ((bi + 1) * block_len).min(stage_m);
            let payload: Arc<dyn TaoPayload> = {
                let stages = stages.clone();
                let weights = weights.clone();
                let handle = handle.clone();
                crate::coordinator::tao::payload_fn(KernelClass::Gemm, move |rank, width| {
                    let stage = &stages[li];
                    let (w, b) = &weights.layers[li];
                    // Rank-slice the channel block.
                    let rows = hi - lo;
                    let rlo = lo + rank * rows / width;
                    let rhi = lo + (rank + 1) * rows / width;
                    if rlo >= rhi {
                        return;
                    }
                    let (k, n) = (stage.k, stage.n);
                    let cols = unsafe { stage.cols.slice_mut(0, k * n) };
                    let wslice = &w[rlo * k..rhi * k];
                    let mut out = handle
                        .gemm(wslice, cols, rhi - rlo, k, n)
                        .expect("PJRT gemm");
                    for (ri, row) in out.chunks_mut(n).enumerate() {
                        let bias = b[rlo + ri];
                        for v in row.iter_mut() {
                            *v += bias;
                        }
                    }
                    let dst = unsafe { stage.out.slice_mut(rlo * n, rhi * n) };
                    dst.copy_from_slice(&out);
                })
            };
            let (_, k, n) = stages[li].spec.gemm_dims();
            let flops = 2.0 * (hi - lo) as f64 * k as f64 * n as f64;
            let id = dag.add_task_payload(
                KernelClass::Gemm,
                li,
                flops / crate::vgg::REF_FLOPS,
                Some(payload),
            );
            dag.add_edge(prep_id, id);
            gemm_ids.push(id);
        }
        prev_gemm_ids = gemm_ids;
    }
    dag.finalize().expect("VGG real DAG is acyclic");
    let logits = stages.last().unwrap().out.clone();
    (dag, logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_matches_manual_center() {
        // 1 channel, 3×3 input, center tap (ky=kx=1) must equal the input.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let cols = im2col_3x3(&x, 1, 3, 3);
        let center = &cols[4 * 9..5 * 9];
        assert_eq!(center, &x[..]);
    }

    #[test]
    fn im2col_zero_pads_edges() {
        let x = vec![1f32; 4]; // 1×2×2
        let cols = im2col_3x3(&x, 1, 2, 2);
        // Top-left tap (ky=0,kx=0) at output (0,0) reads x[-1,-1] = 0.
        assert_eq!(cols[0], 0.0);
        // Center tap all ones.
        assert_eq!(&cols[4 * 4..5 * 4], &[1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn maxpool_known() {
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect(); // 1×4×4
        let out = maxpool2(&x, 1, 4);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn weights_shapes_match_manifest_convention() {
        let w = VggWeights::synthetic(64, 1);
        assert_eq!(w.layers.len(), 16);
        // conv1_1: [64, 27].
        assert_eq!(w.layers[0].0.len(), 64 * 27);
        assert_eq!(w.layers[0].1.len(), 64);
        // fc8: [1000, 4096].
        assert_eq!(w.layers[15].0.len(), 1000 * 4096);
        let flat = w.flat();
        assert_eq!(flat.len(), 32);
    }

    #[test]
    fn synthetic_image_deterministic() {
        assert_eq!(synthetic_image(32, 7), synthetic_image(32, 7));
        assert_ne!(synthetic_image(32, 7), synthetic_image(32, 8));
    }
}
