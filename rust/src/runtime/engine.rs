//! The GEMM execution service.
//!
//! Two interchangeable implementations sit behind one thread-confined
//! service API (workers talk to a dedicated service thread over an mpsc
//! request channel and block on a reply channel):
//!
//! - **`pjrt` feature enabled** — the real thing: the `xla` crate's PJRT
//!   CPU client loads the HLO-text artifacts produced by
//!   `python/compile/aot.py` (JAX/Pallas, build-time only) and compiles
//!   them once. The PJRT types are not `Send`/`Sync` (raw C-API handles),
//!   which is why the service thread exists at all. Requires adding the
//!   `xla` dependency to Cargo.toml — unavailable in the offline build.
//! - **default (no `pjrt`)** — a pure-Rust fallback with the same API:
//!   [`GemmHandle::gemm`] computes natively, so the tiled pipeline and the
//!   real TAO-DAG still execute end to end; whole-model VGG inference
//!   (which only exists as an XLA executable) reports an error. See
//!   DESIGN.md §Substitutions.
//!
//! The hot operation is [`GemmHandle::gemm`]: an arbitrary-shape
//! `C = A·B (+C₀)`. Under PJRT it is decomposed into fixed-shape tile
//! executions of the Pallas `gemm_acc` artifact (`c + a@b` over one tile),
//! keeping the running accumulator as an on-device literal across K steps —
//! mirroring the kernel's K-innermost VMEM-resident schedule at the host
//! level.

use super::manifest::Manifest;
use anyhow::{Context, Result, anyhow};
use std::path::Path;
use std::sync::mpsc;

/// A GEMM job: row-major `a` (m×k) times `b` (k×n), plus optional `c0`.
struct GemmJob {
    a: Vec<f32>,
    b: Vec<f32>,
    c0: Option<Vec<f32>>,
    m: usize,
    k: usize,
    n: usize,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

/// VGG whole-model inference job (parameters are cached in the service).
struct VggJob {
    image: Vec<f32>,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

enum Request {
    Gemm(GemmJob),
    /// Load VGG params into the service (once, before inference).
    VggLoad { params: Vec<Vec<f32>>, reply: mpsc::Sender<Result<()>> },
    VggInfer(VggJob),
    Shutdown,
}

/// Handle to the GEMM service; clonable and `Send` — one per TAO payload.
#[derive(Clone)]
pub struct GemmHandle {
    tx: mpsc::Sender<Request>,
}

impl GemmHandle {
    /// `C = A·B` (row-major flat buffers).
    pub fn gemm(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Result<Vec<f32>> {
        self.gemm_acc(a, b, None, m, k, n)
    }

    /// `C = C₀ + A·B`.
    pub fn gemm_acc(
        &self,
        a: &[f32],
        b: &[f32],
        c0: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<f32>> {
        assert_eq!(a.len(), m * k, "a shape");
        assert_eq!(b.len(), k * n, "b shape");
        if let Some(c) = c0 {
            assert_eq!(c.len(), m * n, "c0 shape");
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request::Gemm(GemmJob {
                a: a.to_vec(),
                b: b.to_vec(),
                c0: c0.map(|c| c.to_vec()),
                m,
                k,
                n,
                reply: rtx,
            }))
            .map_err(|_| anyhow!("GEMM service is down"))?;
        rrx.recv().map_err(|_| anyhow!("GEMM service dropped reply"))?
    }

    /// Install VGG parameters (flat, model order) for whole-model inference.
    pub fn vgg_load(&self, params: Vec<Vec<f32>>) -> Result<()> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request::VggLoad { params, reply: rtx })
            .map_err(|_| anyhow!("GEMM service is down"))?;
        rrx.recv().map_err(|_| anyhow!("GEMM service dropped reply"))?
    }

    /// Whole-model inference: image `[3·hw·hw]` → logits.
    pub fn vgg_infer(&self, image: &[f32]) -> Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request::VggInfer(VggJob { image: image.to_vec(), reply: rtx }))
            .map_err(|_| anyhow!("GEMM service is down"))?;
        rrx.recv().map_err(|_| anyhow!("GEMM service dropped reply"))?
    }
}

/// The running service; shuts down on drop.
pub struct PjrtService {
    tx: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
    manifest: Manifest,
}

/// With PJRT, the manifest is the contract — fail loudly when absent.
#[cfg(feature = "pjrt")]
fn load_manifest(dir: &Path) -> Result<Manifest> {
    Manifest::load(dir)
}

/// The native fallback computes GEMMs without artifacts, so a missing or
/// unreadable manifest degrades to an empty one (no VGG executable).
#[cfg(not(feature = "pjrt"))]
fn load_manifest(dir: &Path) -> Result<Manifest> {
    Ok(Manifest::load(dir).unwrap_or(Manifest {
        dir: dir.to_path_buf(),
        gemm_tiles: Vec::new(),
        vgg: None,
    }))
}

impl PjrtService {
    /// Start the service from an artifact directory. Under PJRT this
    /// compiles all GEMM tile executables up front (the VGG executable
    /// lazily at `vgg_load`); the native fallback starts unconditionally.
    pub fn start(artifact_dir: &Path) -> Result<PjrtService> {
        let manifest = load_manifest(artifact_dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let m2 = manifest.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("gemm-service".into())
            .spawn(move || service_main(m2, rx, ready_tx))
            .context("spawn gemm service")?;
        ready_rx.recv().map_err(|_| anyhow!("service died during init"))??;
        Ok(PjrtService { tx, join: Some(join), manifest })
    }

    pub fn handle(&self) -> GemmHandle {
        GemmHandle { tx: self.tx.clone() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Service thread
// ---------------------------------------------------------------------------

fn service_main(manifest: Manifest, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let state = match service::init_state(&manifest) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut state = state;
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Gemm(job) => {
                let result = service::tiled_gemm(&state, &job);
                let _ = job.reply.send(result);
            }
            Request::VggLoad { params, reply } => {
                let _ = reply.send(service::vgg_load(&mut state, params));
            }
            Request::VggInfer(job) => {
                let _ = job.reply.send(service::vgg_infer(&state, &job.image));
            }
        }
    }
}

/// Extract the zero-padded tile `(ti, tj)` of the row-major `src` (r×c).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))] // tile loop is PJRT-only; kept under test
fn tile_of(src: &[f32], r: usize, c: usize, ti: usize, tj: usize, b: usize) -> Vec<f32> {
    let mut out = vec![0f32; b * b];
    let r0 = ti * b;
    let c0 = tj * b;
    let rows = b.min(r.saturating_sub(r0));
    let cols = b.min(c.saturating_sub(c0));
    for i in 0..rows {
        let srow = (r0 + i) * c + c0;
        out[i * b..i * b + cols].copy_from_slice(&src[srow..srow + cols]);
    }
    out
}

#[cfg(feature = "pjrt")]
mod service {
    //! The real PJRT backend: compiled HLO executables via the `xla` crate.

    use super::{GemmJob, tile_of};
    use crate::runtime::manifest::Manifest;
    use anyhow::{Context, Result, anyhow};
    use std::collections::BTreeMap;

    pub(super) struct ServiceState {
        client: xla::PjRtClient,
        /// block size → compiled gemm_acc executable.
        tiles: BTreeMap<usize, xla::PjRtLoadedExecutable>,
        manifest: Manifest,
        vgg_exe: Option<xla::PjRtLoadedExecutable>,
        vgg_params: Option<Vec<xla::Literal>>,
    }

    pub(super) fn init_state(manifest: &Manifest) -> Result<ServiceState> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut tiles = BTreeMap::new();
        for tile in &manifest.gemm_tiles {
            let proto = xla::HloModuleProto::from_text_file(&tile.path)
                .with_context(|| format!("load {}", tile.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).with_context(|| format!("compile tile {}", tile.block))?;
            tiles.insert(tile.block, exe);
        }
        Ok(ServiceState {
            client,
            tiles,
            manifest: manifest.clone(),
            vgg_exe: None,
            vgg_params: None,
        })
    }

    /// Pick the largest tile not exceeding every padded dimension's "waste
    /// budget": the smallest dimension determines how much padding a large
    /// tile would add.
    fn pick_block(
        tiles: &BTreeMap<usize, xla::PjRtLoadedExecutable>,
        m: usize,
        k: usize,
        n: usize,
    ) -> usize {
        let smallest_dim = m.min(k).min(n);
        let mut best = *tiles.keys().next().expect("at least one tile");
        for &b in tiles.keys() {
            // Accept b if padding the smallest dim to b wastes < 2× its size,
            // i.e. b ≤ 2 × smallest_dim, preferring the largest such b.
            if b <= (2 * smallest_dim).max(best) {
                best = b;
            }
        }
        best
    }

    fn literal_2d(data: &[f32], r: usize, c: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[r as i64, c as i64])?)
    }

    /// The tiled GEMM: pads (m, k, n) to tile multiples and loops the
    /// single-tile `gemm_acc` executable, keeping the accumulator as a
    /// device literal across the K loop.
    pub(super) fn tiled_gemm(state: &ServiceState, job: &GemmJob) -> Result<Vec<f32>> {
        let (m, k, n) = (job.m, job.k, job.n);
        let b = pick_block(&state.tiles, m, k, n);
        let exe = &state.tiles[&b];
        let (tm, tk, tn) = (m.div_ceil(b), k.div_ceil(b), n.div_ceil(b));
        let mut out = vec![0f32; m * n];
        let zeros = vec![0f32; b * b];
        for ti in 0..tm {
            for tj in 0..tn {
                // Seed the accumulator with C₀'s tile (or zeros).
                let seed = match &job.c0 {
                    Some(c0) => tile_of(c0, m, n, ti, tj, b),
                    None => zeros.clone(),
                };
                let mut acc = literal_2d(&seed, b, b)?;
                for tkk in 0..tk {
                    let at = tile_of(&job.a, m, k, ti, tkk, b);
                    let bt = tile_of(&job.b, k, n, tkk, tj, b);
                    let al = literal_2d(&at, b, b)?;
                    let bl = literal_2d(&bt, b, b)?;
                    let result =
                        exe.execute::<xla::Literal>(&[al, bl, acc])?[0][0].to_literal_sync()?;
                    acc = result.to_tuple1()?;
                }
                let tile: Vec<f32> = acc.to_vec::<f32>()?;
                // Scatter the valid region back.
                let r0 = ti * b;
                let c0 = tj * b;
                let rows = b.min(m - r0);
                let cols = b.min(n - c0);
                for i in 0..rows {
                    let drow = (r0 + i) * n + c0;
                    out[drow..drow + cols].copy_from_slice(&tile[i * b..i * b + cols]);
                }
            }
        }
        Ok(out)
    }

    pub(super) fn vgg_load(state: &mut ServiceState, params: Vec<Vec<f32>>) -> Result<()> {
        let spec = state
            .manifest
            .vgg
            .clone()
            .ok_or_else(|| anyhow!("manifest has no VGG artifact"))?;
        anyhow::ensure!(
            params.len() == spec.param_shapes.len(),
            "expected {} params, got {}",
            spec.param_shapes.len(),
            params.len()
        );
        if state.vgg_exe.is_none() {
            let proto = xla::HloModuleProto::from_text_file(&spec.path)
                .with_context(|| format!("load {}", spec.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            state.vgg_exe = Some(state.client.compile(&comp).context("compile VGG model")?);
        }
        let mut lits = Vec::with_capacity(params.len());
        for (p, shape) in params.iter().zip(&spec.param_shapes) {
            let numel: usize = shape.iter().product();
            anyhow::ensure!(p.len() == numel, "param shape mismatch: {} vs {shape:?}", p.len());
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(p).reshape(&dims)?);
        }
        state.vgg_params = Some(lits);
        Ok(())
    }

    pub(super) fn vgg_infer(state: &ServiceState, image: &[f32]) -> Result<Vec<f32>> {
        let spec = state.manifest.vgg.as_ref().ok_or_else(|| anyhow!("no VGG artifact"))?;
        let exe = state.vgg_exe.as_ref().ok_or_else(|| anyhow!("vgg_load first"))?;
        let params = state.vgg_params.as_ref().ok_or_else(|| anyhow!("vgg_load first"))?;
        let hw = spec.input_hw;
        anyhow::ensure!(image.len() == 3 * hw * hw, "image must be 3×{hw}×{hw}");
        let img = xla::Literal::vec1(image).reshape(&[3, hw as i64, hw as i64])?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&img);
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
mod service {
    //! Native fallback: reference GEMM on the service thread, no artifacts
    //! required. Keeps the pipeline and TAO-DAG paths runnable (and the
    //! scheduler exercisable end to end) on hosts without XLA bindings.

    use super::GemmJob;
    use crate::runtime::manifest::Manifest;
    use anyhow::{Result, anyhow};

    pub(super) struct ServiceState {
        manifest: Manifest,
    }

    pub(super) fn init_state(manifest: &Manifest) -> Result<ServiceState> {
        Ok(ServiceState { manifest: manifest.clone() })
    }

    pub(super) fn tiled_gemm(_state: &ServiceState, job: &GemmJob) -> Result<Vec<f32>> {
        let (m, k, n) = (job.m, job.k, job.n);
        let mut out = match &job.c0 {
            Some(c0) => c0.clone(),
            None => vec![0f32; m * n],
        };
        for i in 0..m {
            let crow = &mut out[i * n..(i + 1) * n];
            for kk in 0..k {
                let aik = job.a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &job.b[kk * n..(kk + 1) * n];
                for (c, bv) in crow.iter_mut().zip(brow) {
                    *c += aik * bv;
                }
            }
        }
        Ok(out)
    }

    pub(super) fn vgg_load(state: &mut ServiceState, _params: Vec<Vec<f32>>) -> Result<()> {
        if state.manifest.vgg.is_none() {
            return Err(anyhow!("manifest has no VGG artifact"));
        }
        Err(anyhow!("whole-model VGG inference requires the `pjrt` feature (xla bindings)"))
    }

    pub(super) fn vgg_infer(state: &ServiceState, _image: &[f32]) -> Result<Vec<f32>> {
        let _ = &state.manifest;
        Err(anyhow!("whole-model VGG inference requires the `pjrt` feature (xla bindings)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn artifacts_available() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    fn reference_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let aik = a[i * k + kk];
                for j in 0..n {
                    c[i * n + j] += aik * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..len).map(|_| (rng.gen_f64() * 2.0 - 1.0) as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn tile_of_pads_with_zeros() {
        let src: Vec<f32> = (0..6).map(|v| v as f32).collect(); // 2×3
        let t = tile_of(&src, 2, 3, 0, 0, 4);
        assert_eq!(t[0..3], [0.0, 1.0, 2.0]);
        assert_eq!(t[3], 0.0); // padded col
        assert_eq!(t[4..7], [3.0, 4.0, 5.0]);
        assert_eq!(&t[8..], &[0.0; 8]); // padded rows
    }

    #[test]
    fn tile_of_offset_block() {
        let src: Vec<f32> = (0..16).map(|v| v as f32).collect(); // 4×4
        let t = tile_of(&src, 4, 4, 1, 1, 2);
        assert_eq!(t, vec![10.0, 11.0, 14.0, 15.0]);
    }

    // The service tests below run against whichever backend is compiled in:
    // the PJRT path needs `make artifacts` (and skips without it); the
    // native fallback needs nothing and validates the same contract.

    fn start_service() -> Option<PjrtService> {
        if cfg!(feature = "pjrt") && !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtService::start(Path::new("artifacts")).unwrap())
    }

    #[test]
    fn service_gemm_exact_tile() {
        let Some(svc) = start_service() else { return };
        let h = svc.handle();
        let (m, k, n) = (32, 32, 32);
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let got = h.gemm(&a, &b, m, k, n).unwrap();
        assert_close(&got, &reference_gemm(&a, &b, m, k, n), 1e-3);
    }

    #[test]
    fn service_gemm_ragged_shapes() {
        let Some(svc) = start_service() else { return };
        let h = svc.handle();
        for &(m, k, n) in &[(5usize, 7usize, 3usize), (70, 33, 100), (64, 576, 50), (1, 100, 1)] {
            let a = rand_vec(m * k, m as u64);
            let b = rand_vec(k * n, n as u64);
            let got = h.gemm(&a, &b, m, k, n).unwrap();
            assert_close(&got, &reference_gemm(&a, &b, m, k, n), 1e-2);
        }
    }

    #[test]
    fn service_gemm_acc_seeds_accumulator() {
        let Some(svc) = start_service() else { return };
        let h = svc.handle();
        let (m, k, n) = (16, 16, 16);
        let a = rand_vec(m * k, 3);
        let b = rand_vec(k * n, 4);
        let c0 = rand_vec(m * n, 5);
        let got = h.gemm_acc(&a, &b, Some(&c0), m, k, n).unwrap();
        let mut want = reference_gemm(&a, &b, m, k, n);
        for (w, c) in want.iter_mut().zip(&c0) {
            *w += c;
        }
        assert_close(&got, &want, 1e-3);
    }

    #[test]
    fn handles_are_cloneable_across_threads() {
        let Some(svc) = start_service() else { return };
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let h = svc.handle();
                std::thread::spawn(move || {
                    let a = rand_vec(8 * 8, i);
                    let b = rand_vec(8 * 8, i + 10);
                    let got = h.gemm(&a, &b, 8, 8, 8).unwrap();
                    assert_close(&got, &reference_gemm(&a, &b, 8, 8, 8), 1e-3);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn native_fallback_rejects_whole_model_inference() {
        let svc = PjrtService::start(Path::new("artifacts")).unwrap();
        let h = svc.handle();
        assert!(h.vgg_infer(&[0.0; 3]).is_err());
        assert!(h.vgg_load(vec![vec![0.0; 4]]).is_err());
    }

    // `pick_block` needs real executables to construct the map; its choice
    // logic is covered indirectly by `service_gemm_ragged_shapes`, which
    // exercises shapes that hit every tile size.
}
