//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::Json;
use std::path::{Path, PathBuf};

/// One GEMM tile executable.
#[derive(Debug, Clone)]
pub struct GemmTileSpec {
    pub block: usize,
    pub path: PathBuf,
}

/// The whole-model VGG executable.
#[derive(Debug, Clone)]
pub struct VggSpec {
    pub path: PathBuf,
    pub input_hw: usize,
    /// Flat parameter shapes, model order (W, b per layer).
    pub param_shapes: Vec<Vec<usize>>,
    pub n_logits: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub gemm_tiles: Vec<GemmTileSpec>,
    pub vgg: Option<VggSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("read manifest in {}: {e}", dir.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse manifest: {e}"))?;
        let mut gemm_tiles = Vec::new();
        let tiles = json
            .get("gemm_acc")
            .and_then(|j| j.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing gemm_acc"))?;
        for entry in tiles.values() {
            let block = entry
                .get("block")
                .and_then(|b| b.as_usize())
                .ok_or_else(|| anyhow::anyhow!("gemm_acc entry missing block"))?;
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow::anyhow!("gemm_acc entry missing file"))?;
            gemm_tiles.push(GemmTileSpec { block, path: dir.join(file) });
        }
        gemm_tiles.sort_by_key(|t| t.block);
        anyhow::ensure!(!gemm_tiles.is_empty(), "no gemm tiles in manifest");

        let vgg = match json.get("vgg") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let file = v
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow::anyhow!("vgg entry missing file"))?;
                let shapes = v
                    .get("param_shapes")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("vgg entry missing param_shapes"))?
                    .iter()
                    .map(|shape| {
                        shape
                            .as_arr()
                            .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                            .ok_or_else(|| anyhow::anyhow!("bad shape"))
                    })
                    .collect::<anyhow::Result<Vec<Vec<usize>>>>()?;
                Some(VggSpec {
                    path: dir.join(file),
                    input_hw: v
                        .get("input_hw")
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow::anyhow!("vgg missing input_hw"))?,
                    param_shapes: shapes,
                    n_logits: v.get("n_logits").and_then(|x| x.as_usize()).unwrap_or(1000),
                })
            }
        };
        Ok(Manifest { dir: dir.to_path_buf(), gemm_tiles, vgg })
    }

    /// Default artifact directory (repo-relative).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_full_manifest() {
        let dir = std::env::temp_dir().join("xitao_manifest_test1");
        write_manifest(
            &dir,
            r#"{"gemm_acc": {"32": {"file": "g32.hlo.txt", "block": 32},
                             "128": {"file": "g128.hlo.txt", "block": 128}},
                "vgg": {"file": "v.hlo.txt", "input_hw": 64,
                        "param_shapes": [[64, 27], [64]], "n_logits": 1000}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.gemm_tiles.len(), 2);
        assert_eq!(m.gemm_tiles[0].block, 32); // sorted ascending
        let vgg = m.vgg.unwrap();
        assert_eq!(vgg.input_hw, 64);
        assert_eq!(vgg.param_shapes[0], vec![64, 27]);
    }

    #[test]
    fn vgg_optional() {
        let dir = std::env::temp_dir().join("xitao_manifest_test2");
        write_manifest(&dir, r#"{"gemm_acc": {"32": {"file": "g.hlo.txt", "block": 32}}, "vgg": null}"#);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.vgg.is_none());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(!m.gemm_tiles.is_empty());
        }
    }
}
