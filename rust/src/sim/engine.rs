//! Discrete-event execution of the XiTAO coordinator on modelled platforms.
//!
//! This engine is a thin *substrate* over the shared scheduling core
//! ([`SchedCore`]): the entire task lifecycle — [`PlaceCtx`] construction
//! and policy dispatch, the §3.3 commit-and-wake-up with the criticality
//! hand-off, the leader-side PTT update, per-app attribution — is the
//! *same code objects* as in the real-thread engine
//! (`coordinator::worker`). What this file owns is only the
//! discrete-event machinery: **virtual time** advancement against the
//! [`Platform`] performance model, the analytic rating of running TAOs,
//! and the modelled timer jitter fed to the PTT. That is what makes the
//! paper's experiments reproducible on this single-core build host:
//! heterogeneity, cache/bandwidth contention and interference episodes
//! are modelled, while every scheduling decision is made by the shared
//! core, driven only by what the PTT observed (see DESIGN.md
//! §Substitutions).
//!
//! [`PlaceCtx`]: crate::coordinator::scheduler::PlaceCtx
//!
//! ## Execution model
//!
//! Virtual cores mirror the worker loop of `coordinator::worker`:
//! AQ first, then own WSQ (placement decision), then a random steal. A TAO
//! placed on a width-w partition starts when all w member cores have
//! reached it at their AQ heads (members that arrive early wait — the
//! convoy behaviour of resource aggregation the paper relies on to prevent
//! interference). While running, a TAO progresses at the piecewise-constant
//! rate given by [`Platform::rate`]; every start, finish, or episode
//! boundary re-rates all running TAOs.
//!
//! Deadlock-freedom: placements insert into all member AQs atomically, so
//! any two TAOs appear in the same relative order in every AQ that holds
//! both; FIFO fetch therefore cannot produce a circular wait.
//!
//! ## Multi-application admission
//!
//! [`run_stream_sim`] executes a *stream* of applications: one combined
//! DAG whose per-app root tasks are admitted at their arrival times.
//! Arrivals are ordinary simulation events — `advance` treats the next
//! arrival like an episode boundary (re-rating running TAOs there), and
//! when every admitted task has drained before the next arrival, virtual
//! time jumps directly to it. [`run_dag_sim`] is the degenerate stream
//! (one app, arrival 0), so the single-DAG path and the stream path are
//! the same code — the parity the multi-app tests pin bit-for-bit.
//!
//! ## Fault realization
//!
//! Fail-slow episodes need no machinery here: they flow through the
//! platform's composed `speed_factor` like interference does, and the PTT
//! observes the slowdown. Fail-stop is realised as discrete events: every
//! fault boundary is a simulation event (via `next_boundary_after`), and
//! at each one the engine applies *transitions* — a newly dead core aborts
//! whatever instance it was part of (never committed, so the task
//! re-enters placement exactly once), hands its queued work to live cores
//! through an orphan buffer, and is masked out of acquisition and
//! placement (the shared core's dead mask) until its recovery boundary.
//! All of it is gated on `EpisodeSchedule::has_faults`, so fault-free runs
//! make bit-for-bit the same rng draws as before. A wedged run returns a
//! structured [`SchedError`] instead of panicking.

use crate::coordinator::core::{
    AdmissionSource, CommitInfo, SchedCore, ServingApp, ServingOpts, ServingRun, ServingSource,
};
use crate::coordinator::dag::{TaoDag, TaskId};
use crate::coordinator::metrics::{RunResult, TraceRecord, jain_fairness_total};
use crate::coordinator::ptt::Ptt;
use crate::coordinator::scheduler::{Policy, QosClass};
use crate::error::SchedError;
use crate::platform::{Partition, Platform, RunningTask};
use crate::util::Pcg32;
use std::cell::Cell;
use std::collections::VecDeque;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOpts {
    /// Seed for root distribution and steal-victim selection.
    pub seed: u64,
    /// If set, sample the PTT entry `(type_id, core, width)` after every
    /// simulation event — reproduces the PTT-value trace of Fig 8(a).
    pub ptt_probe: Option<(usize, usize, usize)>,
    /// If set, snapshot the full per-core PTT state (type-0 width-1
    /// long-run values + change-detector flags) once virtual time crosses
    /// each multiple of the given interval — the §5.3 interference-response
    /// time series (`bench-interference`). Sampling only *reads* the PTT
    /// (no rng draws, no scheduling effect), so it cannot perturb the
    /// run's bit-for-bit determinism.
    pub probe_interval: Option<f64>,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts { seed: 0x51b, ptt_probe: None, probe_interval: None }
    }
}

/// One interval snapshot of the PTT's per-core state (see
/// [`SimOpts::probe_interval`]).
#[derive(Debug, Clone)]
pub struct PttIntervalSample {
    /// Virtual time of the event that crossed the interval boundary.
    pub t: f64,
    /// Long-run width-1 estimate of PTT type 0 for every core.
    pub w1: Vec<f64>,
    /// Change-detector flag of every core ([`Ptt::core_flags`]).
    pub flags: Vec<bool>,
}

/// Result of a simulated run: the usual [`RunResult`] plus probe samples.
#[derive(Debug, Clone)]
pub struct SimRun {
    pub result: RunResult,
    /// `(virtual time, PTT value)` samples if a probe was configured.
    pub ptt_samples: Vec<(f64, f64)>,
    /// Interval snapshots if [`SimOpts::probe_interval`] was configured.
    pub interval_samples: Vec<PttIntervalSample>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum CoreState {
    Idle,
    /// Waiting at the AQ head for the rest of the partition (inst index).
    Arrived(usize),
    /// Executing (inst index).
    Running(usize),
}

#[derive(Debug)]
struct Inst {
    task: TaskId,
    partition: Partition,
    critical: bool,
    arrived: usize,
    started: bool,
    t_start: f64,
    remaining_work: f64,
    rate: f64,
    /// Slot is inert: the instance committed, or a fail-stop aborted it
    /// (its task re-entered placement under a fresh instance).
    gone: bool,
}

struct Sim<'a> {
    dag: &'a TaoDag,
    plat: &'a Platform,
    /// The shared task-lifecycle core (placement, commit-and-wake-up,
    /// criticality, per-app attribution) — identical code to the real
    /// engine's; this struct keeps only the DES substrate around it.
    core: SchedCore<'a>,
    t: f64,
    cores: Vec<CoreState>,
    wsqs: Vec<VecDeque<TaskId>>,
    aqs: Vec<VecDeque<usize>>,
    insts: Vec<Inst>,
    /// Running-instance list in start order, with `TOMB` holes left by
    /// `complete` (O(1) removal via `running_pos`); compacted once half
    /// the slots are dead. The *live* iteration order is identical to the
    /// old `retain`-based list: rng draws at completion time depend on
    /// that order, so bit-for-bit determinism forbids a plain swap-remove
    /// (it would reorder simultaneous completions).
    running: Vec<usize>,
    /// `inst idx → position in running` (`TOMB` when not running).
    running_pos: Vec<usize>,
    /// Number of live (non-tombstone) entries in `running`.
    running_live: usize,
    records: Vec<TraceRecord>,
    rng: Pcg32,
    probe: Option<(usize, usize, usize)>,
    samples: Vec<(f64, f64)>,
    /// Interval-snapshot state: `(interval, next boundary to cross)`.
    interval_probe: Option<(f64, f64)>,
    interval_samples: Vec<PttIntervalSample>,
    /// Reusable rate-snapshot buffer (avoids per-event allocation).
    snapshot_buf: Vec<RunningTask>,
    /// Reusable completion buffer.
    done_buf: Vec<usize>,
    /// Reusable `acquire_fixpoint` scan-order buffer.
    order_buf: Vec<usize>,
    /// Fault substrate, only exercised when the schedule carries fault
    /// episodes ([`crate::platform::EpisodeSchedule::has_faults`]) — the
    /// gate that keeps fault-free runs bit-for-bit identical to before.
    faults: bool,
    /// Realised fail-stop state per core (tracks episode boundaries).
    dead_mask: Vec<bool>,
    /// Tasks reclaimed from dead cores (and admissions that found no live
    /// lane), awaiting re-placement on live cores.
    orphans: VecDeque<TaskId>,
}

/// Tombstone marker in `running` / `running_pos`.
const TOMB: usize = usize::MAX;

impl<'a> Sim<'a> {
    fn n(&self) -> usize {
        self.plat.topo.n_cores()
    }

    fn sample_probe(&mut self) {
        if let Some((ty, c, w)) = self.probe {
            self.samples.push((self.t, self.core.ptt().read(ty, c, w)));
        }
        if let Some((interval, next)) = self.interval_probe {
            // Snapshot once per crossed boundary (catching up over long
            // event gaps with one sample per boundary keeps the series
            // aligned with wall-style periodic sampling).
            let mut next = next;
            while self.t >= next {
                let ptt = self.core.ptt();
                self.interval_samples.push(PttIntervalSample {
                    t: self.t,
                    w1: (0..self.plat.topo.n_cores()).map(|c| ptt.read(0, c, 1)).collect(),
                    flags: ptt.core_flags(),
                });
                next += interval;
            }
            self.interval_probe = Some((interval, next));
        }
    }

    /// Place `task` from the perspective of `core`: the decision (PlaceCtx
    /// + policy dispatch) is the shared core's; this substrate only
    /// materialises the instance and inserts it into every member AQ
    /// (atomic w.r.t. other placements — we're single-threaded here, so
    /// trivially so).
    fn place(&mut self, core: usize, task: TaskId) {
        let placed = self.core.place(core, task, self.t);
        let node = &self.dag.nodes[task];
        let idx = self.insts.len();
        self.insts.push(Inst {
            task,
            partition: placed.partition,
            critical: placed.critical,
            arrived: 0,
            started: false,
            t_start: 0.0,
            remaining_work: node.class.traits().base_work * node.work_scale,
            rate: 0.0,
            gone: false,
        });
        self.running_pos.push(TOMB); // parallel to insts; set in start_tao
        for c in placed.partition.cores() {
            self.aqs[c].push_back(idx);
        }
    }

    /// Idle cores acquire work until nothing changes.
    ///
    /// The scan order is re-shuffled every pass: on real hardware all idle
    /// cores race for WSQ entries and the winner is effectively random, so a
    /// fixed order would systematically hand work to low-numbered cores and
    /// (on the TX2 model) silently gift the fast Denver cluster to the
    /// homogeneous baseline.
    fn acquire_fixpoint(&mut self) {
        // Reused buffer, reset to the identity each call: the shuffle must
        // see exactly the input the old allocating version saw (bit-for-bit
        // rng parity) — only the per-call allocation is gone.
        let mut order = std::mem::take(&mut self.order_buf);
        order.clear();
        order.extend(0..self.n());
        loop {
            let mut progress = false;
            self.rng.shuffle(&mut order);
            for oi in 0..order.len() {
                let core = order[oi];
                if self.dead_mask[core] || self.cores[core] != CoreState::Idle {
                    continue;
                }
                // 1. AQ head — arrive at the next committed TAO.
                if let Some(&idx) = self.aqs[core].front() {
                    self.aqs[core].pop_front();
                    self.insts[idx].arrived += 1;
                    self.cores[core] = CoreState::Arrived(idx);
                    if self.insts[idx].arrived == self.insts[idx].partition.width {
                        self.start_tao(idx);
                    }
                    progress = true;
                    continue;
                }
                // 2. Own WSQ (LIFO pop like the real engine).
                if let Some(task) = self.wsqs[core].pop_back() {
                    self.place(core, task);
                    progress = true;
                    continue;
                }
                // 3. Random steal (FIFO from the victim) — reservoir-pick a
                // non-empty victim without allocating.
                let mut victim = None;
                let mut seen = 0u32;
                for v in 0..self.n() {
                    if v != core && !self.wsqs[v].is_empty() {
                        seen += 1;
                        if self.rng.gen_range(seen) == 0 {
                            victim = Some(v);
                        }
                    }
                }
                if let Some(v) = victim {
                    let task = self.wsqs[v].pop_front().unwrap();
                    self.place(core, task);
                    progress = true;
                }
            }
            if !progress {
                break;
            }
        }
        self.order_buf = order;
    }

    fn start_tao(&mut self, idx: usize) {
        let inst = &mut self.insts[idx];
        inst.started = true;
        inst.t_start = self.t;
        for c in inst.partition.cores() {
            self.cores[c] = CoreState::Running(idx);
        }
        self.running_pos[idx] = self.running.len();
        self.running.push(idx);
        self.running_live += 1;
    }

    /// Recompute rates of all running TAOs against current contention.
    fn rerate(&mut self) {
        self.snapshot_buf.clear();
        let (dag, insts) = (self.dag, &self.insts);
        self.snapshot_buf.extend(
            self.running.iter().copied().filter(|&i| i != TOMB).map(|i| RunningTask {
                class: dag.nodes[insts[i].task].class,
                partition: insts[i].partition,
            }),
        );
        for &i in &self.running {
            if i == TOMB {
                continue;
            }
            let class = self.dag.nodes[self.insts[i].task].class;
            let r = self.plat.rate(class, self.insts[i].partition, &self.snapshot_buf, self.t);
            assert!(r > 0.0, "rate must be positive (class {class:?})");
            self.insts[i].rate = r;
        }
    }

    /// Advance virtual time to the next completion, episode boundary, or
    /// application arrival (arrivals re-rate running TAOs like episode
    /// boundaries do — admission changes nothing mid-flight, but the
    /// admitted roots must be placed at exactly their arrival time).
    ///
    /// Returns [`SchedError::Deadlock`] when called with nothing running:
    /// the drivers only reach this after establishing that no arrival (or
    /// recovery boundary) can unblock the run, so it *is* a wedge — but a
    /// reportable one, not a process abort.
    fn advance(&mut self, next_arrival: Option<f64>, phase: &'static str) -> Result<(), SchedError> {
        if self.running_live == 0 {
            return Err(SchedError::Deadlock {
                completed: self.core.completed(),
                total: self.dag.len(),
                t: self.t,
                phase,
            });
        }
        let dt_complete = self
            .running
            .iter()
            .filter(|&&i| i != TOMB)
            .map(|&i| self.insts[i].remaining_work / self.insts[i].rate)
            .fold(f64::INFINITY, f64::min);
        let mut dt = dt_complete;
        if let Some(b) = self.plat.episodes.next_boundary_after(self.t) {
            if b - self.t < dt {
                dt = b - self.t;
            }
        }
        if let Some(a) = next_arrival {
            debug_assert!(a > self.t, "arrivals at or before now are admitted eagerly");
            if a - self.t < dt {
                dt = a - self.t;
            }
        }
        self.t += dt;
        for &i in &self.running {
            if i == TOMB {
                continue;
            }
            let inst = &mut self.insts[i];
            inst.remaining_work -= inst.rate * dt;
        }
        // Complete everything that reached zero (tolerance for fp drift).
        let mut done = std::mem::take(&mut self.done_buf);
        done.clear();
        done.extend(
            self.running
                .iter()
                .copied()
                .filter(|&i| i != TOMB && self.insts[i].remaining_work <= 1e-12),
        );
        for &idx in &done {
            self.complete(idx);
        }
        self.done_buf = done;
        Ok(())
    }

    /// O(1) removal from `running`: tombstone the slot found through the
    /// position map; survivors keep their relative order (see the field
    /// docs — determinism depends on it), and compaction amortises the
    /// holes away.
    fn unrun(&mut self, idx: usize) {
        let pos = self.running_pos[idx];
        debug_assert_eq!(self.running[pos], idx);
        self.running[pos] = TOMB;
        self.running_pos[idx] = TOMB;
        self.running_live -= 1;
        if self.running.len() >= 64 && self.running_live * 2 <= self.running.len() {
            self.running.retain(|&i| i != TOMB);
            for (pos, &i) in self.running.iter().enumerate() {
                self.running_pos[i] = pos;
            }
        }
    }

    fn complete(&mut self, idx: usize) {
        self.unrun(idx);
        let (task, partition, critical, t_start) = {
            let inst = &self.insts[idx];
            (inst.task, inst.partition, inst.critical, inst.t_start)
        };
        let exec = self.t - t_start;
        if self.core.uses_ptt() {
            // Real timers jitter by a few percent (system activity, timer
            // resolution). Modelling it matters: without noise, PTT values
            // of identical partitions stay exactly tied and the argmin
            // degenerates to one partition instead of wandering among
            // near-equals like the real scheduler. The rng draw is gated
            // on `uses_ptt` so the draw order matches the historical
            // engine bit for bit.
            let noise = 1.0 + 0.05 * (self.rng.gen_f64() * 2.0 - 1.0);
            self.core.record_leader_share(task, partition, exec * noise);
        }
        // Commit-and-wake-up is the shared core's; this substrate only
        // decides *where* released children go — onto the leader's WSQ,
        // the single-threaded stand-in for "the committing core's deque".
        let info = CommitInfo {
            task,
            partition,
            critical,
            t_start,
            t_end: self.t,
            exec,
            now: self.t,
        };
        let (core, wsqs) = (&self.core, &mut self.wsqs);
        // A duplicate commit cannot happen here by construction (instances
        // abort *before* their commit under fail-stop); if it ever does,
        // the shared core's latch absorbs it and counts it — no abort
        // path, and the fault tests assert the counter stays zero.
        if let Some(out) = core.commit(&info, |child| wsqs[partition.leader].push_back(child)) {
            self.records.push(out.record);
        }
        self.insts[idx].gone = true;
        for c in partition.cores() {
            debug_assert_eq!(self.cores[c], CoreState::Running(idx));
            self.cores[c] = CoreState::Idle;
        }
        self.sample_probe();
    }

    /// Realise fail-stop transitions at the current virtual time: newly
    /// dead cores abort their in-flight instances, hand their queued work
    /// to live cores, and are masked out of placement and acquisition
    /// until recovery. No-op (and no extra rng draws) on fault-free
    /// schedules.
    fn apply_fault_transitions(&mut self) -> Result<(), SchedError> {
        if !self.faults {
            return Ok(());
        }
        for c in 0..self.n() {
            let dead = self.plat.episodes.fail_stopped(c, self.t);
            if dead == self.dead_mask[c] {
                continue;
            }
            self.dead_mask[c] = dead;
            self.core.set_core_dead(c, dead);
            if !dead {
                continue; // recovered: re-enters acquisition next fixpoint
            }
            // Kill core `c`: abort whatever it was part of, orphan its
            // queued work. Its committed history (records, PTT rows) stays
            // — only uncommitted state is reclaimed.
            while let Some(idx) = self.aqs[c].pop_front() {
                self.abort_inst(idx);
            }
            if let CoreState::Arrived(idx) | CoreState::Running(idx) = self.cores[c] {
                self.abort_inst(idx);
            }
            self.cores[c] = CoreState::Idle;
            while let Some(task) = self.wsqs[c].pop_front() {
                self.orphans.push_back(task);
            }
        }
        self.flush_orphans()
    }

    /// Abort a placed-but-uncommitted instance: its progress is lost,
    /// every member core returns to idle, and the task re-enters placement
    /// through the orphan buffer. Exactly-once holds because the commit
    /// only ever happens from whichever instance *finishes* — this one no
    /// longer can.
    fn abort_inst(&mut self, idx: usize) {
        if self.insts[idx].gone {
            return;
        }
        self.insts[idx].gone = true;
        let partition = self.insts[idx].partition;
        for m in partition.cores() {
            if matches!(self.cores[m], CoreState::Arrived(i) | CoreState::Running(i) if i == idx) {
                self.cores[m] = CoreState::Idle;
            }
            self.aqs[m].retain(|&e| e != idx);
        }
        if self.insts[idx].started {
            self.unrun(idx);
        }
        self.orphans.push_back(self.insts[idx].task);
    }

    /// Re-admit orphaned tasks onto live cores (round-robin over the live
    /// set). With every core dead they stay parked for the next recovery
    /// boundary; if none is scheduled the machine is gone for good.
    fn flush_orphans(&mut self) -> Result<(), SchedError> {
        if self.orphans.is_empty() {
            return Ok(());
        }
        let live: Vec<usize> = (0..self.n()).filter(|&c| !self.dead_mask[c]).collect();
        if live.is_empty() {
            if self.plat.episodes.next_boundary_after(self.t).is_none() {
                return Err(SchedError::AllCoresDead { t: self.t });
            }
            return Ok(()); // a recovery is scheduled — hold until then
        }
        let mut i = 0;
        while let Some(task) = self.orphans.pop_front() {
            self.wsqs[live[i % live.len()]].push_back(task);
            i += 1;
        }
        Ok(())
    }
}

/// First live lane at or after `lane` (wrapping), or `None` when every
/// core is fail-stopped. Identity on a fault-free run (`dead` all false).
fn live_lane(dead: &[bool], lane: usize) -> Option<usize> {
    let n = dead.len();
    (0..n).map(|k| (lane + k) % n).find(|&c| !dead[c])
}

/// Simulate `dag` under `policy` on `plat`, returning the trace in virtual
/// time. Pass a warm `ptt` to chain runs (otherwise a fresh table is used).
///
/// This is the degenerate workload stream: one application whose roots are
/// admitted at `t = 0` (see [`run_stream_sim`]).
pub fn run_dag_sim(
    dag: &TaoDag,
    plat: &Platform,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &SimOpts,
) -> Result<SimRun, SchedError> {
    run_stream_sim(dag, &[], &[(0.0, dag.roots())], plat, policy, ptt, opts)
}

/// Simulate a multi-application workload stream in virtual time.
///
/// `dag` is the combined DAG over all applications (independent components,
/// typically built by [`crate::workload::WorkloadStream::build`]);
/// `app_of[task]` maps each task to its application (an empty slice tags
/// everything app 0); `admissions` lists `(arrival, roots)` pairs sorted by
/// arrival — each application's root tasks enter the work-stealing queues
/// (round-robin, like §3.3's default root distribution) exactly at its
/// arrival time. Tasks of not-yet-arrived apps are invisible to the
/// scheduler: criticality, the PTT and all queues only ever see admitted
/// work, so inter-app interference emerges solely from contention —
/// exactly the situation the paper's PTT claims to detect.
pub fn run_stream_sim(
    dag: &TaoDag,
    app_of: &[usize],
    admissions: &[(f64, Vec<TaskId>)],
    plat: &Platform,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &SimOpts,
) -> Result<SimRun, SchedError> {
    let source = AdmissionSource::new(dag, app_of, admissions);
    let fresh;
    let ptt = match ptt {
        Some(p) => p,
        None => {
            fresh = Ptt::new(dag.n_types(), &plat.topo);
            &fresh
        }
    };
    let n = plat.topo.n_cores();
    let mut sim = Sim {
        dag,
        plat,
        core: SchedCore::new(dag, app_of, &plat.topo, policy, ptt),
        t: 0.0,
        cores: vec![CoreState::Idle; n],
        wsqs: (0..n).map(|_| VecDeque::new()).collect(),
        aqs: (0..n).map(|_| VecDeque::new()).collect(),
        insts: Vec::with_capacity(dag.len()),
        running: Vec::new(),
        running_pos: Vec::with_capacity(dag.len()),
        running_live: 0,
        records: Vec::with_capacity(dag.len()),
        rng: Pcg32::seeded(opts.seed),
        probe: opts.ptt_probe,
        samples: Vec::new(),
        interval_probe: opts.probe_interval.map(|iv| {
            assert!(iv > 0.0, "probe interval must be positive");
            (iv, iv)
        }),
        interval_samples: Vec::new(),
        snapshot_buf: Vec::with_capacity(n),
        done_buf: Vec::with_capacity(n),
        order_buf: Vec::with_capacity(n),
        faults: plat.episodes.has_faults(),
        dead_mask: vec![false; n],
        orphans: VecDeque::new(),
    };
    while !sim.core.is_done() {
        sim.apply_fault_transitions()?;
        // Admit every application whose arrival time has been reached,
        // through the shared source (round-robin per batch; initial tasks
        // are non-critical, §3.3). Lanes on fail-stopped cores redirect to
        // the next live one.
        {
            let (wsqs, mask, orphans) = (&mut sim.wsqs, &sim.dead_mask, &mut sim.orphans);
            source.admit_due(sim.t, n, |lane, root| match live_lane(mask, lane) {
                Some(lane) => wsqs[lane].push_back(root),
                None => orphans.push_back(root),
            });
        }
        sim.acquire_fixpoint();
        if sim.core.is_done() {
            break;
        }
        if sim.running_live == 0 {
            // Everything admitted has drained (or is parked behind a
            // fail-stop); jump to whatever comes next — an arrival, or,
            // under a fault schedule, the next episode boundary (a
            // recovery may be what unblocks the parked orphans).
            let boundary =
                if sim.faults { plat.episodes.next_boundary_after(sim.t) } else { None };
            let next = match (source.next_arrival(), boundary) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            match next {
                Some(t) => {
                    sim.t = t;
                    continue;
                }
                None => {
                    return Err(SchedError::Deadlock {
                        completed: sim.core.completed(),
                        total: dag.len(),
                        t: sim.t,
                        phase: "stream",
                    });
                }
            }
        }
        sim.rerate();
        sim.advance(source.next_arrival(), "stream")?;
    }
    let mut records = sim.records;
    records.sort_by(|a, b| a.t_start.partial_cmp(&b.t_start).unwrap());
    Ok(SimRun {
        result: RunResult {
            policy: policy.name().to_string(),
            platform: plat.topo.name.clone(),
            makespan: sim.t,
            records,
            bound: None,
        },
        ptt_samples: sim.samples,
        interval_samples: sim.interval_samples,
    })
}

/// Simulate a serving-mode workload in virtual time: the open-loop offer
/// schedule in `apps` goes through [`ServingSource`] backpressure — the
/// per-lane reading is the lane's work-stealing-queue backlog (the sim's
/// stand-in for the real engine's admission-inbox depth), pressured offers
/// are delayed (batch) or shed (best-effort, tasks cancelled so the run
/// terminates), and the fairness feedback fires on virtual-time period
/// boundaries. At `serving.drain_after` the source enters drain mode and
/// the backlog quiesces.
///
/// Deterministic for a fixed `opts.seed`: admission, backpressure and the
/// feedback loop are all driven by virtual time and draw no randomness,
/// so two identical invocations produce bit-identical [`ServingRun`]s —
/// the soak tests pin this.
#[allow(clippy::too_many_arguments)]
pub fn run_serving_sim(
    dag: &TaoDag,
    app_of: &[usize],
    apps: Vec<ServingApp>,
    app_qos: Vec<QosClass>,
    plat: &Platform,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &SimOpts,
    serving: &ServingOpts,
) -> Result<ServingRun, SchedError> {
    // (arrival, n_tasks) per app id for the fairness sampler (∞ arrival =
    // not part of the serving schedule, never sampled).
    let n_apps = apps.iter().map(|a| a.app_id + 1).max().unwrap_or(1);
    let mut app_meta = vec![(f64::INFINITY, 1usize); n_apps];
    for a in &apps {
        app_meta[a.app_id] = (a.arrival, a.n_tasks.max(1));
    }
    let mut source = ServingSource::new(apps, serving.max_lane_depth, serving.delay_step);
    let mut shed = vec![false; n_apps];
    let mut shed_apps: Vec<usize> = Vec::new();
    let mut fairness: Vec<(f64, f64)> = Vec::new();
    let mut last_feedback = 0.0f64;
    let mut lane_high_water = 0usize;
    let mut draining = false;
    let fresh;
    let ptt = match ptt {
        Some(p) => p,
        None => {
            fresh = Ptt::new(dag.n_types(), &plat.topo);
            &fresh
        }
    };
    let n = plat.topo.n_cores();
    let mut sim = Sim {
        dag,
        plat,
        core: SchedCore::new(dag, app_of, &plat.topo, policy, ptt).with_app_qos(app_qos),
        t: 0.0,
        cores: vec![CoreState::Idle; n],
        wsqs: (0..n).map(|_| VecDeque::new()).collect(),
        aqs: (0..n).map(|_| VecDeque::new()).collect(),
        insts: Vec::with_capacity(dag.len()),
        running: Vec::new(),
        running_pos: Vec::with_capacity(dag.len()),
        running_live: 0,
        records: Vec::with_capacity(dag.len()),
        rng: Pcg32::seeded(opts.seed),
        // PTT probes are stream-run machinery; ServingRun has no sample
        // channel, so don't pay for sampling that would be discarded.
        probe: None,
        samples: Vec::new(),
        interval_probe: None,
        interval_samples: Vec::new(),
        snapshot_buf: Vec::with_capacity(n),
        done_buf: Vec::with_capacity(n),
        order_buf: Vec::with_capacity(n),
        faults: plat.episodes.has_faults(),
        dead_mask: vec![false; n],
        orphans: VecDeque::new(),
    };
    while !sim.core.is_done() {
        sim.apply_fault_transitions()?;
        if !draining && sim.t >= serving.drain_after {
            source.begin_drain();
            draining = true;
        }
        // Offer everything due, under backpressure. The depth snapshot
        // plus the `extra` cells give each offer in the batch an exact
        // reading that includes the roots admitted just before it. Lanes
        // on fail-stopped cores redirect to the next live one — both the
        // reading and the push, so fewer live cores means deeper lanes and
        // the QoS backpressure sheds best-effort work first (graceful
        // degradation instead of queueing into the void).
        {
            let (wsqs, core) = (&mut sim.wsqs, &sim.core);
            let (mask, orphans) = (&sim.dead_mask, &mut sim.orphans);
            let depths: Vec<usize> = wsqs.iter().map(VecDeque::len).collect();
            let extra: Vec<Cell<usize>> = (0..n).map(|_| Cell::new(0)).collect();
            source.admit_due(
                sim.t,
                n,
                |lane| match live_lane(mask, lane) {
                    Some(lane) => depths[lane] + extra[lane].get(),
                    None => usize::MAX, // machine fully dead: saturated
                },
                |lane, root| match live_lane(mask, lane) {
                    Some(lane) => {
                        wsqs[lane].push_back(root);
                        extra[lane].set(extra[lane].get() + 1);
                    }
                    None => orphans.push_back(root),
                },
                |app| {
                    shed[app.app_id] = true;
                    shed_apps.push(app.app_id);
                    // Shed roots were never pushed — the whole subgraph is
                    // unreachable; account it done so the run terminates.
                    core.cancel_tasks(app.n_tasks);
                },
            );
            for lane in 0..n {
                lane_high_water = lane_high_water.max(depths[lane] + extra[lane].get());
            }
        }
        // Fairness feedback, gated on virtual-time period boundaries (no
        // rng, no new events — a pure read of the core's counters).
        if sim.t - last_feedback >= serving.fairness_period {
            last_feedback = sim.t;
            let xs: Vec<f64> = app_meta
                .iter()
                .enumerate()
                .filter(|&(a, &(arrival, _))| arrival <= sim.t && !shed[a])
                .map(|(a, &(_, nt))| sim.core.app_done(a) as f64 / nt as f64)
                .collect();
            if xs.len() >= 2 {
                let jain = jain_fairness_total(&xs);
                policy.on_fairness(jain, &sim.core.monopolists(serving.min_streak));
                fairness.push((sim.t, jain));
            }
        }
        sim.acquire_fixpoint();
        if sim.core.is_done() {
            break;
        }
        if sim.running_live == 0 {
            // Everything admitted has drained (or is parked behind a
            // fail-stop); jump to the next offer or, under a fault
            // schedule, the next episode boundary.
            let boundary =
                if sim.faults { plat.episodes.next_boundary_after(sim.t) } else { None };
            let next = match (source.next_offer(), boundary) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            match next {
                Some(t) => {
                    sim.t = t;
                    continue;
                }
                None => {
                    return Err(SchedError::Deadlock {
                        completed: sim.core.completed(),
                        total: dag.len(),
                        t: sim.t,
                        phase: "serving",
                    });
                }
            }
        }
        sim.rerate();
        sim.advance(source.next_offer(), "serving")?;
    }
    let mut records = sim.records;
    records.sort_by(|a, b| a.t_start.partial_cmp(&b.t_start).unwrap());
    Ok(ServingRun {
        result: RunResult {
            policy: policy.name().to_string(),
            platform: plat.topo.name.clone(),
            makespan: sim.t,
            records,
            bound: None,
        },
        counters: source.counters(),
        shed_apps,
        lane_high_water,
        wsq_retired: 0,
        fairness,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{HomogeneousWs, PerformanceBased};
    use crate::dag_gen::fixtures::{chain_dag, independent_dag, paper_figure1_dag};
    use crate::platform::KernelClass;

    #[test]
    fn completes_all_tasks() {
        let plat = Platform::tx2();
        let dag = independent_dag(100, KernelClass::MatMul);
        let run = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        assert_eq!(run.result.n_tasks(), 100);
        assert!(run.result.makespan > 0.0);
    }

    #[test]
    fn deterministic_under_seed() {
        let plat = Platform::tx2();
        let dag = independent_dag(60, KernelClass::Sort);
        let a = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        let b = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        assert_eq!(a.result.makespan, b.result.makespan);
        assert_eq!(a.result.records.len(), b.result.records.len());
    }

    #[test]
    fn chain_is_sequential_in_virtual_time() {
        let plat = Platform::homogeneous(4);
        let d = chain_dag(5, KernelClass::MatMul);
        let run = run_dag_sim(&d, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        let recs = &run.result.records;
        for w in recs.windows(2) {
            assert!(w[1].t_start >= w[0].t_end - 1e-12);
        }
        // Makespan ≈ 5 × single-task time.
        let single = plat.ideal_exec_time(KernelClass::MatMul, Partition { leader: 0, width: 1 });
        assert!((run.result.makespan - 5.0 * single).abs() < 1e-9);
    }

    #[test]
    fn parallel_tasks_overlap() {
        let plat = Platform::homogeneous(4);
        let dag = independent_dag(4, KernelClass::MatMul);
        let run = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        // Four independent width-1 tasks on four cores: makespan ≈ one task.
        let single = plat.ideal_exec_time(KernelClass::MatMul, Partition { leader: 0, width: 1 });
        assert!(run.result.makespan < 1.5 * single, "{}", run.result.makespan);
    }

    #[test]
    fn figure1_dag_critical_tagging() {
        let plat = Platform::tx2();
        let (dag, _) = paper_figure1_dag();
        let run = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        let crit_tasks: Vec<usize> =
            run.result.records.iter().filter(|r| r.critical).map(|r| r.task).collect();
        // C (id 2), G (4), D (5), F (6) are woken over critical edges;
        // roots A, B are non-critical by definition; E is not.
        assert!(crit_tasks.contains(&2));
        assert!(crit_tasks.contains(&4));
        assert!(crit_tasks.contains(&5));
        assert!(crit_tasks.contains(&6));
        assert!(!crit_tasks.contains(&0));
        assert!(!crit_tasks.contains(&1));
        assert!(!crit_tasks.contains(&3));
    }

    #[test]
    fn ptt_learns_denver_faster() {
        let plat = Platform::tx2();
        let dag = independent_dag(300, KernelClass::MatMul);
        let ptt = Ptt::new(1, &plat.topo);
        run_dag_sim(&dag, &plat, &PerformanceBased, Some(&ptt), &Default::default()).unwrap();
        let denver = ptt.read(0, 0, 1);
        let a57 = ptt.read(0, 2, 1);
        assert!(denver > 0.0 && a57 > 0.0, "both trained");
        assert!(denver < a57, "PTT must discover the Denver cores are faster");
    }

    #[test]
    fn performance_policy_beats_homogeneous_on_hetero_low_parallelism() {
        // The paper's headline: at low parallelism the PTT scheduler routes
        // critical work to fast cores and picks useful widths.
        let plat = Platform::tx2();
        let d = chain_dag(200, KernelClass::MatMul); // parallelism = 1
        let perf = run_dag_sim(&d, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        let homo = run_dag_sim(&d, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        let speedup = homo.result.makespan / perf.result.makespan;
        assert!(speedup > 1.3, "expected clear win, got {speedup:.2}×");
    }

    #[test]
    fn probe_samples_are_monotone_in_time() {
        let plat = Platform::tx2();
        let dag = independent_dag(50, KernelClass::MatMul);
        let opts = SimOpts { ptt_probe: Some((0, 1, 1)), ..Default::default() };
        let run = run_dag_sim(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
        assert_eq!(run.ptt_samples.len(), 50);
        for w in run.ptt_samples.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
    }

    #[test]
    fn interval_probe_snapshots_per_core_state() {
        let plat = Platform::tx2();
        let dag = independent_dag(80, KernelClass::MatMul);
        let opts = SimOpts { probe_interval: Some(0.005), ..Default::default() };
        let run = run_dag_sim(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
        assert!(!run.interval_samples.is_empty());
        for s in &run.interval_samples {
            assert_eq!(s.w1.len(), 6);
            assert_eq!(s.flags.len(), 6);
        }
        for w in run.interval_samples.windows(2) {
            assert!(w[1].t >= w[0].t);
        }
        // Off by default: existing callers see no samples and identical
        // runs (the probe only reads).
        let plain = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        assert!(plain.interval_samples.is_empty());
        assert_eq!(plain.result.makespan.to_bits(), run.result.makespan.to_bits());
    }

    #[test]
    fn interference_inflates_exec_times_on_affected_cores() {
        use crate::platform::{Episode, EpisodeSchedule};
        let plat = Platform::homogeneous(4).with_episodes(EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.0, 1e9, 0.25, 0.0),
        ]));
        let dag = independent_dag(200, KernelClass::MatMul);
        let run = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        let on0: Vec<f64> = run
            .result
            .records
            .iter()
            .filter(|r| r.partition.leader == 0)
            .map(|r| r.exec_time())
            .collect();
        let on1: Vec<f64> = run
            .result
            .records
            .iter()
            .filter(|r| r.partition.leader == 1)
            .map(|r| r.exec_time())
            .collect();
        assert!(!on0.is_empty() && !on1.is_empty());
        let m0 = crate::util::stats::mean(&on0);
        let m1 = crate::util::stats::mean(&on1);
        assert!((m0 / m1 - 4.0).abs() < 0.5, "interfered core ~4× slower, got {}", m0 / m1);
    }

    #[test]
    fn fail_stop_mid_run_loses_no_tasks() {
        use crate::platform::{Episode, EpisodeSchedule};
        let base = Platform::homogeneous(4);
        let fault_free =
            run_dag_sim(&independent_dag(120, KernelClass::MatMul), &base, &HomogeneousWs, None, &Default::default())
                .unwrap();
        // Kill half the machine partway through, permanently.
        let t_fail = fault_free.result.makespan * 0.3;
        let plat = Platform::homogeneous(4).with_episodes(EpisodeSchedule::new(vec![
            Episode::fail_stop(vec![0], t_fail, None),
            Episode::fail_stop(vec![1], t_fail, None),
        ]));
        let dag = independent_dag(120, KernelClass::MatMul);
        let run = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        // Exactly once: every task committed, none twice.
        assert_eq!(run.result.n_tasks(), 120, "tasks lost to the fail-stop");
        let mut tasks: Vec<usize> = run.result.records.iter().map(|r| r.task).collect();
        tasks.sort_unstable();
        tasks.dedup();
        assert_eq!(tasks.len(), 120, "a task committed twice");
        // Nothing lands on a dead core after the failure instant.
        for r in &run.result.records {
            if r.t_start >= t_fail {
                assert!(
                    r.partition.cores().all(|c| c >= 2),
                    "task {} started on a dead core at t={}",
                    r.task,
                    r.t_start
                );
            }
        }
        // Losing half the cores must cost wall-clock.
        assert!(run.result.makespan > fault_free.result.makespan);
    }

    #[test]
    fn fail_stop_recovery_restores_the_core() {
        use crate::platform::{Episode, EpisodeSchedule};
        let base = Platform::homogeneous(2);
        let ff = run_dag_sim(&chain_dag(40, KernelClass::Copy), &base, &HomogeneousWs, None, &Default::default())
            .unwrap();
        let mid = ff.result.makespan * 0.5;
        // Both cores down for a window mid-run: the run must stall through
        // the outage and finish after recovery — no deadlock error.
        let plat = Platform::homogeneous(2).with_episodes(EpisodeSchedule::new(vec![
            Episode::fail_stop(vec![0, 1], mid, Some(mid * 1.5)),
        ]));
        let dag = chain_dag(40, KernelClass::Copy);
        let run = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap();
        assert_eq!(run.result.n_tasks(), 40);
        assert!(run.result.makespan >= ff.result.makespan, "outage cannot speed the run up");
    }

    #[test]
    fn all_cores_dead_without_recovery_is_an_error() {
        use crate::platform::{Episode, EpisodeSchedule};
        let plat = Platform::homogeneous(2).with_episodes(EpisodeSchedule::new(vec![
            Episode::fail_stop(vec![0, 1], 1e-6, None),
        ]));
        let dag = independent_dag(50, KernelClass::MatMul);
        let err = run_dag_sim(&dag, &plat, &HomogeneousWs, None, &Default::default()).unwrap_err();
        assert!(
            matches!(err, SchedError::AllCoresDead { .. } | SchedError::Deadlock { .. }),
            "{err}"
        );
    }

    #[test]
    fn fault_free_schedules_unchanged_by_fault_machinery() {
        // The fault substrate is gated on has_faults(): a schedule with
        // only interference episodes must reproduce the exact historical
        // virtual-time trace (rng draw-order parity).
        use crate::platform::{Episode, EpisodeSchedule};
        let plat = Platform::tx2().with_episodes(EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.01, 0.05, 0.25, 0.0),
        ]));
        let dag = independent_dag(90, KernelClass::Sort);
        let a = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        let b = run_dag_sim(&dag, &plat, &PerformanceBased, None, &Default::default()).unwrap();
        assert_eq!(a.result.makespan.to_bits(), b.result.makespan.to_bits());
    }
}
