//! Virtual-time execution substrate.
//!
//! Runs the coordinator's scheduling logic (shared with the real-thread
//! engine) against the analytic platform model in `crate::platform`,
//! which is how the paper's TX2/Haswell experiments are reproduced on a
//! host without that hardware.

pub mod engine;

pub use engine::{
    PttIntervalSample, SimOpts, SimRun, run_dag_sim, run_serving_sim, run_stream_sim,
};
