//! Seeded random TAO-DAG generation (§4.2.2).
//!
//! The generator follows the paper's three-step construction, itself
//! modelled on Topcuoglu et al.'s DAG synthesiser:
//!
//! 1. **Shape** — nodes are arranged in levels whose width is drawn around
//!    the requested average (this fixes the critical-path length and hence
//!    the *average parallelism* = tasks / critical-path length); a spine
//!    of one edge per level keeps the longest path equal to the level
//!    count, and the *edge rate* controls how many extra edges each node
//!    receives from the previous level.
//! 2. **Memory** — a per-kernel vector of data locations is maintained; a
//!    node reuses a predecessor's location when one of the same kernel is
//!    found, otherwise it claims a fresh slot. This maximises data reuse
//!    between same-kernel tasks "while guaranteeing isolated data
//!    execution when tasks run in parallel".
//! 3. **Spawn** — tasks and edges are emitted in XiTAO form
//!    ([`crate::coordinator::TaoDag`]), with real kernel payloads attached
//!    on request.
//!
//! A fixed seed recreates the identical DAG, which is how the paper
//! compares schedulers on the same workload.
//!
//! Hand-built deterministic test DAGs (independent sets, chains, payload
//! counters) live in [`fixtures`], shared by the whole test tree.

pub mod fixtures;

use crate::coordinator::dag::TaoDag;
use crate::coordinator::tao::TaoPayload;
use crate::kernels::{CopyTao, KernelSizes, MatMulTao, SortTao};
use crate::platform::KernelClass;
use crate::util::Pcg32;
use std::collections::HashMap;
use std::sync::Arc;

/// Generator parameters (the paper's §4.2.2 configuration set).
#[derive(Debug, Clone)]
pub struct DagParams {
    /// Number of tasks per kernel class ("which kernel should be most
    /// prominent in the DAG").
    pub tasks_per_kernel: Vec<(KernelClass, usize)>,
    /// Average level width — the target degree of parallelism.
    pub avg_width: f64,
    /// Average number of incoming edges per non-root task beyond the spine.
    pub edge_rate: f64,
    /// Reproducibility seed.
    pub seed: u64,
    /// Attach real kernel payloads of these sizes (`None` = sim-only DAG).
    pub payload_sizes: Option<KernelSizes>,
}

impl DagParams {
    /// Equal mixture of the paper's three kernels.
    pub fn mix(total: usize, parallelism: f64, seed: u64) -> DagParams {
        let per = total / 3;
        DagParams {
            tasks_per_kernel: vec![
                (KernelClass::MatMul, total - 2 * per),
                (KernelClass::Sort, per),
                (KernelClass::Copy, per),
            ],
            avg_width: parallelism,
            edge_rate: 1.5,
            seed,
            payload_sizes: None,
        }
    }

    /// Single-kernel DAG (Fig 6/7 sweeps).
    pub fn single(class: KernelClass, total: usize, parallelism: f64, seed: u64) -> DagParams {
        DagParams {
            tasks_per_kernel: vec![(class, total)],
            avg_width: parallelism,
            edge_rate: 1.5,
            seed,
            payload_sizes: None,
        }
    }

    pub fn total_tasks(&self) -> usize {
        self.tasks_per_kernel.iter().map(|&(_, n)| n).sum()
    }

    pub fn with_payloads(mut self, sizes: KernelSizes) -> DagParams {
        self.payload_sizes = Some(sizes);
        self
    }

    /// Rebind the reproducibility seed (workload streams derive one DAG
    /// per application from a shared parameter template).
    pub fn with_seed(mut self, seed: u64) -> DagParams {
        self.seed = seed;
        self
    }
}

/// Statistics of a generated DAG (exposed for tests and bench logs).
#[derive(Debug, Clone)]
pub struct DagStats {
    pub tasks: usize,
    pub levels: usize,
    pub edges: usize,
    pub parallelism: f64,
    /// Distinct data locations allocated per class (memory-reuse step).
    pub data_locations: HashMap<&'static str, usize>,
    /// Total bytes moved along DAG edges (producer working sets handed to
    /// consumers; drives the comm-cost terms in planners and perf model).
    pub edge_bytes: u64,
}

/// Generate a random TAO-DAG. Returns the finalized DAG and its stats.
pub fn generate(params: &DagParams) -> (TaoDag, DagStats) {
    let total = params.total_tasks();
    assert!(total > 0, "no tasks requested");
    assert!(params.avg_width >= 1.0, "avg_width must be ≥ 1");
    let mut rng = Pcg32::seeded(params.seed);

    // ---- step 1: shape ----------------------------------------------------
    // Draw level widths around avg_width until all tasks are placed. The
    // spine edge per level makes the critical path equal the level count,
    // so average parallelism ≈ avg_width by construction.
    let mut level_sizes: Vec<usize> = Vec::new();
    let mut placed = 0usize;
    while placed < total {
        let jitter = if params.avg_width > 1.0 {
            // ±50% uniform jitter, at least 1.
            let lo = (params.avg_width * 0.5).max(1.0);
            let hi = params.avg_width * 1.5;
            rng.gen_f64_range(lo, hi + 1.0).floor() as usize
        } else {
            1
        };
        let take = jitter.max(1).min(total - placed);
        level_sizes.push(take);
        placed += take;
    }

    // Node ids assigned level-major.
    let mut levels: Vec<Vec<usize>> = Vec::with_capacity(level_sizes.len());
    let mut next_id = 0usize;
    for &sz in &level_sizes {
        levels.push((0..sz).map(|i| next_id + i).collect());
        next_id += sz;
    }

    // Kernel classes per node: the requested counts, shuffled.
    let mut classes: Vec<KernelClass> = params
        .tasks_per_kernel
        .iter()
        .flat_map(|&(c, n)| std::iter::repeat(c).take(n))
        .collect();
    rng.shuffle(&mut classes);

    // Edges: spine + random fan-in from the previous level.
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for li in 1..levels.len() {
        let prev = &levels[li - 1];
        let cur = &levels[li];
        // Spine: first node links to a random node of the previous level.
        edges.push((*rng.choose(prev), cur[0]));
        for &node in cur.iter() {
            // Extra predecessors per edge_rate (Poisson-ish via repeated
            // Bernoulli draws, capped by the previous level size).
            let mut extra = 0usize;
            let mut p = params.edge_rate;
            while p > 0.0 && extra < prev.len() {
                if rng.gen_f64() < p.min(1.0) {
                    extra += 1;
                }
                p -= 1.0;
            }
            for _ in 0..extra {
                edges.push((*rng.choose(prev), node));
            }
        }
    }

    // ---- step 2: memory / data reuse --------------------------------------
    // Per class, a vector of location slots; node claims a predecessor's
    // slot of the same class when free, else a new one (paper's algorithm).
    let mut preds_of: Vec<Vec<usize>> = vec![Vec::new(); total];
    for &(a, b) in &edges {
        preds_of[b].push(a);
    }
    let mut loc_of: Vec<usize> = vec![usize::MAX; total];
    let mut next_loc: HashMap<usize, usize> = HashMap::new(); // class idx → count
    // `owner[class][loc]` = node currently owning the slot (replaced on reuse).
    let mut owner: HashMap<(usize, usize), usize> = HashMap::new();
    for node in 0..total {
        let ci = classes[node].index();
        let mut claimed = None;
        for &p in &preds_of[node] {
            if classes[p].index() == ci {
                let loc = loc_of[p];
                if owner.get(&(ci, loc)) == Some(&p) {
                    claimed = Some(loc);
                    break;
                }
            }
        }
        let loc = claimed.unwrap_or_else(|| {
            let c = next_loc.entry(ci).or_insert(0);
            let l = *c;
            *c += 1;
            l
        });
        loc_of[node] = loc;
        owner.insert((ci, loc), node);
    }

    // ---- step 3: spawn -----------------------------------------------------
    // Shared input arenas per (class, location) when payloads are requested.
    let mut dag = TaoDag::new();
    let mut arenas: HashMap<(usize, usize), ArenaEntry> = HashMap::new();
    for node in 0..total {
        let class = classes[node];
        let payload: Option<Arc<dyn TaoPayload>> = params.payload_sizes.map(|sizes| {
            let key = (class.index(), loc_of[node]);
            let arena = arenas
                .entry(key)
                .or_insert_with(|| ArenaEntry::new(class, sizes, params.seed ^ node as u64));
            arena.instantiate(class, sizes)
        });
        let id = dag.add_task_payload(class, class.index(), 1.0, payload);
        debug_assert_eq!(id, node);
    }
    for &(a, b) in &edges {
        if a != b {
            // Data item per edge: the producer hands its working set to the
            // consumer. A consumer that reuses the producer's data location
            // (memory step above) receives the full set; otherwise it reads
            // a quarter-sized result slice. Duplicate edges keep the max.
            let ws = classes[a].traits().working_set;
            let same_loc =
                classes[a].index() == classes[b].index() && loc_of[a] == loc_of[b];
            let bytes = if same_loc { ws } else { ws / 4 };
            dag.add_edge_bytes(a, b, bytes);
        }
    }
    dag.finalize().expect("layered construction is acyclic");

    let stats = DagStats {
        tasks: total,
        levels: levels.len(),
        edges: dag.nodes.iter().map(|n| n.succs.len()).sum(),
        parallelism: dag.parallelism(),
        data_locations: params
            .tasks_per_kernel
            .iter()
            .map(|&(c, _)| (c.name(), next_loc.get(&c.index()).copied().unwrap_or(0)))
            .collect(),
        edge_bytes: dag.total_edge_bytes(),
    };
    (dag, stats)
}

/// Shared input buffers for one (class, data-location) pair.
enum ArenaEntry {
    MatMul { a: Arc<Vec<f32>>, b: Arc<Vec<f32>> },
    Copy { src: Arc<Vec<u8>> },
    Fresh { seed: u64 },
}

impl ArenaEntry {
    fn new(class: KernelClass, sizes: KernelSizes, seed: u64) -> ArenaEntry {
        let mut rng = Pcg32::seeded(seed);
        match class {
            KernelClass::MatMul | KernelClass::Gemm => {
                let n = sizes.matmul_n;
                ArenaEntry::MatMul {
                    a: Arc::new((0..n * n).map(|_| rng.gen_f64() as f32).collect()),
                    b: Arc::new((0..n * n).map(|_| rng.gen_f64() as f32).collect()),
                }
            }
            KernelClass::Copy => ArenaEntry::Copy {
                src: Arc::new((0..sizes.copy_bytes).map(|_| rng.next_u32() as u8).collect()),
            },
            // Sort mutates its input in place, so each task gets fresh data
            // (reuse would re-sort already sorted data — trivial work).
            KernelClass::Sort => ArenaEntry::Fresh { seed },
        }
    }

    fn instantiate(&self, class: KernelClass, sizes: KernelSizes) -> Arc<dyn TaoPayload> {
        match self {
            ArenaEntry::MatMul { a, b } => {
                Arc::new(MatMulTao::with_inputs(sizes.matmul_n, a.clone(), b.clone()))
            }
            ArenaEntry::Copy { src } => Arc::new(CopyTao::with_source(src.clone())),
            ArenaEntry::Fresh { seed } => {
                debug_assert_eq!(class, KernelClass::Sort);
                Arc::new(SortTao::new(sizes.sort_len, *seed))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_task_counts() {
        let (dag, stats) = generate(&DagParams::mix(300, 4.0, 1));
        assert_eq!(dag.len(), 300);
        assert_eq!(stats.tasks, 300);
        let matmuls =
            dag.nodes.iter().filter(|n| n.class == KernelClass::MatMul).count();
        assert_eq!(matmuls, 100);
    }

    #[test]
    fn parallelism_close_to_target() {
        for target in [1.0, 2.0, 4.0, 8.0, 16.0] {
            let (_, stats) = generate(&DagParams::mix(1000, target, 7));
            let ratio = stats.parallelism / target;
            assert!(
                (0.6..=1.6).contains(&ratio),
                "target {target} got {} (ratio {ratio})",
                stats.parallelism
            );
        }
    }

    #[test]
    fn seed_reproducibility() {
        let (d1, s1) = generate(&DagParams::mix(200, 4.0, 99));
        let (d2, s2) = generate(&DagParams::mix(200, 4.0, 99));
        assert_eq!(s1.edges, s2.edges);
        assert_eq!(s1.levels, s2.levels);
        for (a, b) in d1.nodes.iter().zip(&d2.nodes) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.succs, b.succs);
            assert_eq!(a.succ_bytes, b.succ_bytes);
        }
    }

    #[test]
    fn edges_carry_data_bytes() {
        let (dag, stats) = generate(&DagParams::mix(300, 4.0, 17));
        assert!(stats.edge_bytes > 0, "generated DAG should move data");
        assert_eq!(stats.edge_bytes, dag.total_edge_bytes());
        // Every edge carries a positive data item (producer working sets
        // are all non-zero, and the smallest quarter-slice is 12 KiB).
        for n in &dag.nodes {
            for &b in &n.succ_bytes {
                assert!(b > 0);
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (_, s1) = generate(&DagParams::mix(200, 4.0, 1));
        let (_, s2) = generate(&DagParams::mix(200, 4.0, 2));
        assert_ne!(s1.edges, s2.edges);
    }

    #[test]
    fn acyclic_and_connected_spine() {
        let (dag, stats) = generate(&DagParams::mix(500, 8.0, 3));
        assert!(dag.topo_order().is_ok());
        // Critical path == number of levels (spine construction).
        assert_eq!(dag.critical_path_len() as usize, stats.levels);
    }

    #[test]
    fn chain_when_parallelism_one() {
        let (dag, _) = generate(&DagParams::single(KernelClass::Sort, 50, 1.0, 5));
        assert_eq!(dag.critical_path_len(), 50);
        assert_eq!(dag.parallelism(), 1.0);
    }

    #[test]
    fn data_reuse_allocates_fewer_locations_than_tasks() {
        let (_, stats) = generate(&DagParams::mix(600, 2.0, 11));
        // Low-parallelism DAG chains same-kernel tasks often; reuse must
        // keep allocations well below the task count.
        let total_locs: usize = stats.data_locations.values().sum();
        assert!(total_locs < 600, "locations {total_locs}");
        assert!(total_locs > 0);
    }

    #[test]
    fn payloads_attached_and_runnable() {
        let params = DagParams::mix(30, 4.0, 13).with_payloads(KernelSizes::small());
        let (dag, _) = generate(&params);
        for n in &dag.nodes {
            let p = n.payload.as_ref().expect("payload attached");
            p.execute(0, 1);
        }
    }

    #[test]
    fn edge_rate_increases_edges() {
        let mut lo = DagParams::mix(400, 8.0, 21);
        lo.edge_rate = 0.2;
        let mut hi = lo.clone();
        hi.edge_rate = 3.0;
        let (_, s_lo) = generate(&lo);
        let (_, s_hi) = generate(&hi);
        assert!(s_hi.edges > s_lo.edges, "{} vs {}", s_hi.edges, s_lo.edges);
    }
}
