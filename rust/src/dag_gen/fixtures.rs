//! Deterministic DAG fixtures shared by the test tree.
//!
//! Before this module, `independent_dag`, chain builders and payload
//! counters were re-implemented in `sim/engine.rs`, `coordinator/worker.rs`
//! and the integration tests — near-identical helpers that drifted
//! independently. Tests (unit, integration and property) should build
//! structural fixtures from here; the randomized workloads stay with
//! [`crate::dag_gen::generate`]. The Figure-1 example DAG remains in
//! [`crate::coordinator::dag`] next to the criticality logic it
//! illustrates and is re-exported here for convenience.
//!
//! Everything here is deliberately tiny and deterministic — no rng, no
//! sizes that would slow a `--quick` CI run.

pub use crate::coordinator::dag::paper_figure1_dag;
use crate::coordinator::dag::TaoDag;
use crate::coordinator::tao::payload_fn;
use crate::platform::KernelClass;
use std::sync::Arc;
use std::sync::atomic::{AtomicUsize, Ordering};

/// `n` independent tasks of one kernel class (simulation-only payloads):
/// maximal parallelism, critical-path length 1.
pub fn independent_dag(n: usize, class: KernelClass) -> TaoDag {
    let mut d = TaoDag::new();
    for _ in 0..n {
        d.add_task(class, class.index(), 1.0);
    }
    d.finalize().unwrap();
    d
}

/// A strict chain of `n` tasks of one kernel class (simulation-only
/// payloads): parallelism 1, task ids `0..n` in execution order.
pub fn chain_dag(n: usize, class: KernelClass) -> TaoDag {
    let mut d = TaoDag::new();
    let ids: Vec<_> = (0..n).map(|_| d.add_task(class, class.index(), 1.0)).collect();
    for w in ids.windows(2) {
        d.add_edge(w[0], w[1]);
    }
    d.finalize().unwrap();
    d
}

/// `n` MatMul tasks whose payload increments a shared counter once per
/// *executed share* (rank); `chain` links them into a dependency chain.
/// The counter proves exactly-once execution per rank on the real engine.
pub fn counting_dag(n: usize, chain: bool) -> (TaoDag, Arc<AtomicUsize>) {
    let hits = Arc::new(AtomicUsize::new(0));
    let mut d = TaoDag::new();
    let ids: Vec<_> = (0..n)
        .map(|_| {
            let h = hits.clone();
            d.add_task_payload(
                KernelClass::MatMul,
                0,
                1.0,
                Some(payload_fn(KernelClass::MatMul, move |_r, _w| {
                    h.fetch_add(1, Ordering::SeqCst);
                })),
            )
        })
        .collect();
    if chain {
        for w in ids.windows(2) {
            d.add_edge(w[0], w[1]);
        }
    }
    d.finalize().unwrap();
    (d, hits)
}

/// A chain of `n` MatMul tasks counting *rank-0* executions (one per TAO
/// regardless of the width the scheduler chooses). With `assert_order`,
/// each payload additionally asserts it observes the counter at exactly
/// its chain position — proving dependency ordering under real threads.
pub fn rank0_counting_chain(n: usize, assert_order: bool) -> (TaoDag, Arc<AtomicUsize>) {
    let hits = Arc::new(AtomicUsize::new(0));
    let mut d = TaoDag::new();
    let mut prev: Option<usize> = None;
    for i in 0..n {
        let h = hits.clone();
        let id = d.add_task_payload(
            KernelClass::MatMul,
            0,
            1.0,
            Some(payload_fn(KernelClass::MatMul, move |rank, _w| {
                if rank == 0 {
                    let v = h.fetch_add(1, Ordering::SeqCst);
                    if assert_order {
                        assert_eq!(v, i, "chain order violated");
                    }
                }
            })),
        );
        if let Some(p) = prev {
            d.add_edge(p, id);
        }
        prev = Some(id);
    }
    d.finalize().unwrap();
    (d, hits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_as_documented() {
        let ind = independent_dag(8, KernelClass::Sort);
        assert_eq!(ind.len(), 8);
        assert_eq!(ind.critical_path_len(), 1);
        assert_eq!(ind.roots().len(), 8);

        let chain = chain_dag(5, KernelClass::MatMul);
        assert_eq!(chain.len(), 5);
        assert_eq!(chain.critical_path_len(), 5);
        assert_eq!(chain.roots(), vec![0]);

        let (counting, hits) = counting_dag(3, true);
        assert_eq!(counting.critical_path_len(), 3);
        counting.nodes[0].payload.as_ref().unwrap().execute(0, 1);
        assert_eq!(hits.load(Ordering::SeqCst), 1);

        let (rank0, hits) = rank0_counting_chain(4, false);
        assert_eq!(rank0.critical_path_len(), 4);
        rank0.nodes[0].payload.as_ref().unwrap().execute(1, 2); // non-zero rank
        assert_eq!(hits.load(Ordering::SeqCst), 0, "only rank 0 counts");
    }
}
