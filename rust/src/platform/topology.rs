//! Hardware topology description.
//!
//! The paper requires "as little information as the number of cores and their
//! distribution into core-clusters with shared caches" (§3.2) — the data
//! hwloc provides. Here a [`Topology`] is an explicit description: cores with
//! a type label, grouped into clusters that share a last-level cache.
//!
//! XiTAO's placement rules (§3.1) are encoded here:
//! - a resource width must be a *natural divisor* of the cluster size;
//! - partitions are consecutive core ids within one cluster;
//! - the leader is the lowest id in the partition, and leaders are aligned
//!   (a width-w partition starts at a multiple of w within its cluster).

/// Index of a logical core.
pub type CoreId = usize;

/// A core type label (e.g. "denver2", "a57", "haswell"). Purely descriptive —
/// the scheduler never reads it (it is *heterogeneity-unaware*, §3.3); only
/// the simulator's performance model does.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreKind(pub String);

/// One logical core.
#[derive(Debug, Clone)]
pub struct CoreDesc {
    pub id: CoreId,
    /// Index into `Topology::clusters`.
    pub cluster: usize,
    pub kind: CoreKind,
}

/// A group of cores sharing a last-level cache (e.g. a NUMA node or a
/// big.LITTLE cluster).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub id: usize,
    /// First core id in the cluster (cores are consecutive).
    pub first_core: CoreId,
    /// Number of cores.
    pub len: usize,
    /// Shared cache capacity in bytes (L2 on the TX2, L3 on Haswell).
    pub cache_bytes: u64,
}

impl Cluster {
    pub fn cores(&self) -> std::ops::Range<CoreId> {
        self.first_core..self.first_core + self.len
    }

    pub fn contains(&self, core: CoreId) -> bool {
        self.cores().contains(&core)
    }

    /// Natural divisors of the cluster size — the valid resource widths
    /// (§3.1: "The resource width must be a natural divisor of the number of
    /// available logical cores in a particular core-cluster").
    pub fn valid_widths(&self) -> Vec<usize> {
        (1..=self.len).filter(|w| self.len % w == 0).collect()
    }
}

/// A full platform topology.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub cores: Vec<CoreDesc>,
    pub clusters: Vec<Cluster>,
}

/// A concrete resource partition: `width` consecutive cores led by `leader`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Partition {
    pub leader: CoreId,
    pub width: usize,
}

impl Partition {
    pub fn cores(&self) -> std::ops::Range<CoreId> {
        self.leader..self.leader + self.width
    }

    pub fn contains(&self, core: CoreId) -> bool {
        self.cores().contains(&core)
    }
}

impl Topology {
    /// Build a topology from `(cluster_size, kind, cache_bytes)` groups.
    pub fn from_clusters(name: &str, groups: &[(usize, &str, u64)]) -> Topology {
        let mut cores = Vec::new();
        let mut clusters = Vec::new();
        let mut next = 0;
        for (ci, &(len, kind, cache)) in groups.iter().enumerate() {
            assert!(len > 0, "empty cluster");
            clusters.push(Cluster { id: ci, first_core: next, len, cache_bytes: cache });
            for _ in 0..len {
                cores.push(CoreDesc { id: next, cluster: ci, kind: CoreKind(kind.to_string()) });
                next += 1;
            }
        }
        Topology { name: name.to_string(), cores, clusters }
    }

    /// Uniform single-cluster topology (tests, generic machines).
    pub fn homogeneous(n: usize) -> Topology {
        Self::from_clusters("homogeneous", &[(n, "generic", 8 << 20)])
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn cluster_of(&self, core: CoreId) -> &Cluster {
        &self.clusters[self.cores[core].cluster]
    }

    /// All widths valid for partitions led by `core` (divisors of its cluster
    /// size at which `core` is alignment-eligible as leader).
    pub fn leader_widths(&self, core: CoreId) -> Vec<usize> {
        let cl = self.cluster_of(core);
        let off = core - cl.first_core;
        cl.valid_widths().into_iter().filter(|w| off % w == 0).collect()
    }

    /// The union of all valid widths across clusters, sorted ascending.
    /// This is the PTT's width axis.
    pub fn all_widths(&self) -> Vec<usize> {
        let mut ws: Vec<usize> =
            self.clusters.iter().flat_map(|c| c.valid_widths()).collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    /// Check that `(leader, width)` denotes a valid partition.
    pub fn is_valid_partition(&self, p: Partition) -> bool {
        if p.width == 0 || p.leader >= self.n_cores() {
            return false;
        }
        let cl = self.cluster_of(p.leader);
        let off = p.leader - cl.first_core;
        cl.len % p.width == 0 && off % p.width == 0 && p.leader + p.width <= cl.first_core + cl.len
    }

    /// The partition led by `leader` at `width`; `None` if invalid.
    pub fn partition(&self, leader: CoreId, width: usize) -> Option<Partition> {
        let p = Partition { leader, width };
        self.is_valid_partition(p).then_some(p)
    }

    /// The partition of width `w` *containing* `core` (for non-critical
    /// placement: the paper keeps the task near the current core and only
    /// picks a width). `None` if `w` is invalid for the core's cluster.
    pub fn enclosing_partition(&self, core: CoreId, width: usize) -> Option<Partition> {
        let cl = self.cluster_of(core);
        if cl.len % width != 0 {
            return None;
        }
        let off = core - cl.first_core;
        let leader = cl.first_core + (off / width) * width;
        Some(Partition { leader, width })
    }

    /// Every valid partition on the machine (used by exhaustive tests and by
    /// the dHEFT baseline).
    pub fn all_partitions(&self) -> Vec<Partition> {
        let mut out = Vec::new();
        for cl in &self.clusters {
            for w in cl.valid_widths() {
                let mut leader = cl.first_core;
                while leader + w <= cl.first_core + cl.len {
                    out.push(Partition { leader, width: w });
                    leader += w;
                }
            }
        }
        out
    }

    /// Number of PTT entries per cluster of N cores is 2N−1 when N is a power
    /// of two (§3.3 states the per-NUMA-node entry count); exposed for tests.
    pub fn ptt_entries_per_cluster(&self, cluster: usize) -> usize {
        let cl = &self.clusters[cluster];
        cl.valid_widths().iter().map(|w| cl.len / w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tx2_like() -> Topology {
        Topology::from_clusters(
            "tx2",
            &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)],
        )
    }

    #[test]
    fn cluster_layout() {
        let t = tx2_like();
        assert_eq!(t.n_cores(), 6);
        assert_eq!(t.clusters.len(), 2);
        assert_eq!(t.clusters[0].cores(), 0..2);
        assert_eq!(t.clusters[1].cores(), 2..6);
        assert_eq!(t.cluster_of(3).id, 1);
    }

    #[test]
    fn valid_widths_are_divisors() {
        let t = tx2_like();
        assert_eq!(t.clusters[0].valid_widths(), vec![1, 2]);
        assert_eq!(t.clusters[1].valid_widths(), vec![1, 2, 4]);
        assert_eq!(t.all_widths(), vec![1, 2, 4]);
    }

    #[test]
    fn leader_alignment() {
        let t = tx2_like();
        // Core 2 is first of the a57 cluster: can lead widths 1,2,4.
        assert_eq!(t.leader_widths(2), vec![1, 2, 4]);
        // Core 3 is offset 1: only width 1.
        assert_eq!(t.leader_widths(3), vec![1]);
        // Core 4 is offset 2: widths 1,2.
        assert_eq!(t.leader_widths(4), vec![1, 2]);
    }

    #[test]
    fn partition_validity() {
        let t = tx2_like();
        assert!(t.is_valid_partition(Partition { leader: 2, width: 4 }));
        assert!(!t.is_valid_partition(Partition { leader: 3, width: 2 })); // misaligned
        assert!(!t.is_valid_partition(Partition { leader: 0, width: 4 })); // exceeds cluster
        assert!(!t.is_valid_partition(Partition { leader: 0, width: 0 }));
        assert!(!t.is_valid_partition(Partition { leader: 99, width: 1 }));
    }

    #[test]
    fn enclosing_partition_snaps_to_alignment() {
        let t = tx2_like();
        let p = t.enclosing_partition(3, 2).unwrap();
        assert_eq!(p, Partition { leader: 2, width: 2 });
        let p = t.enclosing_partition(5, 4).unwrap();
        assert_eq!(p, Partition { leader: 2, width: 4 });
        assert!(t.enclosing_partition(0, 4).is_none()); // 4 doesn't divide 2... no: 2%4 != 0
    }

    #[test]
    fn all_partitions_are_valid_and_complete() {
        let t = tx2_like();
        let ps = t.all_partitions();
        for p in &ps {
            assert!(t.is_valid_partition(*p), "{p:?}");
        }
        // denver: 2 width-1 + 1 width-2 = 3; a57: 4 + 2 + 1 = 7.
        assert_eq!(ps.len(), 10);
    }

    #[test]
    fn ptt_entries_match_2n_minus_1() {
        let t = Topology::homogeneous(4);
        // widths 1,2,4 -> 4 + 2 + 1 = 7 = 2*4 - 1.
        assert_eq!(t.ptt_entries_per_cluster(0), 7);
        let t = Topology::homogeneous(8);
        assert_eq!(t.ptt_entries_per_cluster(0), 15);
    }

    #[test]
    fn homogeneous_topology() {
        let t = Topology::homogeneous(16);
        assert_eq!(t.n_cores(), 16);
        assert_eq!(t.clusters.len(), 1);
        assert_eq!(t.all_widths(), vec![1, 2, 4, 8, 16]);
    }
}
