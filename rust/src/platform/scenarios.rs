//! Named platform scenarios — the registry behind `--platform`.
//!
//! A scenario is a reproducible [`Platform`] configuration: a topology plus
//! the memory system and (optionally) a dynamic-heterogeneity episode
//! schedule. The registry makes any `(backend × policy × platform)` triple
//! a single lookup away (see [`crate::exec::run_triple`]), which is how the
//! CLI, the figure regenerators and the conformance tests enumerate
//! configurations without hard-coding constructors.
//!
//! Registered scenarios:
//! - `tx2` — the paper's NVIDIA Jetson TX2 (2× Denver2 + 4× A57).
//! - `haswell20` — the paper's dual-socket Xeon E5-2650v3 (2 NUMA × 10).
//! - `biglittle44` — synthetic big.LITTLE: 4 fast + 4 slow cores, the
//!   static-heterogeneity stress case with symmetric cluster widths.
//! - `dvfs8` — 8 homogeneous cores with alternating DVFS throttle
//!   episodes, the dynamic-heterogeneity case of §1.
//! - `interference20` — `haswell20` plus a background process
//!   time-sharing cores 0–1 mid-run (the §5.3 experiment).
//! - `stream-pois8` / `duet-tx2` / `bg-interferer-haswell20` — the
//!   platform substrates of the multi-application workload streams
//!   registered under the same names in [`crate::workload::scenarios`]
//!   (the last one adds a heavy 0.05–0.45 s squeeze of cores 0–1).
//! - `failstop20` / `failstop-recover8` / `failslow-biglittle44` — the
//!   fault-injection scenarios behind the chaos harness
//!   (`repro bench-faults`): cores dying mid-run (with and without
//!   recovery) and a permanent fail-slow degradation of the big cluster.
//! - `commbound-tx2` / `commbound-numa20` — communication-bound variants
//!   of the paper platforms: DRAM throttled to
//!   [`COMMBOUND_DRAM_GBPS`] GB/s so MiB-scale DAG edge payloads make
//!   cluster crossings the dominant scheduling cost (exercises the
//!   planners' comm terms and the elastic bench's comm-bound point).
//! - `hom64` / `hom128` — many-core steal-pressure stress for
//!   `bench-overhead`: 64/128 homogeneous cores, far past the paper's
//!   4–44-core platforms, where queue contention (not placement quality)
//!   dominates scheduler overhead. Registered explicitly (identical to
//!   the dynamic `hom<N>` resolution) so they show up in `--list` and the
//!   experiment matrix.
//!
//! The dynamic `hom<N>` family (N homogeneous cores) is also resolved by
//! [`by_name`] for arbitrary N ≥ 1. Episode schedules drive **both**
//! backends: the simulator interprets them analytically in virtual time,
//! and the real-thread engine realizes the same schedule in wall clock
//! (`coordinator::episodes_rt` — background spinner threads for
//! interference plus per-core duty-cycle throttling), so a scenario like
//! `interference20` produces a comparable response shape on either
//! substrate. Keep episode windows short enough for a real run to span
//! them — a wall-clock run that drains early simply never sees the
//! episode.

use super::episodes::{Episode, EpisodeSchedule};
use super::perf_model::Platform;
use super::topology::Topology;

/// One registered platform scenario.
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
    build: fn() -> Platform,
}

impl Scenario {
    /// Materialise the scenario's platform (fresh instance per call).
    pub fn platform(&self) -> Platform {
        (self.build)()
    }
}

fn biglittle44() -> Platform {
    Platform {
        topo: Topology::from_clusters(
            "biglittle44",
            &[(4, "denver2", 4 << 20), (4, "a57", 2 << 20)],
        ),
        dram_bw_gbps: 30.0,
        episodes: EpisodeSchedule::default(),
    }
}

fn dvfs8() -> Platform {
    // Two alternating throttle windows: first one half of the machine drops
    // to 40%, later the other half to 50% — the scheduler must migrate the
    // critical chain twice, guided only by PTT observations.
    Platform::homogeneous(8).with_episodes(EpisodeSchedule::new(vec![
        Episode::dvfs(vec![0, 1, 2, 3], 0.05, 0.20, 0.4),
        Episode::dvfs(vec![4, 5, 6, 7], 0.25, 0.40, 0.5),
    ]))
}

fn interference20() -> Platform {
    // The §5.3 setup: a same-priority background process keeps ~45% of
    // cores 0–1 for itself during [0.05, 0.25) and adds memory traffic.
    Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![
        Episode::interference(vec![0, 1], 0.05, 0.25, 0.45, 2.0),
    ]))
}

fn stream_pois8() -> Platform {
    // Substrate of the `stream-pois8` workload stream (workload::scenarios):
    // 8 homogeneous cores, no episodes — all interference is DAG-on-DAG.
    Platform::homogeneous(8)
}

/// Victim cores of the `bg-interferer-haswell20` scenario. Exported so the
/// interference bench and the PTT regression test measure exactly the
/// episode the scenario schedules (no silently drifting copies).
pub const BG_INTERFERER_VICTIMS: [usize; 2] = [0, 1];
/// `[start, end)` of the background squeeze in `bg-interferer-haswell20`.
pub const BG_INTERFERER_WINDOW: (f64, f64) = (0.05, 0.45);

fn bg_interferer_haswell20() -> Platform {
    // Substrate of the `bg-interferer-haswell20` stream: haswell20 with a
    // heavier, longer background squeeze than `interference20` — the
    // runtime keeps only ~30% of the victim cores inside the window, so
    // the PTT's interference response is unmistakable even while a second
    // tenant is churning the queues.
    Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![Episode::interference(
        BG_INTERFERER_VICTIMS.to_vec(),
        BG_INTERFERER_WINDOW.0,
        BG_INTERFERER_WINDOW.1,
        0.30,
        2.0,
    )]))
}

/// Cores that die *permanently* in `failstop20` (never recover). Exported
/// like the `BG_INTERFERER_*` consts so the chaos harness and the fault
/// tests measure exactly the outage the scenario schedules.
pub const FAILSTOP_CORES: [usize; 2] = [0, 1];
/// Core in `failstop20` that blips out and comes back.
pub const FAILSTOP_RECOVERING_CORE: usize = 2;
/// Failure time of every `failstop20` outage (seconds of run time).
pub const FAILSTOP_AT: f64 = 0.05;
/// Recovery time of [`FAILSTOP_RECOVERING_CORE`].
pub const FAILSTOP_RECOVER_AT: f64 = 0.25;

fn failstop20() -> Platform {
    // Three cores die at the same instant mid-run; cores 0-1 stay dead,
    // core 2 returns at 0.25 s. Any task queued on or running on them at
    // the failure instant must be reclaimed and re-executed elsewhere
    // exactly once.
    Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![
        Episode::fail_stop(FAILSTOP_CORES.to_vec(), FAILSTOP_AT, None),
        Episode::fail_stop(vec![FAILSTOP_RECOVERING_CORE], FAILSTOP_AT, Some(FAILSTOP_RECOVER_AT)),
    ]))
}

/// Cores of `failstop-recover8` that blip out together.
pub const FAILSTOP_RECOVER8_CORES: [usize; 4] = [4, 5, 6, 7];
/// `[failure, recovery)` window of the `failstop-recover8` outage.
pub const FAILSTOP_RECOVER8_WINDOW: (f64, f64) = (0.05, 0.20);

fn failstop_recover8() -> Platform {
    // Half the machine loses power for 150 ms and comes back — the
    // transient-outage case: capacity halves, nothing may be lost, and the
    // recovered cores must be used again afterwards.
    Platform::homogeneous(8).with_episodes(EpisodeSchedule::new(vec![Episode::fail_stop(
        FAILSTOP_RECOVER8_CORES.to_vec(),
        FAILSTOP_RECOVER8_WINDOW.0,
        Some(FAILSTOP_RECOVER8_WINDOW.1),
    )]))
}

/// Big-cluster cores degraded in `failslow-biglittle44`.
pub const FAILSLOW_CORES: [usize; 2] = [0, 1];
/// Residual speed of the degraded cores (fraction of nominal).
pub const FAILSLOW_FACTOR: f64 = 0.3;
/// Onset of the permanent degradation (seconds of run time).
pub const FAILSLOW_AT: f64 = 0.06;

fn failslow_biglittle44() -> Platform {
    // Two of the four big cores silently degrade below LITTLE speed and
    // never recover. No event announces it — the PTT's change detector is
    // the only sensor, and `ptt-adaptive` must steer off the sick cores.
    biglittle44().with_episodes(EpisodeSchedule::new(vec![Episode::fail_slow(
        FAILSLOW_CORES.to_vec(),
        FAILSLOW_AT,
        f64::INFINITY,
        FAILSLOW_FACTOR,
    )]))
}

/// DRAM bandwidth of the communication-bound scenarios, GB/s. Far below
/// the nominal platforms (25–100 GB/s): with MiB-scale edge payloads a
/// cluster crossing costs a task-sized slice of time, so data-movement-
/// aware placement (comm-cost planners, locality-preserving policies) is
/// actually exercised instead of being noise.
pub const COMMBOUND_DRAM_GBPS: f64 = 4.0;

fn commbound_tx2() -> Platform {
    // TX2 topology with the memory system throttled to interconnect-era
    // bandwidth: crossing between the Denver and A57 clusters is the
    // dominant scheduling cost.
    Platform { dram_bw_gbps: COMMBOUND_DRAM_GBPS, ..Platform::tx2() }
}

fn commbound_numa20() -> Platform {
    // haswell20's two NUMA sockets with starved cross-socket bandwidth —
    // the classical list-scheduling setting where HEFT/PEFT's comm terms
    // decide placements.
    Platform { dram_bw_gbps: COMMBOUND_DRAM_GBPS, ..Platform::haswell20() }
}

fn hom64() -> Platform {
    // Many-core steal-pressure stress (bench-overhead's scaling scenario):
    // identical to the dynamic `hom64` resolution by construction — the
    // registration only makes the scenario enumerable.
    Platform::homogeneous(64)
}

fn hom128() -> Platform {
    // Two doublings past one socket; full-mode bench-overhead only (128
    // worker threads is too heavy for the quick CI smoke).
    Platform::homogeneous(128)
}

/// The static scenario registry.
pub fn scenarios() -> &'static [Scenario] {
    static SCENARIOS: &[Scenario] = &[
        Scenario {
            name: "tx2",
            description: "NVIDIA Jetson TX2: 2x Denver2 + 4x Cortex-A57 (paper §4.1)",
            build: Platform::tx2,
        },
        Scenario {
            name: "haswell20",
            description: "dual-socket Xeon E5-2650v3: 2 NUMA x 10 cores (paper §4.1)",
            build: Platform::haswell20,
        },
        Scenario {
            name: "biglittle44",
            description: "synthetic big.LITTLE: 4 fast + 4 slow cores, symmetric clusters",
            build: biglittle44,
        },
        Scenario {
            name: "dvfs8",
            description: "8 homogeneous cores with alternating DVFS throttle episodes",
            build: dvfs8,
        },
        Scenario {
            name: "interference20",
            description: "haswell20 with a background process on cores 0-1 (§5.3)",
            build: interference20,
        },
        Scenario {
            name: "stream-pois8",
            description: "8 homogeneous cores backing the Poisson multi-app stream",
            build: stream_pois8,
        },
        Scenario {
            name: "duet-tx2",
            description: "TX2 model backing the chain+burst duet stream",
            build: Platform::tx2,
        },
        Scenario {
            name: "bg-interferer-haswell20",
            description: "haswell20 with a heavy background process on cores 0-1 (multi-app §5.3)",
            build: bg_interferer_haswell20,
        },
        Scenario {
            name: "failstop20",
            description: "haswell20 where cores 0-2 die at 0.05 s (core 2 recovers at 0.25 s)",
            build: failstop20,
        },
        Scenario {
            name: "failstop-recover8",
            description: "8 homogeneous cores; cores 4-7 fail-stop during [0.05, 0.20)",
            build: failstop_recover8,
        },
        Scenario {
            name: "failslow-biglittle44",
            description: "biglittle44 where big cores 0-1 permanently degrade to 30% at 0.06 s",
            build: failslow_biglittle44,
        },
        Scenario {
            name: "commbound-tx2",
            description: "TX2 clusters with 4 GB/s DRAM: cross-cluster data movement dominates",
            build: commbound_tx2,
        },
        Scenario {
            name: "commbound-numa20",
            description: "haswell20 NUMA pair with 4 GB/s DRAM: comm-bound list-scheduling setting",
            build: commbound_numa20,
        },
        Scenario {
            name: "hom64",
            description: "64 homogeneous cores: many-core steal-pressure stress (bench-overhead)",
            build: hom64,
        },
        Scenario {
            name: "hom128",
            description: "128 homogeneous cores: steal-pressure stress, full-mode bench only",
            build: hom128,
        },
    ];
    SCENARIOS
}

/// Resolve a scenario by name. Understands every registered scenario plus
/// the dynamic `hom<N>` family; returns `None` for unknown names.
pub fn by_name(name: &str) -> Option<Platform> {
    if let Some(s) = scenarios().iter().find(|s| s.name == name) {
        return Some(s.platform());
    }
    if let Some(rest) = name.strip_prefix("hom") {
        if let Ok(n) = rest.parse::<usize>() {
            if n > 0 {
                return Some(Platform::homogeneous(n));
            }
        }
    }
    None
}

/// Names of all registered (static) scenarios.
pub fn names() -> Vec<&'static str> {
    scenarios().iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{KernelClass, Partition};

    #[test]
    fn registry_contains_paper_platforms_and_synthetics() {
        let names = names();
        for expected in [
            "tx2",
            "haswell20",
            "biglittle44",
            "dvfs8",
            "interference20",
            "stream-pois8",
            "duet-tx2",
            "bg-interferer-haswell20",
            "failstop20",
            "failstop-recover8",
            "failslow-biglittle44",
            "commbound-tx2",
            "commbound-numa20",
            "hom64",
            "hom128",
        ] {
            assert!(names.contains(&expected), "missing scenario {expected}");
        }
        assert!(names.len() >= 15);
    }

    #[test]
    fn commbound_scenarios_starve_bandwidth_but_keep_topology() {
        let cb = by_name("commbound-tx2").unwrap();
        let nominal = Platform::tx2();
        assert_eq!(cb.topo.n_cores(), nominal.topo.n_cores());
        assert!(cb.dram_bw_gbps < nominal.dram_bw_gbps / 2.0);
        // A 2 MiB cross-cluster edge costs a schedulable amount of time
        // (hundreds of µs at 4 GB/s) instead of rounding to nothing.
        let t = cb.transfer_time(2 << 20, false, 2 << 20);
        assert!(t > 1e-4, "comm must be schedulably expensive: {t}");
        let numa = by_name("commbound-numa20").unwrap();
        assert_eq!(numa.topo.clusters.len(), 2);
        assert!((numa.dram_bw_gbps - COMMBOUND_DRAM_GBPS).abs() < 1e-12);
    }

    #[test]
    fn every_scenario_yields_a_sound_platform() {
        for s in scenarios() {
            let p = s.platform();
            assert!(p.topo.n_cores() >= 1, "{}", s.name);
            assert!(!p.topo.all_widths().is_empty(), "{}", s.name);
            assert!(p.dram_bw_gbps > 0.0, "{}", s.name);
            for part in p.topo.all_partitions() {
                assert!(p.topo.is_valid_partition(part), "{}: {part:?}", s.name);
            }
        }
    }

    #[test]
    fn by_name_resolves_registered_and_hom_family() {
        assert_eq!(by_name("tx2").unwrap().topo.n_cores(), 6);
        assert_eq!(by_name("haswell20").unwrap().topo.n_cores(), 20);
        assert_eq!(by_name("hom8").unwrap().topo.n_cores(), 8);
        // Registered many-core entries resolve identically to the dynamic
        // family (single 64/128-core cluster) — the registration must not
        // change semantics.
        assert_eq!(by_name("hom64").unwrap().topo.n_cores(), 64);
        assert_eq!(by_name("hom64").unwrap().topo.clusters.len(), 1);
        assert_eq!(by_name("hom128").unwrap().topo.n_cores(), 128);
        assert!(by_name("hom0").is_none());
        assert!(by_name("homX").is_none());
        assert!(by_name("riscv").is_none());
    }

    #[test]
    fn biglittle_is_statically_heterogeneous() {
        let p = by_name("biglittle44").unwrap();
        let fast = p.ideal_exec_time(KernelClass::MatMul, Partition { leader: 0, width: 1 });
        let slow = p.ideal_exec_time(KernelClass::MatMul, Partition { leader: 4, width: 1 });
        assert!(fast < slow, "big cores must be faster: {fast} vs {slow}");
    }

    #[test]
    fn dvfs_scenario_throttles_inside_windows_only() {
        let p = by_name("dvfs8").unwrap();
        assert!((p.episodes.speed_factor(0, 0.10) - 0.4).abs() < 1e-12);
        assert_eq!(p.episodes.speed_factor(0, 0.30), 1.0);
        assert!((p.episodes.speed_factor(4, 0.30) - 0.5).abs() < 1e-12);
        assert_eq!(p.episodes.speed_factor(4, 0.10), 1.0);
    }

    #[test]
    fn interference_scenario_adds_bandwidth_pressure() {
        let p = by_name("interference20").unwrap();
        assert!(p.episodes.extra_bw(0.10) > 0.0);
        assert_eq!(p.episodes.extra_bw(0.30), 0.0);
    }

    #[test]
    fn failstop_scenarios_schedule_the_exported_outages() {
        let p = by_name("failstop20").unwrap();
        assert!(p.episodes.has_faults());
        for &c in &FAILSTOP_CORES {
            assert!(!p.episodes.fail_stopped(c, FAILSTOP_AT - 0.01));
            assert!(p.episodes.fail_stopped(c, FAILSTOP_AT));
            assert!(p.episodes.fail_stopped(c, 1e6), "permanent outage");
        }
        assert!(p.episodes.fail_stopped(FAILSTOP_RECOVERING_CORE, FAILSTOP_AT));
        assert!(!p.episodes.fail_stopped(FAILSTOP_RECOVERING_CORE, FAILSTOP_RECOVER_AT));
        // Core 3 onward untouched.
        assert!(!p.episodes.fail_stopped(3, FAILSTOP_AT));

        let p = by_name("failstop-recover8").unwrap();
        let (t0, t1) = FAILSTOP_RECOVER8_WINDOW;
        for &c in &FAILSTOP_RECOVER8_CORES {
            assert!(p.episodes.fail_stopped(c, t0));
            assert!(!p.episodes.fail_stopped(c, t1), "all cores recover");
        }
        assert!(!p.episodes.fail_stopped(0, t0));
    }

    #[test]
    fn failslow_scenario_degrades_without_killing() {
        let p = by_name("failslow-biglittle44").unwrap();
        assert!(p.episodes.has_faults());
        for &c in &FAILSLOW_CORES {
            assert_eq!(p.episodes.speed_factor(c, FAILSLOW_AT - 0.01), 1.0);
            assert!((p.episodes.speed_factor(c, FAILSLOW_AT) - FAILSLOW_FACTOR).abs() < 1e-12);
            assert!((p.episodes.speed_factor(c, 1e6) - FAILSLOW_FACTOR).abs() < 1e-12);
            assert!(!p.episodes.fail_stopped(c, 1.0), "fail-slow cores stay alive");
        }
        // Stripping faults recovers the plain biglittle44 platform.
        assert!(!p.episodes.without_faults().has_faults());
    }
}
