//! The performance model that stands in for real silicon.
//!
//! The host for this reproduction exposes a single CPU core, so the paper's
//! platforms (Jetson TX2, dual-socket Haswell) are modelled analytically and
//! driven by the discrete-event simulator in `crate::sim`. The scheduler is
//! *not* told any of this — it observes only per-(core,width) execution times
//! through the PTT, exactly as on real hardware.
//!
//! A running TAO progresses at a piecewise-constant **rate** (work-units per
//! simulated second):
//!
//! ```text
//! rate = class_speed(core_kind, class)              // static heterogeneity
//!      × width_speedup(class, width)                // internal scalability
//!      × cache_factor(cluster occupancy, class)     // LLC oversubscription
//!      × bw_factor(global bandwidth demand, class)  // memory-bus contention
//!      × episode_speed(core, t)                     // DVFS / interference
//! ```
//!
//! All figure reproductions rest on this model; the constants below are
//! calibrated to published Denver2/A57 micro-benchmarks and to the paper's
//! reported speedups (see DESIGN.md §Substitutions, and EXPERIMENTS.md
//! §Calibration at the repository root for the full constant tables and
//! the interference-response measurement protocol they feed).

use super::episodes::EpisodeSchedule;
use super::topology::{Partition, Topology};

/// Workload classes distinguished by the model (the paper's three kernel
/// characteristics, §4.2.1, plus the VGG GEMM).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// 64×64 matrix multiply — compute-bound, tiny working set.
    MatMul,
    /// quick+merge sort over 256 KiB — cache-capacity-bound.
    Sort,
    /// 16.8 MB memcpy — memory-bandwidth-bound (streaming).
    Copy,
    /// VGG-16 convolution/FC expressed as GEMM — compute-bound with a
    /// moderate working set.
    Gemm,
}

impl KernelClass {
    pub const ALL: [KernelClass; 4] =
        [KernelClass::MatMul, KernelClass::Sort, KernelClass::Copy, KernelClass::Gemm];

    pub fn name(&self) -> &'static str {
        match self {
            KernelClass::MatMul => "matmul",
            KernelClass::Sort => "sort",
            KernelClass::Copy => "copy",
            KernelClass::Gemm => "gemm",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelClass> {
        match s {
            "matmul" => Some(KernelClass::MatMul),
            "sort" => Some(KernelClass::Sort),
            "copy" => Some(KernelClass::Copy),
            "gemm" => Some(KernelClass::Gemm),
            _ => None,
        }
    }

    /// Stable dense index (PTT tables are per-class arrays).
    pub fn index(&self) -> usize {
        match self {
            KernelClass::MatMul => 0,
            KernelClass::Sort => 1,
            KernelClass::Copy => 2,
            KernelClass::Gemm => 3,
        }
    }
}

/// Per-class traits of a kernel on this platform model.
#[derive(Debug, Clone, Copy)]
pub struct ClassTraits {
    /// Work units of one task instance (1 unit ≡ 1 s on a speed-1 core at
    /// width 1 with no contention). The ratios follow the paper's working
    /// sets: 64×64 matmul ≈ 0.52 MFLOP, 256 KiB sort, 16.8 MB copy.
    pub base_work: f64,
    /// Parallelizable fraction for Amdahl-style internal scaling.
    pub par_fraction: f64,
    /// Hard cap on useful internal parallelism (the paper's sort kernel "has
    /// a maximum parallelism of four", §4.2.1).
    pub max_parallelism: usize,
    /// Constructive-sharing bonus: running one TAO across w cores gives the
    /// task the aggregate cache/TLB/bus of the whole partition, which for
    /// cache-hungry kernels yields *superlinear* internal scaling (the
    /// phenomenon PDF-style schedulers exploit; §6.2 of the paper). Applied
    /// as `speedup × (1 + boost·(1 − 1/w))`. This is also what makes wide
    /// entries win the paper's `time × width` search once the PTT has
    /// observed them (Fig 10's width-8 population).
    pub cache_boost: f64,
    /// Working-set bytes charged against the cluster cache while running.
    pub working_set: u64,
    /// Sensitivity of the rate to cache overflow, in `[0, 1]`.
    pub cache_sensitivity: f64,
    /// Memory-bandwidth demand at full speed, GB/s per participating core.
    pub bw_demand_gbps: f64,
    /// Fraction of runtime that is memory-bound (how strongly bus contention
    /// bites), in `[0, 1]`.
    pub mem_boundedness: f64,
    /// Co-runner sensitivity: fractional slowdown when every *other* core of
    /// the cluster is busy (shared LLC ways, DRAM queues, frontend — effects
    /// present even for compute-bound kernels). This closes the PTT feedback
    /// loop: a partition convoying critical tasks sees its observed times
    /// inflate, and the global search redirects — the paper's self-balancing
    /// behaviour (§5.3 relies on exactly this mechanism for interference).
    pub corun_sensitivity: f64,
}

impl KernelClass {
    pub fn traits(&self) -> ClassTraits {
        match self {
            // Compute-bound: scales well internally, negligible memory needs.
            KernelClass::MatMul => ClassTraits {
                base_work: 1.0e-3,
                par_fraction: 0.96,
                max_parallelism: 8,
                cache_boost: 0.20, // shared B-matrix reuse across the team
                working_set: 48 << 10, // three 64×64 f32 matrices
                cache_sensitivity: 0.05,
                bw_demand_gbps: 0.2,
                mem_boundedness: 0.05,
                corun_sensitivity: 0.20,
            },
            // Cache-bound: 524 KiB live set (double buffering, §4.2.1);
            // suffers badly when the cluster L2 is oversubscribed.
            KernelClass::Sort => ClassTraits {
                base_work: 2.2e-3,
                par_fraction: 0.85,
                max_parallelism: 4,
                cache_boost: 0.40, // 524 KiB set fits the aggregated L2 slices
                working_set: 524 << 10,
                cache_sensitivity: 0.9,
                bw_demand_gbps: 1.0,
                mem_boundedness: 0.3,
                corun_sensitivity: 0.25,
            },
            // Stream-bound: internal scaling saturates once the bus is full.
            KernelClass::Copy => ClassTraits {
                base_work: 4.0e-3,
                par_fraction: 0.98,
                max_parallelism: 8,
                cache_boost: 0.12, // extra outstanding streams fill the bus
                working_set: 2 << 20, // resident stream buffer slice
                cache_sensitivity: 0.0,
                bw_demand_gbps: 10.0, // read+write streams saturate quickly
                mem_boundedness: 0.9,
                corun_sensitivity: 0.15,
            },
            // VGG GEMM block: compute-bound, moderate tiles.
            KernelClass::Gemm => ClassTraits {
                base_work: 6.0e-3,
                par_fraction: 0.93,
                max_parallelism: 16,
                cache_boost: 0.65, // blocked GEMM: row-slices drop into private L2s
                working_set: 1536 << 10, // im2col slice + weights block
                cache_sensitivity: 0.30,
                bw_demand_gbps: 1.5,
                mem_boundedness: 0.15,
                corun_sensitivity: 0.20,
            },
        }
    }

    /// Internal speedup at `width` participating cores: Amdahl with a hard
    /// parallelism cap, times the constructive-sharing bonus (see
    /// [`ClassTraits::cache_boost`]).
    pub fn width_speedup(&self, width: usize) -> f64 {
        let t = self.traits();
        let w = width.min(t.max_parallelism).max(1) as f64;
        let amdahl = 1.0 / ((1.0 - t.par_fraction) + t.par_fraction / w);
        amdahl * (1.0 + t.cache_boost * (1.0 - 1.0 / w))
    }
}

/// Static per-core-kind speed factors by class. Denver2-vs-A57 ratios follow
/// published single-thread results (Denver ~1.8–2.2× on dense FP, smaller
/// edge on memory streaming).
fn class_speed(kind: &str, class: KernelClass) -> f64 {
    match (kind, class) {
        ("denver2", KernelClass::MatMul) => 2.0,
        ("denver2", KernelClass::Sort) => 1.5,
        ("denver2", KernelClass::Copy) => 1.3,
        ("denver2", KernelClass::Gemm) => 2.0,
        ("a57", _) => 1.0,
        ("haswell", _) => 1.0,
        ("generic", _) => 1.0,
        // Unknown kinds run at nominal speed.
        _ => 1.0,
    }
}

/// A platform = topology + global memory system + episode schedule.
#[derive(Debug, Clone)]
pub struct Platform {
    pub topo: Topology,
    /// Total DRAM bandwidth, GB/s.
    pub dram_bw_gbps: f64,
    /// Dynamic-heterogeneity schedule (may be empty).
    pub episodes: EpisodeSchedule,
}

/// Snapshot of what is running, fed to the rate calculation by the DES.
#[derive(Debug, Clone, Copy)]
pub struct RunningTask {
    pub class: KernelClass,
    pub partition: Partition,
}

impl Platform {
    /// NVIDIA Jetson TX2: 2× Denver2 + 4× Cortex-A57, per-cluster 2 MB L2,
    /// ~59.7 GB/s theoretical LPDDR4 (≈30 GB/s sustained).
    pub fn tx2() -> Platform {
        Platform {
            topo: Topology::from_clusters(
                "tx2",
                &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)],
            ),
            dram_bw_gbps: 25.0,
            episodes: EpisodeSchedule::default(),
        }
    }

    /// Dual-socket Intel Xeon E5-2650v3: 2 NUMA × 10 cores, 25 MB L3 each,
    /// ~68 GB/s/socket theoretical (≈50 sustained each).
    pub fn haswell20() -> Platform {
        Platform {
            topo: Topology::from_clusters(
                "haswell20",
                &[(10, "haswell", 25 << 20), (10, "haswell", 25 << 20)],
            ),
            dram_bw_gbps: 100.0,
            episodes: EpisodeSchedule::default(),
        }
    }

    /// A single-cluster homogeneous machine with `n` cores (used for the
    /// VGG-16 strong-scaling study, where the runtime sees `n` threads).
    pub fn homogeneous(n: usize) -> Platform {
        Platform {
            topo: Topology::homogeneous(n),
            dram_bw_gbps: 50.0,
            episodes: EpisodeSchedule::default(),
        }
    }

    /// Wrap an existing topology (e.g. the detected host) with generic
    /// memory-system defaults — what the real backend needs when no
    /// modelled scenario applies.
    pub fn from_topology(topo: Topology) -> Platform {
        Platform { topo, dram_bw_gbps: 50.0, episodes: EpisodeSchedule::default() }
    }

    pub fn with_episodes(mut self, eps: EpisodeSchedule) -> Platform {
        self.episodes = eps;
        self
    }

    /// Cache-overflow factor for a task of `class` running in `cluster`,
    /// given everything running there. When the sum of working sets exceeds
    /// the shared cache, sensitive kernels slow proportionally.
    fn cache_factor(&self, class: KernelClass, cluster: usize, running: &[RunningTask]) -> f64 {
        let cl = &self.topo.clusters[cluster];
        let demand: u64 = running
            .iter()
            .filter(|r| self.topo.cores[r.partition.leader].cluster == cluster)
            .map(|r| r.class.traits().working_set)
            .sum();
        if demand <= cl.cache_bytes {
            return 1.0;
        }
        // Overflowing the LLC converts hits into DRAM accesses, which cost
        // roughly MISS_PENALTY× more. The slowdown of a fully cache-bound
        // kernel is then 1 / (hit + miss·penalty); sensitivity interpolates
        // towards 1.0 for kernels that don't live in the cache.
        const MISS_PENALTY: f64 = 8.0;
        let hit_frac = cl.cache_bytes as f64 / demand as f64; // < 1
        let miss_frac = 1.0 - hit_frac;
        let full = 1.0 / (hit_frac + miss_frac * MISS_PENALTY);
        let s = class.traits().cache_sensitivity;
        (1.0 - s) + s * full
    }

    /// Bus-contention factor given total bandwidth demand at time `t`.
    fn bw_factor(&self, class: KernelClass, running: &[RunningTask], t: f64) -> f64 {
        let demand: f64 = running
            .iter()
            .map(|r| {
                let tr = r.class.traits();
                tr.bw_demand_gbps * r.partition.width.min(tr.max_parallelism) as f64
            })
            .sum::<f64>()
            + self.episodes.extra_bw(t);
        if demand <= self.dram_bw_gbps {
            return 1.0;
        }
        let share = self.dram_bw_gbps / demand; // < 1
        let m = class.traits().mem_boundedness;
        (1.0 - m) + m * share
    }

    /// Co-runner factor: cores of the same cluster that are busy with
    /// *other* TAOs degrade this task through shared LLC ways, DRAM queues
    /// and the interconnect, proportionally to the class's sensitivity.
    fn corun_factor(&self, class: KernelClass, partition: Partition, running: &[RunningTask]) -> f64 {
        let cl = self.topo.cluster_of(partition.leader);
        let other_busy: usize = running
            .iter()
            .filter(|r| {
                r.partition != partition
                    && self.topo.cores[r.partition.leader].cluster == cl.id
            })
            .map(|r| r.partition.width)
            .sum();
        if cl.len <= partition.width {
            return 1.0;
        }
        let occupancy = (other_busy as f64 / (cl.len - partition.width) as f64).min(1.0);
        1.0 - class.traits().corun_sensitivity * occupancy
    }

    /// Progress rate (work-units/second) of a task of `class` on `partition`
    /// at time `t`, given the set of running tasks (which includes itself).
    ///
    /// The partition progresses at the pace of its *slowest* member core
    /// (workers leave the TAO's internal barrier together).
    pub fn rate(
        &self,
        class: KernelClass,
        partition: Partition,
        running: &[RunningTask],
        t: f64,
    ) -> f64 {
        debug_assert!(self.topo.is_valid_partition(partition));
        let slowest_core = partition
            .cores()
            .map(|c| {
                let kind = &self.topo.cores[c].kind.0;
                class_speed(kind, class) * self.episodes.speed_factor(c, t)
            })
            .fold(f64::INFINITY, f64::min);
        let cluster = self.topo.cores[partition.leader].cluster;
        slowest_core
            * class.width_speedup(partition.width)
            * self.cache_factor(class, cluster, running)
            * self.bw_factor(class, running, t)
            * self.corun_factor(class, partition, running)
    }

    /// Convenience: uncontended execution time of one `class` task at
    /// `partition` with no episodes (used by dHEFT oracle tests).
    pub fn ideal_exec_time(&self, class: KernelClass, partition: Partition) -> f64 {
        let only = [RunningTask { class, partition }];
        class.traits().base_work / self.rate(class, partition, &only, 0.0)
    }

    /// Time to move a `bytes`-sized data item from a producer to a
    /// consumer. Within a cluster the item is still resident in the shared
    /// cache and the consumer re-reads it cache-to-cache at
    /// [`SAME_CLUSTER_BW_MULT`]× DRAM speed. Crossing clusters forces it
    /// through DRAM: a fixed hop latency plus the DRAM round trip, doubled
    /// when the item overflows the destination cluster's cache (it streams
    /// — write-out plus re-read miss traffic — instead of landing once).
    /// Zero bytes (control-only edges) are free.
    pub fn transfer_time(&self, bytes: u64, same_cluster: bool, dest_cache_bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let dram = self.dram_bw_gbps * 1e9; // bytes per second
        if same_cluster {
            bytes as f64 / (SAME_CLUSTER_BW_MULT * dram)
        } else {
            let spill =
                if dest_cache_bytes > 0 && bytes > dest_cache_bytes { 2.0 } else { 1.0 };
            CROSS_CLUSTER_LATENCY_S + spill * bytes as f64 / dram
        }
    }

    /// [`Platform::transfer_time`] between two concrete partitions: the
    /// cost of consuming on `to` an item produced on `from`.
    pub fn edge_transfer_time(&self, bytes: u64, from: Partition, to: Partition) -> f64 {
        let same =
            self.topo.cores[from.leader].cluster == self.topo.cores[to.leader].cluster;
        self.transfer_time(bytes, same, self.topo.cluster_of(to.leader).cache_bytes)
    }
}

/// Fixed latency of a cluster-crossing transfer (coherence hop + DRAM
/// round-trip setup), seconds. Dominates small items; bandwidth dominates
/// large ones.
pub const CROSS_CLUSTER_LATENCY_S: f64 = 2e-6;

/// Same-cluster transfers run cache-to-cache at this multiple of DRAM
/// bandwidth (the producer's output is still in the shared LLC).
pub const SAME_CLUSTER_BW_MULT: f64 = 8.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::CoreId;

    fn part(leader: CoreId, width: usize) -> Partition {
        Partition { leader, width }
    }

    #[test]
    fn denver_faster_at_matmul() {
        let p = Platform::tx2();
        let t_denver = p.ideal_exec_time(KernelClass::MatMul, part(0, 1));
        let t_a57 = p.ideal_exec_time(KernelClass::MatMul, part(2, 1));
        assert!((t_a57 / t_denver - 2.0).abs() < 1e-9, "ratio {}", t_a57 / t_denver);
    }

    #[test]
    fn width_speedup_monotone_and_capped() {
        for class in KernelClass::ALL {
            let mut prev = 0.0;
            for w in [1, 2, 4, 8] {
                let s = class.width_speedup(w);
                assert!(s >= prev, "{class:?} width {w}");
                prev = s;
            }
        }
        // Sort capped at 4.
        assert_eq!(
            KernelClass::Sort.width_speedup(4),
            KernelClass::Sort.width_speedup(8)
        );
    }

    #[test]
    fn wider_partition_runs_faster_per_task() {
        let p = Platform::tx2();
        let t1 = p.ideal_exec_time(KernelClass::MatMul, part(2, 1));
        let t4 = p.ideal_exec_time(KernelClass::MatMul, part(2, 4));
        assert!(t4 < t1);
    }

    #[test]
    fn sort_oversubscription_slows_cluster() {
        let p = Platform::tx2();
        // Four sorts on the a57 cluster: 4 × 524 KiB > 2 MB L2.
        let running: Vec<RunningTask> = (2..6)
            .map(|c| RunningTask { class: KernelClass::Sort, partition: part(c, 1) })
            .collect();
        let contended = p.rate(KernelClass::Sort, part(2, 1), &running, 0.0);
        let alone =
            p.rate(KernelClass::Sort, part(2, 1), &running[..1].to_vec(), 0.0);
        assert!(
            contended < 0.95 * alone,
            "cache oversubscription must slow sorts: {contended} vs {alone}"
        );
    }

    #[test]
    fn copy_tasks_contend_on_bandwidth() {
        let p = Platform::tx2();
        let many: Vec<RunningTask> = (2..6)
            .map(|c| RunningTask { class: KernelClass::Copy, partition: part(c, 1) })
            .collect();
        let contended = p.rate(KernelClass::Copy, part(2, 1), &many, 0.0);
        let alone = p.rate(KernelClass::Copy, part(2, 1), &many[..1].to_vec(), 0.0);
        assert!(contended < alone);
        // MatMul barely cares about the same contention.
        let mm_contended = p.rate(KernelClass::MatMul, part(0, 1), &many, 0.0);
        let mm_alone = p.rate(KernelClass::MatMul, part(0, 1), &[], 0.0);
        assert!(mm_contended > 0.9 * mm_alone);
    }

    #[test]
    fn interference_episode_cuts_rate_during_window_only() {
        use crate::platform::episodes::{Episode, EpisodeSchedule};
        let p = Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![
            Episode::interference(vec![0, 1], 1.0, 2.0, 0.4, 0.0),
        ]));
        let r_before = p.rate(KernelClass::MatMul, part(0, 1), &[], 0.5);
        let r_during = p.rate(KernelClass::MatMul, part(0, 1), &[], 1.5);
        let r_after = p.rate(KernelClass::MatMul, part(0, 1), &[], 2.5);
        assert!((r_during / r_before - 0.4).abs() < 1e-9);
        assert_eq!(r_before, r_after);
        // Unaffected core keeps full rate.
        let r_other = p.rate(KernelClass::MatMul, part(5, 1), &[], 1.5);
        assert_eq!(r_other, r_before);
    }

    #[test]
    fn partition_rate_limited_by_slowest_member() {
        // A hypothetical mixed cluster: if a partition spanned slow cores the
        // rate is the slow core's. On tx2 partitions never cross clusters, so
        // check via DVFS on one member.
        use crate::platform::episodes::{Episode, EpisodeSchedule};
        let p = Platform::tx2().with_episodes(EpisodeSchedule::new(vec![Episode::dvfs(
            vec![3],
            0.0,
            100.0,
            0.5,
        )]));
        let r = p.rate(KernelClass::MatMul, part(2, 2), &[], 1.0);
        let r_clean = Platform::tx2().rate(KernelClass::MatMul, part(2, 2), &[], 1.0);
        assert!((r / r_clean - 0.5).abs() < 1e-9);
    }

    #[test]
    fn ideal_exec_time_positive_for_all_classes() {
        let p = Platform::haswell20();
        for class in KernelClass::ALL {
            let t = p.ideal_exec_time(class, part(0, 1));
            assert!(t > 0.0 && t.is_finite());
        }
    }

    #[test]
    fn transfer_cost_shape() {
        let p = Platform::tx2();
        // Control edges are free.
        assert_eq!(p.transfer_time(0, false, 2 << 20), 0.0);
        // Crossing clusters costs strictly more than staying inside one.
        let in_cluster = p.edge_transfer_time(1 << 20, part(2, 1), part(4, 1));
        let cross = p.edge_transfer_time(1 << 20, part(0, 1), part(4, 1));
        assert!(in_cluster > 0.0);
        assert!(cross > in_cluster, "cross {cross} vs local {in_cluster}");
        // At least the hop latency, even for tiny items.
        assert!(p.edge_transfer_time(1, part(0, 1), part(2, 1)) >= CROSS_CLUSTER_LATENCY_S);
        // Monotone in bytes, and cache-overflowing items pay the spill.
        let small = p.transfer_time(1 << 20, false, 2 << 20);
        let big = p.transfer_time(4 << 20, false, 2 << 20);
        assert!(big > small);
        let fits = p.transfer_time(2 << 20, false, 2 << 20);
        let spills = p.transfer_time((2 << 20) + 1, false, 2 << 20);
        assert!(spills > 2.0 * fits - CROSS_CLUSTER_LATENCY_S - 1e-12, "{spills} vs {fits}");
    }

    #[test]
    fn class_roundtrip_names() {
        for c in KernelClass::ALL {
            assert_eq!(KernelClass::from_name(c.name()), Some(c));
        }
        assert_eq!(KernelClass::from_name("nope"), None);
    }
}
