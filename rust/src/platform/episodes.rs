//! Dynamic-heterogeneity episodes: time windows during which the effective
//! behaviour of a set of cores changes.
//!
//! Two families from the paper:
//! - **Interference** (§5.3): a background process time-shares some cores,
//!   cutting the CPU share our runtime gets on them and adding memory
//!   traffic. The paper's experiment runs a chain of MatMul DAGs on cores
//!   0–1 of the Haswell box.
//! - **DVFS** (§1): frequency changes scale a core's speed for *all* kernel
//!   classes.
//!
//! Both are modelled as multiplicative speed factors active on a core during
//! `[t_start, t_end)` of simulated time, plus an optional extra memory
//! bandwidth demand, and both are invisible to the scheduler — only the PTT
//! observes their effect through inflated execution times.

use super::topology::CoreId;

/// Kind of episode; affects how the performance model composes factors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpisodeKind {
    /// Time-sharing with another process: the runtime gets `speed_factor`
    /// of each affected core, and the other process adds `extra_bw_gbps`
    /// of memory traffic.
    Interference,
    /// Frequency scaling: the core runs at `speed_factor` of nominal.
    Dvfs,
}

/// One episode of dynamic heterogeneity.
#[derive(Debug, Clone)]
pub struct Episode {
    pub kind: EpisodeKind,
    /// Affected cores.
    pub cores: Vec<CoreId>,
    /// Simulated-seconds window `[t_start, t_end)`.
    pub t_start: f64,
    pub t_end: f64,
    /// Multiplicative speed factor in `(0, 1]` while active.
    pub speed_factor: f64,
    /// Additional memory-bandwidth demand (GB/s) while active.
    pub extra_bw_gbps: f64,
}

impl Episode {
    /// A background process time-sharing `cores` during `[t0, t1)`.
    /// `share` is the CPU fraction our runtime keeps (e.g. 0.5 for a
    /// same-priority spinner per core).
    pub fn interference(cores: Vec<CoreId>, t0: f64, t1: f64, share: f64, bw: f64) -> Episode {
        assert!(t1 > t0 && share > 0.0 && share <= 1.0);
        Episode {
            kind: EpisodeKind::Interference,
            cores,
            t_start: t0,
            t_end: t1,
            speed_factor: share,
            extra_bw_gbps: bw,
        }
    }

    /// A DVFS throttle of `cores` to `factor` of nominal frequency.
    pub fn dvfs(cores: Vec<CoreId>, t0: f64, t1: f64, factor: f64) -> Episode {
        assert!(t1 > t0 && factor > 0.0);
        Episode {
            kind: EpisodeKind::Dvfs,
            cores,
            t_start: t0,
            t_end: t1,
            speed_factor: factor,
            extra_bw_gbps: 0.0,
        }
    }

    pub fn active_at(&self, t: f64) -> bool {
        t >= self.t_start && t < self.t_end
    }

    pub fn affects(&self, core: CoreId) -> bool {
        self.cores.contains(&core)
    }
}

/// A schedule of episodes with boundary-time queries (the simulator needs the
/// next boundary to re-rate running tasks exactly when conditions change).
#[derive(Debug, Clone, Default)]
pub struct EpisodeSchedule {
    pub episodes: Vec<Episode>,
}

impl EpisodeSchedule {
    pub fn new(episodes: Vec<Episode>) -> EpisodeSchedule {
        EpisodeSchedule { episodes }
    }

    pub fn is_empty(&self) -> bool {
        self.episodes.is_empty()
    }

    /// Combined speed factor on `core` at time `t` (product of active
    /// episodes touching the core).
    pub fn speed_factor(&self, core: CoreId, t: f64) -> f64 {
        self.episodes
            .iter()
            .filter(|e| e.active_at(t) && e.affects(core))
            .map(|e| e.speed_factor)
            .product()
    }

    /// Extra bandwidth demand from active episodes at `t`.
    pub fn extra_bw(&self, t: f64) -> f64 {
        self.episodes.iter().filter(|e| e.active_at(t)).map(|e| e.extra_bw_gbps).sum()
    }

    /// The earliest episode boundary strictly after `t`, if any. The DES
    /// schedules a re-rate event at each boundary.
    pub fn next_boundary_after(&self, t: f64) -> Option<f64> {
        self.episodes
            .iter()
            .flat_map(|e| [e.t_start, e.t_end])
            .filter(|&b| b > t)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_semantics_half_open() {
        let e = Episode::interference(vec![0, 1], 1.0, 2.0, 0.5, 3.0);
        assert!(!e.active_at(0.99));
        assert!(e.active_at(1.0));
        assert!(e.active_at(1.99));
        assert!(!e.active_at(2.0));
    }

    #[test]
    fn speed_factor_composes() {
        let s = EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.0, 10.0, 0.5, 0.0),
            Episode::dvfs(vec![0, 1], 5.0, 10.0, 0.8),
        ]);
        assert_eq!(s.speed_factor(0, 1.0), 0.5);
        assert!((s.speed_factor(0, 6.0) - 0.4).abs() < 1e-12);
        assert_eq!(s.speed_factor(1, 6.0), 0.8);
        assert_eq!(s.speed_factor(2, 6.0), 1.0);
    }

    #[test]
    fn extra_bw_sums() {
        let s = EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.0, 10.0, 0.5, 3.0),
            Episode::interference(vec![1], 5.0, 10.0, 0.5, 2.0),
        ]);
        assert_eq!(s.extra_bw(1.0), 3.0);
        assert_eq!(s.extra_bw(6.0), 5.0);
        assert_eq!(s.extra_bw(11.0), 0.0);
    }

    #[test]
    fn next_boundary() {
        let s = EpisodeSchedule::new(vec![Episode::dvfs(vec![0], 2.0, 4.0, 0.5)]);
        assert_eq!(s.next_boundary_after(0.0), Some(2.0));
        assert_eq!(s.next_boundary_after(2.0), Some(4.0));
        assert_eq!(s.next_boundary_after(4.0), None);
        assert_eq!(EpisodeSchedule::default().next_boundary_after(0.0), None);
    }

    #[test]
    #[should_panic]
    fn rejects_empty_window() {
        Episode::dvfs(vec![0], 3.0, 3.0, 0.5);
    }
}
