//! Dynamic-heterogeneity episodes: time windows during which the effective
//! behaviour of a set of cores changes.
//!
//! Two families from the paper:
//! - **Interference** (§5.3): a background process time-shares some cores,
//!   cutting the CPU share our runtime gets on them and adding memory
//!   traffic. The paper's experiment runs a chain of MatMul DAGs on cores
//!   0–1 of the Haswell box.
//! - **DVFS** (§1): frequency changes scale a core's speed for *all* kernel
//!   classes.
//!
//! And two fault families beyond the paper (the chaos-harness extension):
//! - **FailStop**: the core dies at `t_start` — it executes nothing until
//!   the optional recovery time. Not a speed factor (a rate of 0 would
//!   break the DES re-rate invariant); substrates query
//!   [`EpisodeSchedule::fail_stopped`] instead and park/skip the core.
//! - **FailSlow**: the core keeps running but permanently (or until
//!   `t_end`) degrades to `factor` of nominal — a sick-but-alive core.
//!   Composes exactly like DVFS through `speed_factor`, so the PTT's
//!   change detector is the sensor that discovers it.
//!
//! Performance episodes are modelled as multiplicative speed factors active
//! on a core during `[t_start, t_end)` of simulated time, plus an optional
//! extra memory bandwidth demand, and are invisible to the scheduler — only
//! the PTT observes their effect through inflated execution times.

use super::topology::CoreId;

/// Kind of episode; affects how the performance model composes factors.
///
/// Carries `f64` payloads, so `Eq` cannot be derived — compare with
/// `matches!` when only the discriminant matters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EpisodeKind {
    /// Time-sharing with another process: the runtime gets `speed_factor`
    /// of each affected core, and the other process adds `extra_bw_gbps`
    /// of memory traffic.
    Interference,
    /// Frequency scaling: the core runs at `speed_factor` of nominal.
    Dvfs,
    /// Fail-stop: the core executes nothing from `t_start` until `recover`
    /// (absolute time), or forever when `recover` is `None`.
    FailStop { recover: Option<f64> },
    /// Fail-slow: the core degrades to `factor` of nominal speed — the
    /// same payload also lives in `speed_factor` so the composition path
    /// is shared with DVFS.
    FailSlow { factor: f64 },
}

/// One episode of dynamic heterogeneity.
#[derive(Debug, Clone)]
pub struct Episode {
    pub kind: EpisodeKind,
    /// Affected cores.
    pub cores: Vec<CoreId>,
    /// Simulated-seconds window `[t_start, t_end)`. Unrecovered fail-stop
    /// episodes have `t_end == f64::INFINITY`.
    pub t_start: f64,
    pub t_end: f64,
    /// Multiplicative speed factor in `(0, 1]` while active. Fail-stop
    /// episodes keep this at 1.0 — a dead core has no rate, it has no
    /// execution at all (see [`EpisodeSchedule::fail_stopped`]).
    pub speed_factor: f64,
    /// Additional memory-bandwidth demand (GB/s) while active.
    pub extra_bw_gbps: f64,
}

impl Episode {
    /// A background process time-sharing `cores` during `[t0, t1)`.
    /// `share` is the CPU fraction our runtime keeps (e.g. 0.5 for a
    /// same-priority spinner per core).
    pub fn interference(cores: Vec<CoreId>, t0: f64, t1: f64, share: f64, bw: f64) -> Episode {
        assert!(t1 > t0 && share > 0.0 && share <= 1.0);
        Episode {
            kind: EpisodeKind::Interference,
            cores,
            t_start: t0,
            t_end: t1,
            speed_factor: share,
            extra_bw_gbps: bw,
        }
    }

    /// A DVFS throttle of `cores` to `factor` of nominal frequency.
    pub fn dvfs(cores: Vec<CoreId>, t0: f64, t1: f64, factor: f64) -> Episode {
        assert!(t1 > t0 && factor > 0.0);
        Episode {
            kind: EpisodeKind::Dvfs,
            cores,
            t_start: t0,
            t_end: t1,
            speed_factor: factor,
            extra_bw_gbps: 0.0,
        }
    }

    /// `cores` fail-stop at `t0`; with `Some(t1)` they come back at `t1`,
    /// with `None` they are gone for the rest of the run.
    pub fn fail_stop(cores: Vec<CoreId>, t0: f64, recover: Option<f64>) -> Episode {
        if let Some(t1) = recover {
            assert!(t1 > t0, "recovery must come after the failure");
        }
        Episode {
            kind: EpisodeKind::FailStop { recover },
            cores,
            t_start: t0,
            t_end: recover.unwrap_or(f64::INFINITY),
            speed_factor: 1.0,
            extra_bw_gbps: 0.0,
        }
    }

    /// `cores` fail-slow to `factor` of nominal during `[t0, t1)` (pass
    /// `f64::INFINITY` for a permanent degradation).
    pub fn fail_slow(cores: Vec<CoreId>, t0: f64, t1: f64, factor: f64) -> Episode {
        assert!(t1 > t0 && factor > 0.0 && factor < 1.0);
        Episode {
            kind: EpisodeKind::FailSlow { factor },
            cores,
            t_start: t0,
            t_end: t1,
            speed_factor: factor,
            extra_bw_gbps: 0.0,
        }
    }

    pub fn active_at(&self, t: f64) -> bool {
        t >= self.t_start && t < self.t_end
    }

    pub fn affects(&self, core: CoreId) -> bool {
        self.cores.contains(&core)
    }

    /// Is this a fault-injection episode (fail-stop or fail-slow), as
    /// opposed to a performance episode from the paper?
    pub fn is_fault(&self) -> bool {
        matches!(self.kind, EpisodeKind::FailStop { .. } | EpisodeKind::FailSlow { .. })
    }
}

/// A schedule of episodes with boundary-time queries (the simulator needs the
/// next boundary to re-rate running tasks exactly when conditions change).
#[derive(Debug, Clone, Default)]
pub struct EpisodeSchedule {
    pub episodes: Vec<Episode>,
}

impl EpisodeSchedule {
    pub fn new(episodes: Vec<Episode>) -> EpisodeSchedule {
        EpisodeSchedule { episodes }
    }

    pub fn is_empty(&self) -> bool {
        self.episodes.is_empty()
    }

    /// Combined speed factor on `core` at time `t` (product of active
    /// episodes touching the core). Fail-stop episodes are excluded — a
    /// dead core is not "slow", it is absent; see [`Self::fail_stopped`].
    pub fn speed_factor(&self, core: CoreId, t: f64) -> f64 {
        self.episodes
            .iter()
            .filter(|e| {
                !matches!(e.kind, EpisodeKind::FailStop { .. })
                    && e.active_at(t)
                    && e.affects(core)
            })
            .map(|e| e.speed_factor)
            .product()
    }

    /// Is `core` fail-stopped (dead) at time `t`?
    pub fn fail_stopped(&self, core: CoreId, t: f64) -> bool {
        self.episodes.iter().any(|e| {
            matches!(e.kind, EpisodeKind::FailStop { .. }) && e.active_at(t) && e.affects(core)
        })
    }

    /// Does any fail-stop episode touch `core` at any time?
    pub fn has_fail_stop(&self, core: CoreId) -> bool {
        self.episodes
            .iter()
            .any(|e| matches!(e.kind, EpisodeKind::FailStop { .. }) && e.affects(core))
    }

    /// Does the schedule inject any fault (fail-stop or fail-slow)?
    pub fn has_faults(&self) -> bool {
        self.episodes.iter().any(Episode::is_fault)
    }

    /// The same schedule with every fault episode stripped — the fault-free
    /// twin the chaos harness baselines against.
    pub fn without_faults(&self) -> EpisodeSchedule {
        EpisodeSchedule::new(self.episodes.iter().filter(|e| !e.is_fault()).cloned().collect())
    }

    /// Extra bandwidth demand from active episodes at `t`.
    pub fn extra_bw(&self, t: f64) -> f64 {
        self.episodes.iter().filter(|e| e.active_at(t)).map(|e| e.extra_bw_gbps).sum()
    }

    /// The earliest *finite* episode boundary strictly after `t`, if any.
    /// The DES schedules a re-rate event at each boundary; an unrecovered
    /// fail-stop has `t_end == ∞`, which is not a boundary — nothing
    /// changes there, so it must not produce an infinite-dt event.
    pub fn next_boundary_after(&self, t: f64) -> Option<f64> {
        self.episodes
            .iter()
            .flat_map(|e| [e.t_start, e.t_end])
            .filter(|&b| b > t && b.is_finite())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_semantics_half_open() {
        let e = Episode::interference(vec![0, 1], 1.0, 2.0, 0.5, 3.0);
        assert!(!e.active_at(0.99));
        assert!(e.active_at(1.0));
        assert!(e.active_at(1.99));
        assert!(!e.active_at(2.0));
    }

    #[test]
    fn speed_factor_composes() {
        let s = EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.0, 10.0, 0.5, 0.0),
            Episode::dvfs(vec![0, 1], 5.0, 10.0, 0.8),
        ]);
        assert_eq!(s.speed_factor(0, 1.0), 0.5);
        assert!((s.speed_factor(0, 6.0) - 0.4).abs() < 1e-12);
        assert_eq!(s.speed_factor(1, 6.0), 0.8);
        assert_eq!(s.speed_factor(2, 6.0), 1.0);
    }

    #[test]
    fn extra_bw_sums() {
        let s = EpisodeSchedule::new(vec![
            Episode::interference(vec![0], 0.0, 10.0, 0.5, 3.0),
            Episode::interference(vec![1], 5.0, 10.0, 0.5, 2.0),
        ]);
        assert_eq!(s.extra_bw(1.0), 3.0);
        assert_eq!(s.extra_bw(6.0), 5.0);
        assert_eq!(s.extra_bw(11.0), 0.0);
    }

    #[test]
    fn next_boundary() {
        let s = EpisodeSchedule::new(vec![Episode::dvfs(vec![0], 2.0, 4.0, 0.5)]);
        assert_eq!(s.next_boundary_after(0.0), Some(2.0));
        assert_eq!(s.next_boundary_after(2.0), Some(4.0));
        assert_eq!(s.next_boundary_after(4.0), None);
        assert_eq!(EpisodeSchedule::default().next_boundary_after(0.0), None);
    }

    #[test]
    #[should_panic]
    fn rejects_empty_window() {
        Episode::dvfs(vec![0], 3.0, 3.0, 0.5);
    }

    // ----- fault episodes -------------------------------------------------

    #[test]
    fn fail_stop_is_dead_not_slow() {
        let s = EpisodeSchedule::new(vec![Episode::fail_stop(vec![2], 1.0, None)]);
        // Dead from t=1.0 forever…
        assert!(!s.fail_stopped(2, 0.5));
        assert!(s.fail_stopped(2, 1.0));
        assert!(s.fail_stopped(2, 1e9));
        assert!(!s.fail_stopped(0, 1.0));
        // …but never a speed factor: rate stays 1.0 (substrates must not
        // model death as slowness — the DES asserts rate > 0).
        assert_eq!(s.speed_factor(2, 5.0), 1.0);
        assert!(s.has_fail_stop(2));
        assert!(!s.has_fail_stop(0));
    }

    #[test]
    fn fail_stop_with_recovery_ends_at_recover_time() {
        let s = EpisodeSchedule::new(vec![Episode::fail_stop(vec![0], 1.0, Some(3.0))]);
        assert!(s.fail_stopped(0, 2.0));
        assert!(!s.fail_stopped(0, 3.0)); // half-open: back at recovery
        assert_eq!(s.next_boundary_after(0.0), Some(1.0));
        assert_eq!(s.next_boundary_after(1.0), Some(3.0));
        assert_eq!(s.next_boundary_after(3.0), None);
    }

    #[test]
    fn unrecovered_fail_stop_has_no_end_boundary() {
        // t_end = ∞ must not surface as a boundary (the DES would compute
        // an infinite dt and wedge virtual time).
        let s = EpisodeSchedule::new(vec![Episode::fail_stop(vec![0], 2.0, None)]);
        assert_eq!(s.next_boundary_after(0.0), Some(2.0));
        assert_eq!(s.next_boundary_after(2.0), None);
    }

    #[test]
    fn fail_slow_composes_like_dvfs() {
        let s = EpisodeSchedule::new(vec![Episode::fail_slow(vec![1], 0.5, f64::INFINITY, 0.25)]);
        assert_eq!(s.speed_factor(1, 0.0), 1.0);
        assert_eq!(s.speed_factor(1, 1.0), 0.25);
        assert!(!s.fail_stopped(1, 1.0), "fail-slow is alive");
        // Permanent degradation: the onset is the only finite boundary.
        assert_eq!(s.next_boundary_after(0.0), Some(0.5));
        assert_eq!(s.next_boundary_after(0.5), None);
    }

    #[test]
    fn without_faults_strips_only_faults() {
        let s = EpisodeSchedule::new(vec![
            Episode::dvfs(vec![0], 1.0, 2.0, 0.5),
            Episode::fail_stop(vec![1], 1.0, None),
            Episode::fail_slow(vec![2], 1.0, 2.0, 0.5),
        ]);
        assert!(s.has_faults());
        let clean = s.without_faults();
        assert!(!clean.has_faults());
        assert_eq!(clean.episodes.len(), 1);
        assert!(matches!(clean.episodes[0].kind, EpisodeKind::Dvfs));
        assert!(!EpisodeSchedule::default().has_faults());
    }

    #[test]
    #[should_panic]
    fn fail_stop_rejects_recovery_before_failure() {
        Episode::fail_stop(vec![0], 3.0, Some(2.0));
    }
}
