//! Platform substrate: topology, static heterogeneity, dynamic episodes
//! (DVFS, interference) and the analytic performance model used by the
//! discrete-event simulator.
//!
//! See DESIGN.md §Substitutions: the paper's Jetson TX2 and dual-socket
//! Haswell testbeds are modelled here because the build host has one CPU
//! core. The scheduler under test never reads this module's heterogeneity
//! data — it learns everything through the PTT, as on real hardware.

pub mod detect;
pub mod episodes;
pub mod perf_model;
pub mod power;
pub mod scenarios;
pub mod topology;

pub use episodes::{Episode, EpisodeKind, EpisodeSchedule};
pub use perf_model::{
    CROSS_CLUSTER_LATENCY_S, ClassTraits, KernelClass, Platform, RunningTask,
    SAME_CLUSTER_BW_MULT,
};
pub use power::{CorePower, core_power, partition_power, run_energy};
pub use topology::{CoreDesc, CoreId, CoreKind, Cluster, Partition, Topology};
