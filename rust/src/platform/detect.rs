//! Best-effort topology detection for *real-thread* execution mode.
//!
//! The paper obtains the core-cluster/cache layout from hwloc; offline we
//! parse `/sys/devices/system/cpu` + `/proc/cpuinfo` and fall back to a
//! single homogeneous cluster. Only the real-mode runner uses this; the
//! simulator always receives an explicit [`Topology`].

use super::topology::Topology;
use std::fs;

/// Number of online logical CPUs (fallback 1).
pub fn online_cpus() -> usize {
    // std's portable query (sched_getaffinity/sysconf under the hood) —
    // avoids a libc dependency in the offline build.
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Read the last-level cache size (bytes) of cpu0, if exposed by sysfs.
pub fn llc_bytes() -> Option<u64> {
    // Highest index directory under cpu0/cache is the LLC.
    let base = "/sys/devices/system/cpu/cpu0/cache";
    let mut best: Option<(u32, u64)> = None;
    for entry in fs::read_dir(base).ok()? {
        let entry = entry.ok()?;
        let name = entry.file_name().into_string().ok()?;
        if !name.starts_with("index") {
            continue;
        }
        let level: u32 = fs::read_to_string(entry.path().join("level"))
            .ok()?
            .trim()
            .parse()
            .ok()?;
        let size_s = fs::read_to_string(entry.path().join("size")).ok()?;
        let size = parse_size(size_s.trim())?;
        if best.map_or(true, |(l, _)| level > l) {
            best = Some((level, size));
        }
    }
    best.map(|(_, s)| s)
}

/// Parse "32K" / "2048K" / "25M" style sysfs sizes.
fn parse_size(s: &str) -> Option<u64> {
    if let Some(k) = s.strip_suffix('K') {
        k.parse::<u64>().ok().map(|v| v << 10)
    } else if let Some(m) = s.strip_suffix('M') {
        m.parse::<u64>().ok().map(|v| v << 20)
    } else {
        s.parse::<u64>().ok()
    }
}

/// Detect the host as a single-cluster topology (one shared LLC). Accurate
/// multi-socket detection would read `physical_package_id` per cpu; for this
/// reproduction real mode is functional validation only, so one cluster is
/// sufficient and always safe (widths remain natural divisors).
pub fn detect() -> Topology {
    let n = online_cpus();
    let cache = llc_bytes().unwrap_or(8 << 20);
    Topology::from_clusters("host", &[(n, "generic", cache)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_cpus_positive() {
        assert!(online_cpus() >= 1);
    }

    #[test]
    fn detect_yields_valid_topology() {
        let t = detect();
        assert!(t.n_cores() >= 1);
        assert_eq!(t.clusters.len(), 1);
        assert!(!t.all_widths().is_empty());
    }

    #[test]
    fn parse_size_units() {
        assert_eq!(parse_size("32K"), Some(32 << 10));
        assert_eq!(parse_size("25M"), Some(25 << 20));
        assert_eq!(parse_size("123"), Some(123));
        assert_eq!(parse_size("x"), None);
    }
}
