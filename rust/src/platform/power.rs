//! Per-core power model and run energy accounting.
//!
//! §3.3 of the paper: *"a system trying to minimize the energy consumption
//! would instead find the best pair that minimizes energy per task"*. This
//! module provides the power numbers that make that objective computable:
//! active/idle power per core kind (typical published figures for the
//! TX2's Denver2/A57 at nominal frequency and for Haswell server cores),
//! plus energy integration over a run trace.

use super::topology::{CoreId, Topology};
use crate::coordinator::metrics::RunResult;

/// Active and idle power draw of one core, watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorePower {
    pub active_w: f64,
    pub idle_w: f64,
}

/// Power for a core kind. Unknown kinds get a generic 2 W / 0.3 W.
pub fn power_of_kind(kind: &str) -> CorePower {
    match kind {
        // Denver2: wide OoO core, ~2 W active at 2 GHz.
        "denver2" => CorePower { active_w: 2.0, idle_w: 0.25 },
        // Cortex-A57 on the TX2: ~1.1 W active.
        "a57" => CorePower { active_w: 1.1, idle_w: 0.15 },
        // Haswell server core incl. uncore share: ~5 W active.
        "haswell" => CorePower { active_w: 5.0, idle_w: 1.0 },
        _ => CorePower { active_w: 2.0, idle_w: 0.3 },
    }
}

/// Power of one core in a topology.
pub fn core_power(topo: &Topology, core: CoreId) -> CorePower {
    power_of_kind(&topo.cores[core].kind.0)
}

/// Sum of active power over a partition's cores, watts.
pub fn partition_power(topo: &Topology, partition: super::topology::Partition) -> f64 {
    partition.cores().map(|c| core_power(topo, c).active_w).sum()
}

/// Energy of a run, joules: every record charges `active × width × time`
/// on its cores; all remaining core-time is charged at idle power.
pub fn run_energy(topo: &Topology, result: &RunResult) -> f64 {
    let mut busy = vec![0.0f64; topo.n_cores()];
    let mut active_j = 0.0;
    for r in &result.records {
        let dt = r.exec_time();
        for c in r.partition.cores() {
            if c < topo.n_cores() {
                busy[c] += dt;
                active_j += core_power(topo, c).active_w * dt;
            }
        }
    }
    let idle_j: f64 = (0..topo.n_cores())
        .map(|c| core_power(topo, c).idle_w * (result.makespan - busy[c]).max(0.0))
        .sum();
    active_j + idle_j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::TraceRecord;
    use crate::platform::{KernelClass, Partition};

    fn tx2_topo() -> Topology {
        Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)])
    }

    #[test]
    fn kind_lookup() {
        assert_eq!(power_of_kind("denver2").active_w, 2.0);
        assert_eq!(power_of_kind("a57").active_w, 1.1);
        assert_eq!(power_of_kind("alien").active_w, 2.0);
    }

    #[test]
    fn partition_power_sums_members() {
        let topo = tx2_topo();
        let denver_pair = partition_power(&topo, Partition { leader: 0, width: 2 });
        assert!((denver_pair - 4.0).abs() < 1e-12);
        let a57_quad = partition_power(&topo, Partition { leader: 2, width: 4 });
        assert!((a57_quad - 4.4).abs() < 1e-12);
    }

    #[test]
    fn run_energy_active_plus_idle() {
        let topo = tx2_topo();
        let result = RunResult {
            policy: "t".into(),
            platform: "t".into(),
            makespan: 10.0,
            records: vec![TraceRecord {
                task: 0,
                app_id: 0,
                class: KernelClass::MatMul,
                type_id: 0,
                critical: false,
                partition: Partition { leader: 0, width: 1 },
                t_start: 0.0,
                t_end: 10.0,
            }],
            bound: None,
        };
        // Core 0 active 10 s at 2 W = 20 J; core 1 idle 10 s at 0.25 W;
        // cores 2-5 idle at 0.15 W.
        let want = 20.0 + 2.5 + 4.0 * 1.5;
        assert!((run_energy(&topo, &result) - want).abs() < 1e-9);
    }
}
