//! Named multi-application stream scenarios — the registry behind the
//! `stream` CLI subcommand and the interference bench.
//!
//! A stream scenario pairs a platform scenario name (resolved through
//! [`crate::platform::scenarios`]) with a seeded [`WorkloadStream`]
//! builder, so any `(backend × policy × stream-scenario)` triple is one
//! call away ([`crate::exec::run_stream_triple`]). The underlying
//! platforms are registered in the platform registry under the same
//! names, so `--platform duet-tx2` also works for single-DAG runs.
//!
//! Registered streams:
//! - `stream-pois8` — 8 small mixed-kernel apps arriving as a Poisson
//!   process on 8 homogeneous cores: the throughput/fairness smoke case.
//! - `duet-tx2` — a latency-critical serial chain co-running with a
//!   bursty high-parallelism app on the TX2 model: static heterogeneity
//!   plus co-scheduling.
//! - `bg-interferer-haswell20` — a foreground app plus a late-arriving
//!   second app on `haswell20` *with* a background-process interference
//!   episode on cores 0–1 (the paper's §5.3 Haswell experiment, grown to
//!   multi-tenant form: the scheduler sees DAG-level contention and
//!   episode-level interference at once).

use super::{AppSpec, WorkloadStream};
use crate::dag_gen::DagParams;
use crate::platform::KernelClass;

/// One registered stream scenario.
pub struct StreamScenario {
    pub name: &'static str,
    pub description: &'static str,
    /// Platform scenario name this stream is designed for (resolvable via
    /// [`crate::platform::scenarios::by_name`]).
    pub platform: &'static str,
    build: fn(u64, bool) -> WorkloadStream,
}

impl StreamScenario {
    /// Materialise the stream for a seed; `quick` shrinks the apps to
    /// smoke-test scale (CI).
    pub fn stream(&self, seed: u64, quick: bool) -> WorkloadStream {
        (self.build)(seed, quick)
    }
}

fn scale(tasks: usize, quick: bool) -> usize {
    if quick { (tasks / 4).max(12) } else { tasks }
}

fn stream_pois8(seed: u64, quick: bool) -> WorkloadStream {
    let tasks = scale(120, quick);
    WorkloadStream::poisson(8, 0.02, seed, move |_i, s| DagParams::mix(tasks, 4.0, s))
}

fn duet_tx2(seed: u64, quick: bool) -> WorkloadStream {
    // App A: a serial MatMul chain — every task on the critical path, the
    // shape the PTT scheduler wins on. App B: a wide mixed burst arriving
    // shortly after, stealing cores and PTT attention.
    WorkloadStream::fixed(
        vec![
            AppSpec::new(
                "chain",
                DagParams::single(KernelClass::MatMul, scale(120, quick), 1.0, seed),
                0.0,
            ),
            AppSpec::new(
                "burst",
                DagParams::mix(scale(240, quick), 8.0, seed ^ 0xb0b),
                0.02,
            ),
        ],
        seed,
    )
}

fn bg_interferer_haswell20(seed: u64, quick: bool) -> WorkloadStream {
    // Foreground app from t = 0; a second tenant arrives as the platform's
    // background-process episode starts squeezing cores 0–1 (see the
    // matching platform scenario) — DAG-level and episode-level
    // interference hit the PTT at the same time.
    WorkloadStream::fixed(
        vec![
            AppSpec::new(
                "foreground",
                DagParams::mix(scale(600, quick), 8.0, seed),
                0.0,
            ),
            AppSpec::new(
                "tenant",
                DagParams::mix(scale(300, quick), 16.0, seed ^ 0x7e4a47),
                0.05,
            ),
        ],
        seed,
    )
}

/// The static stream-scenario registry.
pub fn stream_scenarios() -> &'static [StreamScenario] {
    static SCENARIOS: &[StreamScenario] = &[
        StreamScenario {
            name: "stream-pois8",
            description: "8 mixed apps, Poisson arrivals (mean gap 20 ms) on 8 homogeneous cores",
            platform: "stream-pois8",
            build: stream_pois8,
        },
        StreamScenario {
            name: "duet-tx2",
            description: "latency-critical chain + bursty wide app co-running on the TX2 model",
            platform: "duet-tx2",
            build: duet_tx2,
        },
        StreamScenario {
            name: "bg-interferer-haswell20",
            description: "two tenants on haswell20 while a background process squeezes cores 0-1",
            platform: "bg-interferer-haswell20",
            build: bg_interferer_haswell20,
        },
    ];
    SCENARIOS
}

/// Resolve a stream scenario by name.
pub fn stream_by_name(name: &str) -> Option<&'static StreamScenario> {
    stream_scenarios().iter().find(|s| s.name == name)
}

/// Names of all registered stream scenarios.
pub fn stream_names() -> Vec<&'static str> {
    stream_scenarios().iter().map(|s| s.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::scenarios as plat_scenarios;

    #[test]
    fn registry_is_resolvable_and_platform_backed() {
        assert!(stream_names().len() >= 3);
        for s in stream_scenarios() {
            assert!(stream_by_name(s.name).is_some());
            // Every stream's platform must resolve in the platform registry.
            let plat = plat_scenarios::by_name(s.platform)
                .unwrap_or_else(|| panic!("{}: platform '{}' unregistered", s.name, s.platform));
            assert!(plat.topo.n_cores() >= 2, "{}", s.name);
        }
        assert!(stream_by_name("nope").is_none());
    }

    #[test]
    fn streams_build_and_quick_mode_shrinks() {
        for s in stream_scenarios() {
            let full = s.stream(7, false).build();
            let quick = s.stream(7, true).build();
            assert!(full.dag.len() > quick.dag.len(), "{}", s.name);
            assert!(quick.apps.len() >= 2, "{}: co-running needs ≥ 2 apps", s.name);
            // Admissions sorted, first at t = 0 (work starts immediately).
            let adm = quick.admissions();
            assert_eq!(adm[0].0, 0.0, "{}", s.name);
            for w in adm.windows(2) {
                assert!(w[0].0 <= w[1].0, "{}", s.name);
            }
        }
    }

    #[test]
    fn stream_builds_are_deterministic_per_seed() {
        let s = stream_by_name("stream-pois8").unwrap();
        let a = s.stream(11, true).build();
        let b = s.stream(11, true).build();
        assert_eq!(a.dag.len(), b.dag.len());
        assert_eq!(a.app_of, b.app_of);
        let aa: Vec<f64> = a.apps.iter().map(|x| x.arrival).collect();
        let bb: Vec<f64> = b.apps.iter().map(|x| x.arrival).collect();
        assert_eq!(aa, bb);
    }
}
