//! Multi-application workload streams (the paper's multi-tenant story).
//!
//! The paper's central claim is that the PTT detects not just per-task
//! latency but *inter-application interference*. Exercising that claim
//! needs more than one DAG per run: this module defines
//!
//! - [`AppSpec`] — one application: DAG generator parameters, an arrival
//!   time, and optional periodic re-submission;
//! - [`WorkloadStream`] — a seeded arrival process over N applications
//!   (fixed arrivals or a Poisson process);
//! - [`MultiDag`] — the materialised stream: one combined TAO-DAG whose
//!   independent components are the applications, plus the task→app map
//!   and the per-app admission schedule both engines consume
//!   ([`crate::sim::run_stream_sim`],
//!   [`crate::coordinator::run_stream_real`]).
//!
//! Admission semantics: an application is invisible to the scheduler until
//! its arrival time — its tasks hold no queue slots, train no PTT rows and
//! carry no criticality until the roots are admitted. Apps share the
//! worker pool, the PTT and (in simulation) the platform's bandwidth
//! model, so all inter-app interference emerges from contention, exactly
//! the situation the PTT is claimed to detect. See DESIGN.md §Workload
//! streams for what differs between the backends.
//!
//! The named stream registry lives in [`scenarios`].

pub mod scenarios;

use crate::coordinator::dag::{TaoDag, TaskId};
use crate::coordinator::scheduler::QosClass;
use crate::dag_gen::{DagParams, generate};
use crate::util::Pcg32;

/// One application in a workload stream.
#[derive(Debug, Clone)]
pub struct AppSpec {
    /// Human-readable label (per-app metric rows are keyed by it).
    pub name: String,
    /// Generator parameters of the app's TAO-DAG (the spec's `seed` keeps
    /// the app reproducible independent of the stream seed).
    pub params: DagParams,
    /// Arrival (admission) time of the first submission, seconds —
    /// virtual time on the sim backend, wall time on the real backend.
    pub arrival: f64,
    /// Re-submission period for periodic apps (`None` = submit once).
    pub period: Option<f64>,
    /// Total number of submissions (≥ 1; ignored unless `period` is set).
    pub copies: usize,
    /// QoS class of every submission of this spec (serving mode; the
    /// finite-stream paths ignore it). Defaults to [`QosClass::Batch`].
    pub qos: QosClass,
}

impl AppSpec {
    pub fn new(name: impl Into<String>, params: DagParams, arrival: f64) -> AppSpec {
        assert!(arrival >= 0.0, "arrival times must be non-negative");
        AppSpec {
            name: name.into(),
            params,
            arrival,
            period: None,
            copies: 1,
            qos: QosClass::default(),
        }
    }

    /// Make the app periodic: `copies` submissions spaced `period` apart,
    /// each a fresh DAG instance (distinct generator seed per copy).
    pub fn periodic(mut self, period: f64, copies: usize) -> AppSpec {
        assert!(period > 0.0, "period must be positive");
        assert!(copies >= 1, "at least one submission");
        self.period = Some(period);
        self.copies = copies;
        self
    }

    /// Set the QoS class (serving mode backpressure + SLO accounting).
    pub fn with_qos(mut self, qos: QosClass) -> AppSpec {
        self.qos = qos;
        self
    }

    /// Number of submissions this spec expands to.
    fn submissions(&self) -> usize {
        if self.period.is_some() { self.copies.max(1) } else { 1 }
    }
}

/// A seeded stream of applications over a shared platform.
#[derive(Debug, Clone)]
pub struct WorkloadStream {
    pub apps: Vec<AppSpec>,
    /// Stream seed (reserved for stream-level randomness; the arrival
    /// draws of [`WorkloadStream::poisson`] already consumed it).
    pub seed: u64,
}

impl WorkloadStream {
    /// A stream with explicitly specified applications.
    pub fn fixed(apps: Vec<AppSpec>, seed: u64) -> WorkloadStream {
        assert!(!apps.is_empty(), "a stream needs at least one application");
        WorkloadStream { apps, seed }
    }

    /// A Poisson arrival process: `n_apps` applications, exponential
    /// inter-arrival gaps with the given mean, first app at `t = 0`.
    /// `mk(i, seed_i)` builds the i-th app's DAG parameters from a
    /// per-app seed derived from the stream seed.
    pub fn poisson(
        n_apps: usize,
        mean_gap: f64,
        seed: u64,
        mk: impl Fn(usize, u64) -> DagParams,
    ) -> WorkloadStream {
        assert!(n_apps >= 1, "a stream needs at least one application");
        assert!(mean_gap > 0.0, "mean inter-arrival gap must be positive");
        let mut rng = Pcg32::new(seed, 0x57ea);
        let mut t = 0.0f64;
        let mut apps = Vec::with_capacity(n_apps);
        for i in 0..n_apps {
            if i > 0 {
                // Inverse-CDF exponential draw; gen_f64() < 1 so ln(1-u)
                // is finite and the gap is non-negative.
                t += -mean_gap * (1.0 - rng.gen_f64()).ln();
            }
            let app_seed = rng.next_u64();
            apps.push(AppSpec::new(format!("app{i}"), mk(i, app_seed), t));
        }
        WorkloadStream { apps, seed }
    }

    /// Total number of DAG submissions (periodic specs expand).
    pub fn n_submissions(&self) -> usize {
        self.apps.iter().map(|a| a.submissions()).sum()
    }

    /// Arrival times of every submission, sorted ascending.
    pub fn arrivals(&self) -> Vec<f64> {
        let mut out: Vec<f64> = self
            .apps
            .iter()
            .flat_map(|a| {
                let period = a.period.unwrap_or(0.0);
                (0..a.submissions()).map(move |k| a.arrival + period * k as f64)
            })
            .collect();
        out.sort_by(|x, y| x.partial_cmp(y).unwrap());
        out
    }

    /// Materialise the stream into one combined DAG plus the admission
    /// schedule. Deterministic: the same stream builds the same
    /// [`MultiDag`] every time, which is what makes same-seed stream runs
    /// reproducible on the sim backend.
    pub fn build(&self) -> MultiDag {
        assert!(!self.apps.is_empty(), "a stream needs at least one application");
        // Expand periodic specs into (arrival, spec, copy#) submissions,
        // sorted by arrival (stable: ties keep spec order).
        let mut subs: Vec<(f64, &AppSpec, usize)> = Vec::with_capacity(self.n_submissions());
        for spec in &self.apps {
            let period = spec.period.unwrap_or(0.0);
            for k in 0..spec.submissions() {
                subs.push((spec.arrival + period * k as f64, spec, k));
            }
        }
        subs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let mut dag = TaoDag::new();
        let mut app_of: Vec<usize> = Vec::new();
        let mut apps: Vec<AdmittedApp> = Vec::with_capacity(subs.len());
        for (app_id, (arrival, spec, copy)) in subs.into_iter().enumerate() {
            let mut params = spec.params.clone();
            // Copy 0 keeps the spec's own seed so a single-submission app
            // is bit-identical to `generate(&spec.params)` — the parity
            // anchor of the stream path. Later copies derive fresh seeds.
            params.seed ^= (copy as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let (sub, _) = generate(&params);
            let offset = dag.len();
            for node in &sub.nodes {
                let id = dag.add_task_payload(
                    node.class,
                    node.type_id,
                    node.work_scale,
                    node.payload.clone(),
                );
                debug_assert_eq!(id, offset + node.id);
                app_of.push(app_id);
            }
            // Node-major edge replay preserves each node's successor order,
            // which criticality hand-off (cp_child) depends on.
            for node in &sub.nodes {
                for &succ in &node.succs {
                    dag.add_edge(offset + node.id, offset + succ);
                }
            }
            let name = if copy == 0 {
                spec.name.clone()
            } else {
                format!("{}#{copy}", spec.name)
            };
            apps.push(AdmittedApp {
                app_id,
                name,
                arrival,
                params,
                qos: spec.qos,
                task_range: (offset, offset + sub.len()),
                roots: sub.roots().into_iter().map(|r| offset + r).collect(),
            });
        }
        dag.finalize().expect("independent app components are acyclic");
        MultiDag { dag, app_of, apps }
    }
}

/// One admitted DAG submission inside a [`MultiDag`].
#[derive(Debug, Clone)]
pub struct AdmittedApp {
    /// Dense submission index — the `app_id` tagged onto trace records.
    pub app_id: usize,
    pub name: String,
    pub arrival: f64,
    /// The exact generator parameters of this submission (periodic copies
    /// differ in seed) — enough to regenerate the app's DAG for an
    /// isolated baseline run.
    pub params: DagParams,
    /// QoS class inherited from the spec (serving-mode backpressure tier).
    pub qos: QosClass,
    /// Global task-id range `[lo, hi)` of this app inside the combined DAG.
    pub task_range: (usize, usize),
    /// Global ids of the app's root tasks (admitted at `arrival`).
    pub roots: Vec<TaskId>,
}

impl AdmittedApp {
    pub fn n_tasks(&self) -> usize {
        self.task_range.1 - self.task_range.0
    }
}

/// A materialised workload stream: one combined DAG, the task→app map, and
/// the admission schedule, in the exact shape the engines consume.
#[derive(Debug)]
pub struct MultiDag {
    pub dag: TaoDag,
    /// `app_of[task]` = submission index owning that task.
    pub app_of: Vec<usize>,
    /// Submissions sorted by arrival time.
    pub apps: Vec<AdmittedApp>,
}

impl MultiDag {
    /// Admission schedule in engine form: `(arrival, roots)` per app,
    /// sorted by arrival.
    pub fn admissions(&self) -> Vec<(f64, Vec<TaskId>)> {
        self.apps.iter().map(|a| (a.arrival, a.roots.clone())).collect()
    }

    /// `(app_id, name, arrival)` triples for per-app metric assembly.
    pub fn app_index(&self) -> Vec<(usize, String, f64)> {
        self.apps.iter().map(|a| (a.app_id, a.name.clone(), a.arrival)).collect()
    }

    /// Per-app QoS classes in `app_id` order — the exact shape
    /// [`crate::coordinator::SchedCore::with_app_qos`] consumes.
    pub fn app_qos(&self) -> Vec<QosClass> {
        self.apps.iter().map(|a| a.qos).collect()
    }

    /// The serving-mode offer schedule in the shape
    /// [`crate::coordinator::ServingSource`] consumes.
    pub fn serving_apps(&self) -> Vec<crate::coordinator::ServingApp> {
        self.apps
            .iter()
            .map(|a| crate::coordinator::ServingApp {
                app_id: a.app_id,
                arrival: a.arrival,
                qos: a.qos,
                roots: a.roots.clone(),
                n_tasks: a.n_tasks(),
            })
            .collect()
    }
}

/// One tenant of a serving workload: a DAG template, a QoS class, and a
/// relative share of the arrival stream.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// DAG template; each admitted instance rederives `params.seed` from
    /// the stream rng so instances are distinct but reproducible.
    pub params: DagParams,
    pub qos: QosClass,
    /// Relative arrival weight (> 0); a tenant with weight 2 receives
    /// twice the arrivals of a tenant with weight 1.
    pub weight: f64,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>, params: DagParams, qos: QosClass) -> TenantSpec {
        TenantSpec { name: name.into(), params, qos, weight: 1.0 }
    }

    pub fn with_weight(mut self, weight: f64) -> TenantSpec {
        assert!(weight > 0.0 && weight.is_finite(), "tenant weight must be positive");
        self.weight = weight;
        self
    }
}

/// An open-loop multi-tenant arrival generator for serving mode.
///
/// Unlike [`WorkloadStream`] — a *finite* set of applications that the
/// engines run to completion — a serving stream is conceptually unbounded:
/// arrivals keep coming at a target aggregate `rate` regardless of whether
/// the scheduler keeps up (that is what makes it open-loop, and why the
/// serving engines need admission backpressure at all). [`window`]
/// materialises a bounded horizon of the process into an ordinary
/// [`WorkloadStream`], which is how both engines and the soak tests
/// consume it: same seed + same horizon ⇒ bit-identical arrivals, tenants
/// and instance seeds.
///
/// [`window`]: ServingStream::window
#[derive(Debug, Clone)]
pub struct ServingStream {
    pub tenants: Vec<TenantSpec>,
    /// Target aggregate admission rate, apps/second (virtual seconds on
    /// the sim backend, wall seconds on the real backend).
    pub rate: f64,
    pub seed: u64,
}

impl ServingStream {
    pub fn new(tenants: Vec<TenantSpec>, rate: f64, seed: u64) -> ServingStream {
        assert!(!tenants.is_empty(), "a serving stream needs at least one tenant");
        assert!(rate > 0.0 && rate.is_finite(), "admission rate must be positive");
        ServingStream { tenants, rate, seed }
    }

    /// Materialise arrivals in `[0, horizon)`: a Poisson process at the
    /// aggregate rate, each arrival assigned to a tenant by weighted draw,
    /// each instance given a fresh generator seed. Always yields at least
    /// one app (tenant 0 at t = 0) so a tiny horizon still runs.
    pub fn window(&self, horizon: f64) -> WorkloadStream {
        assert!(horizon > 0.0, "horizon must be positive");
        let total_weight: f64 = self.tenants.iter().map(|t| t.weight).sum();
        let mut rng = Pcg32::new(self.seed, 0x5e7e);
        let mut apps: Vec<AppSpec> = Vec::new();
        let mut per_tenant = vec![0usize; self.tenants.len()];
        let mut t = 0.0f64;
        loop {
            // Exponential inter-arrival gap at the aggregate rate.
            t += -(1.0 - rng.gen_f64()).ln() / self.rate;
            if t >= horizon {
                break;
            }
            // Weighted tenant pick (cumulative scan; tenant lists are short).
            let mut u = rng.gen_f64() * total_weight;
            let mut which = self.tenants.len() - 1;
            for (i, tenant) in self.tenants.iter().enumerate() {
                u -= tenant.weight;
                if u < 0.0 {
                    which = i;
                    break;
                }
            }
            let tenant = &self.tenants[which];
            let mut params = tenant.params.clone();
            params.seed = rng.next_u64();
            let k = per_tenant[which];
            per_tenant[which] += 1;
            apps.push(
                AppSpec::new(format!("{}#{k}", tenant.name), params, t)
                    .with_qos(tenant.qos),
            );
        }
        if apps.is_empty() {
            let tenant = &self.tenants[0];
            apps.push(
                AppSpec::new(
                    format!("{}#0", tenant.name),
                    tenant.params.clone(),
                    0.0,
                )
                .with_qos(tenant.qos),
            );
        }
        WorkloadStream::fixed(apps, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::KernelClass;

    #[test]
    fn fixed_stream_builds_combined_dag() {
        let stream = WorkloadStream::fixed(
            vec![
                AppSpec::new("a", DagParams::mix(30, 2.0, 1), 0.0),
                AppSpec::new("b", DagParams::mix(21, 4.0, 2), 0.5),
            ],
            7,
        );
        let multi = stream.build();
        assert_eq!(multi.dag.len(), 51);
        assert_eq!(multi.app_of.len(), 51);
        assert_eq!(multi.apps.len(), 2);
        assert_eq!(multi.apps[0].task_range, (0, 30));
        assert_eq!(multi.apps[1].task_range, (30, 51));
        // Every root belongs to the right range and the app map agrees.
        for app in &multi.apps {
            for &r in &app.roots {
                assert!(r >= app.task_range.0 && r < app.task_range.1);
                assert_eq!(multi.app_of[r], app.app_id);
            }
        }
        // Combined roots = union of per-app roots.
        assert_eq!(
            multi.dag.roots().len(),
            multi.apps.iter().map(|a| a.roots.len()).sum::<usize>()
        );
    }

    #[test]
    fn single_app_component_matches_standalone_generate() {
        // The parity anchor: app 0's component must be structurally
        // identical to generating the DAG directly.
        let params = DagParams::mix(40, 4.0, 99);
        let stream =
            WorkloadStream::fixed(vec![AppSpec::new("solo", params.clone(), 0.0)], 0);
        let multi = stream.build();
        let (direct, _) = generate(&params);
        assert_eq!(multi.dag.len(), direct.len());
        for (a, b) in multi.dag.nodes.iter().zip(&direct.nodes) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.type_id, b.type_id);
            assert_eq!(a.succs, b.succs);
            assert_eq!(a.criticality, b.criticality);
            assert_eq!(a.cp_child, b.cp_child);
        }
        assert_eq!(multi.dag.roots(), direct.roots());
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_seeded() {
        let mk = |_i: usize, s: u64| DagParams::mix(10, 2.0, s);
        let s1 = WorkloadStream::poisson(6, 0.05, 42, mk);
        let s2 = WorkloadStream::poisson(6, 0.05, 42, mk);
        let a1 = s1.arrivals();
        assert_eq!(a1.len(), 6);
        assert_eq!(a1[0], 0.0);
        for w in a1.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(a1, s2.arrivals(), "same seed, same arrivals");
        let s3 = WorkloadStream::poisson(6, 0.05, 43, mk);
        assert_ne!(a1, s3.arrivals(), "different seed, different arrivals");
    }

    #[test]
    fn periodic_spec_expands_into_copies_with_distinct_seeds() {
        let spec = AppSpec::new(
            "tick",
            DagParams::single(KernelClass::Sort, 8, 1.0, 5),
            0.1,
        )
        .periodic(0.2, 3);
        let stream = WorkloadStream::fixed(vec![spec], 0);
        assert_eq!(stream.n_submissions(), 3);
        let multi = stream.build();
        assert_eq!(multi.apps.len(), 3);
        assert_eq!(multi.dag.len(), 24);
        let arr: Vec<f64> = multi.apps.iter().map(|a| a.arrival).collect();
        for (got, want) in arr.iter().zip([0.1, 0.3, 0.5]) {
            assert!((got - want).abs() < 1e-12, "{arr:?}");
        }
        assert_eq!(multi.apps[0].name, "tick");
        assert_eq!(multi.apps[1].name, "tick#1");
        // Copies carry distinct generator seeds.
        assert_ne!(multi.apps[0].params.seed, multi.apps[1].params.seed);
        assert_ne!(multi.apps[1].params.seed, multi.apps[2].params.seed);
    }

    #[test]
    fn admissions_sorted_even_when_specs_are_not() {
        let stream = WorkloadStream::fixed(
            vec![
                AppSpec::new("late", DagParams::mix(10, 2.0, 1), 0.9),
                AppSpec::new("early", DagParams::mix(10, 2.0, 2), 0.1),
            ],
            0,
        );
        let multi = stream.build();
        assert_eq!(multi.apps[0].name, "early");
        assert_eq!(multi.apps[1].name, "late");
        let adm = multi.admissions();
        assert!(adm[0].0 <= adm[1].0);
    }

    #[test]
    #[should_panic]
    fn negative_arrival_rejected() {
        AppSpec::new("x", DagParams::mix(10, 2.0, 1), -1.0);
    }

    #[test]
    fn qos_defaults_to_batch_and_flows_into_the_multidag() {
        let stream = WorkloadStream::fixed(
            vec![
                AppSpec::new("plain", DagParams::mix(10, 2.0, 1), 0.0),
                AppSpec::new("rt", DagParams::mix(10, 2.0, 2), 0.1)
                    .with_qos(QosClass::Latency),
                AppSpec::new("scav", DagParams::mix(10, 2.0, 3), 0.2)
                    .with_qos(QosClass::BestEffort),
            ],
            0,
        );
        let multi = stream.build();
        assert_eq!(
            multi.app_qos(),
            vec![QosClass::Batch, QosClass::Latency, QosClass::BestEffort]
        );
    }

    #[test]
    fn serving_window_is_deterministic_and_tracks_the_target_rate() {
        let tenants = vec![
            TenantSpec::new("rt", DagParams::mix(8, 2.0, 1), QosClass::Latency),
            TenantSpec::new("bulk", DagParams::mix(16, 4.0, 2), QosClass::Batch)
                .with_weight(2.0),
            TenantSpec::new("scav", DagParams::mix(8, 2.0, 3), QosClass::BestEffort),
        ];
        let serving = ServingStream::new(tenants.clone(), 50.0, 42);
        let w1 = serving.window(4.0);
        let w2 = ServingStream::new(tenants, 50.0, 42).window(4.0);
        assert_eq!(w1.arrivals(), w2.arrivals(), "same seed, same window");
        assert_eq!(
            w1.apps.iter().map(|a| a.name.clone()).collect::<Vec<_>>(),
            w2.apps.iter().map(|a| a.name.clone()).collect::<Vec<_>>(),
        );
        // Poisson(rate * horizon) = Poisson(200): the count should land
        // well within ±50% (≈ 7σ) of the mean — loose enough to never
        // flake, tight enough to catch a rate bug.
        let n = w1.apps.len() as f64;
        assert!((100.0..=300.0).contains(&n), "got {n} arrivals, expected ≈ 200");
        // Arrivals are monotone and inside the horizon.
        let arr = w1.arrivals();
        assert!(arr.iter().all(|&t| (0.0..4.0).contains(&t)));
        for w in arr.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // The weight-2 tenant should dominate; all three appear.
        let count = |pat: &str| w1.apps.iter().filter(|a| a.name.starts_with(pat)).count();
        let (rt, bulk, scav) = (count("rt#"), count("bulk#"), count("scav#"));
        assert!(rt > 0 && bulk > 0 && scav > 0, "rt={rt} bulk={bulk} scav={scav}");
        assert!(bulk > rt && bulk > scav, "rt={rt} bulk={bulk} scav={scav}");
        // Instances of one tenant carry distinct generator seeds.
        let seeds: std::collections::HashSet<u64> = w1
            .apps
            .iter()
            .filter(|a| a.name.starts_with("bulk#"))
            .map(|a| a.params.seed)
            .collect();
        assert_eq!(seeds.len(), bulk, "every instance reseeded");
    }

    #[test]
    fn serving_window_never_comes_up_empty() {
        let serving = ServingStream::new(
            vec![TenantSpec::new("t", DagParams::mix(8, 2.0, 1), QosClass::Latency)],
            0.001, // ~1 arrival per 1000 s: a short window draws none.
            7,
        );
        let w = serving.window(0.01);
        assert_eq!(w.apps.len(), 1);
        assert_eq!(w.apps[0].arrival, 0.0);
        assert_eq!(w.apps[0].qos, QosClass::Latency);
    }
}
