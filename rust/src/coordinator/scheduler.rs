//! Scheduling policies (§3.3 and the §6 baselines).
//!
//! A policy answers one question: *when core C pops a ready TAO from its
//! work-stealing queue, which partition `(leader, width)` should execute
//! it?* The decision is made **before** the TAO is inserted into assembly
//! queues and is irrevocable afterwards (§3.1).
//!
//! Implemented policies:
//! - [`PerformanceBased`] — the paper's contribution: critical tasks search
//!   the PTT globally for the `(core, width)` minimising
//!   `exec_time × width`; non-critical tasks only pick the best width of
//!   the partition containing the current core.
//! - [`HomogeneousWs`] — the baseline the paper compares against (§5.1):
//!   XiTAO's default random work stealing, width 1, PTT-unaware.
//! - [`CatsLike`] — a CATS-style criticality-aware baseline (§6): critical
//!   tasks go to the empirically fastest cluster ("big"), width fixed at 1.
//! - [`DheftLike`] — a dynamic-HEFT-style baseline (§6): earliest-finish-
//!   time placement from learned width-1 latencies, width fixed at 1.
//!
//! All policies are `Sync`; mutable baseline state (round-robin cursors,
//! core-availability estimates) uses atomics.

use super::ptt::Ptt;
use crate::platform::{CoreId, Partition, Topology};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Per-tenant quality-of-service class, carried from
/// [`crate::workload::AppSpec`] through the scheduling core into every
/// placement decision ([`PlaceCtx::qos`]) and into the serving layer's
/// admission-backpressure ordering.
///
/// The variants are in **priority order** (`Latency` highest): the serving
/// admission path sheds/delays strictly from the bottom of this order
/// (`BestEffort` is shed, `Batch` is delayed, `Latency` is always
/// admitted), and the derived `Ord` encodes exactly that ranking.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum QosClass {
    /// Interactive traffic with a tight per-app latency SLO; never shed or
    /// delayed by admission backpressure.
    Latency,
    /// Throughput-oriented work with a loose SLO; delayed (re-offered
    /// later) under pressure, never shed.
    #[default]
    Batch,
    /// Scavenger work with no SLO; first (and only) class to be shed.
    BestEffort,
}

impl QosClass {
    /// All classes, in priority order (index = [`QosClass::index`]).
    pub const ALL: [QosClass; 3] = [QosClass::Latency, QosClass::Batch, QosClass::BestEffort];

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Latency => "latency",
            QosClass::Batch => "batch",
            QosClass::BestEffort => "besteffort",
        }
    }

    pub fn by_name(name: &str) -> Option<QosClass> {
        match name {
            "latency" => Some(QosClass::Latency),
            "batch" => Some(QosClass::Batch),
            "besteffort" | "best-effort" => Some(QosClass::BestEffort),
            _ => None,
        }
    }

    /// Position in [`QosClass::ALL`] (stable per-class array index for
    /// counters and reports).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Default per-class SLO target, expressed as a slowdown bound
    /// (response time / isolated makespan). An app *attains* its SLO when
    /// its observed slowdown stays at or below this. Best-effort work has
    /// no SLO (`INFINITY` — trivially attained).
    pub fn slo_slowdown(self) -> f64 {
        match self {
            QosClass::Latency => 2.0,
            QosClass::Batch => 8.0,
            QosClass::BestEffort => f64::INFINITY,
        }
    }
}

/// Task-side half of a placement decision: *what* is being placed.
///
/// Grouped so [`PlaceCtx::new`] is the single construction seam for the
/// policy input — adding a field here breaks every call site at compile
/// time instead of silently defaulting through a struct literal (the
/// literal churn that caused the missing-`qos` bug fixed in 6a05946).
#[derive(Debug, Clone, Copy)]
pub struct TaskView {
    /// Task id within the running DAG (global id for multi-app streams).
    /// Online policies ignore it; the plan-ahead policies
    /// ([`super::list_sched::PlannedPolicy`]) use it to replay a
    /// precomputed whole-DAG assignment.
    pub task: usize,
    /// TAO type (PTT row group).
    pub type_id: usize,
    /// Criticality as determined at wake-up time (§3.3; initial tasks are
    /// non-critical).
    pub critical: bool,
    /// Moldability cap ([`super::dag::TaoNode::max_width`]): the widest
    /// partition the kernel can exploit. Elastic policies never choose a
    /// wider one; width-1 policies ignore it.
    pub max_width: usize,
    /// Submitting application (0 for single-DAG runs). Policies may use
    /// the app dimension to reason about co-running workloads — e.g. to
    /// compare how [`PerformanceBased`] isolates a foreground app from an
    /// interfering stream versus the app-blind baselines.
    pub app_id: usize,
    /// The submitting application's QoS class ([`QosClass::default`] for
    /// finite experiment runs — only the serving layer assigns classes).
    pub qos: QosClass,
}

/// Engine-side half of a placement decision: *who* decides, with what
/// learned state, at what time.
pub struct EngineView<'a> {
    /// Core making the decision (the one that popped/stole the task).
    pub core: CoreId,
    pub ptt: &'a Ptt,
    pub topo: &'a Topology,
    /// Engine time in seconds (virtual in sim, wall in real mode).
    pub now: f64,
}

/// Everything a policy may consult when placing one task. Built **only**
/// through [`PlaceCtx::new`] — no struct literals at call sites (the
/// repo's tests grep-enforce this), so the two grouped views stay the
/// whole construction vocabulary.
pub struct PlaceCtx<'a> {
    /// Core making the decision (the one that popped/stole the task).
    pub core: CoreId,
    /// See [`TaskView::task`].
    pub task: usize,
    /// TAO type (PTT row group).
    pub type_id: usize,
    /// See [`TaskView::critical`].
    pub critical: bool,
    /// See [`TaskView::max_width`].
    pub max_width: usize,
    /// See [`TaskView::app_id`].
    pub app_id: usize,
    /// See [`TaskView::qos`].
    pub qos: QosClass,
    pub ptt: &'a Ptt,
    pub topo: &'a Topology,
    /// Engine time in seconds (virtual in sim, wall in real mode).
    pub now: f64,
}

impl<'a> PlaceCtx<'a> {
    /// The required constructor: the task half and the engine half, no
    /// field soup. Keep this the only `PlaceCtx { .. }` literal in the
    /// tree.
    pub fn new(task: TaskView, engine: EngineView<'a>) -> PlaceCtx<'a> {
        PlaceCtx {
            core: engine.core,
            task: task.task,
            type_id: task.type_id,
            critical: task.critical,
            max_width: task.max_width.max(1),
            app_id: task.app_id,
            qos: task.qos,
            ptt: engine.ptt,
            topo: engine.topo,
            now: engine.now,
        }
    }
}

/// A placement policy.
pub trait Policy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Decide the partition for one ready task.
    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition;

    /// Completion hook (time bookkeeping for EFT-style baselines). Speaks
    /// the same placement vocabulary as [`Policy::place`]: the `Partition`
    /// the task actually ran on.
    fn on_complete(&self, _part: Partition, _exec_time: f64, _now: f64) {}

    /// Fairness feedback hook (serving mode): the driver periodically
    /// reports the rolling Jain index over per-app progress plus, per
    /// core, the app currently monopolising that core (`None` when no app
    /// holds a long uninterrupted run there). Default: ignored — only
    /// fairness-aware policies ([`PttServing`]) react.
    fn on_fairness(&self, _jain: f64, _monopolist: &[Option<usize>]) {}

    /// Whether the engine should bother updating the PTT (the homogeneous
    /// baseline is PTT-unaware; skipping updates mirrors its zero overhead).
    fn uses_ptt(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// Performance-based scheduler (the paper's contribution)
// ---------------------------------------------------------------------------

/// §3.3: criticality-aware, PTT-driven elastic scheduling.
#[derive(Debug, Default)]
pub struct PerformanceBased;

impl Policy for PerformanceBased {
    fn name(&self) -> &'static str {
        "performance-based"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        if ctx.critical {
            // Global search: best (core, width) anywhere on the machine.
            ctx.ptt.best_global(ctx.type_id, ctx.topo).0
        } else {
            // Local search: stay near the current core, pick only the width.
            ctx.ptt.best_width_for(ctx.type_id, ctx.core, ctx.topo).0
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive performance-based scheduler (PTT v2 change-detector aware)
// ---------------------------------------------------------------------------

/// [`PerformanceBased`] plus the PTT v2 change detector: the same
/// `time × width` searches, but placement reacts to *dynamic* heterogeneity
/// the moment the detector flags it instead of waiting for the 4:1 average
/// to re-learn.
///
/// - **Critical tasks** search globally *avoiding flagged cores* — a core
///   whose recent behaviour diverged from its long-run average (an
///   interferer arrived, DVFS kicked in, an episode ended) must not host
///   the critical path while its estimates are stale. If every partition
///   touches a flagged core the plain global search is the fallback: a
///   fully flagged machine has no safe harbour.
/// - **Non-critical tasks** normally keep the paper's cheap local width
///   search; when the *deciding core itself* is flagged the search widens
///   to the whole cluster (still never crossing it), restricted to
///   partitions touching no flagged core — with the plain local search as
///   the fallback when the entire cluster is flagged — so the task
///   escapes the interfered core without paying the global search.
///   Every [`PROBE_PERIOD`]th such decision stays local
///   instead: a flagged core whose rows stop receiving samples could never
///   reconverge (the flag would latch and the core would be exiled even
///   after the episode ends), so a deterministic trickle of non-critical
///   probes keeps the PTT fresh — the paper's own §5.3 recovery mechanism.
///
/// With no flags raised this policy makes exactly [`PerformanceBased`]'s
/// decisions (the filtered searches degenerate to the plain ones), so it
/// inherits the §3.3 exploration behaviour on untrained tables.
#[derive(Debug)]
pub struct PttAdaptive {
    /// Per-core escape counters for the non-critical probe trickle — one
    /// counter per *deciding* core, so every flagged core earns its own
    /// probes regardless of how its decisions interleave with other
    /// flagged cores' (a shared counter could park all probes on one core
    /// under an adversarial interleaving and latch the other's flag).
    /// Deterministic in the single-threaded sim; in real mode the exact
    /// interleaving is timing-dependent like every other placement input.
    probe: Vec<AtomicUsize>,
}

/// One in this many non-critical decisions on a flagged core stays local
/// (see [`PttAdaptive`]): enough refresh traffic for the estimates to
/// reconverge within a few sampling rounds, while ~75% of the work still
/// escapes the interfered core immediately.
pub const PROBE_PERIOD: usize = 4;

impl PttAdaptive {
    pub fn new(n_cores: usize) -> PttAdaptive {
        PttAdaptive { probe: (0..n_cores).map(|_| AtomicUsize::new(0)).collect() }
    }
}

impl Policy for PttAdaptive {
    fn name(&self) -> &'static str {
        "ptt-adaptive"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        // A fail-stopped core is the degenerate flagged core: avoid it in
        // every search. (The universal safety net lives in `SchedCore::
        // place`, which remaps any partition touching a dead core; this
        // keeps the adaptive policy's *first choice* off it.)
        let flagged =
            |c: crate::platform::CoreId| ctx.ptt.core_flagged(c) || ctx.ptt.core_dead(c);
        if ctx.critical {
            if let Some((p, _)) = ctx.ptt.best_global_avoiding(ctx.type_id, ctx.topo, flagged) {
                return p;
            }
            ctx.ptt.best_global(ctx.type_id, ctx.topo).0
        } else {
            let dead_here = ctx.ptt.core_dead(ctx.core);
            if ctx.ptt.core_flagged(ctx.core) || dead_here {
                // Counts 0..PERIOD-2 escape (the urgent case at an episode
                // edge); every PERIOD-th stays as a local probe so the
                // flagged core's rows keep learning. A *dead* core never
                // probes — there is nothing left there to learn about.
                let count = self.probe[ctx.core].fetch_add(1, Ordering::Relaxed);
                let stay = !dead_here && count % PROBE_PERIOD == PROBE_PERIOD - 1;
                if !stay {
                    if let Some((p, _)) = ctx.ptt.best_in_cluster_avoiding(
                        ctx.type_id,
                        ctx.core,
                        ctx.topo,
                        flagged,
                    ) {
                        return p;
                    }
                }
            }
            ctx.ptt.best_width_for(ctx.type_id, ctx.core, ctx.topo).0
        }
    }
}

// ---------------------------------------------------------------------------
// Serving variant (fairness-feedback aware)
// ---------------------------------------------------------------------------

/// Jain-index setpoint for [`PttServing`]: the monopolisation bias only
/// engages while the rolling fairness reported through
/// [`Policy::on_fairness`] sits below this.
pub const FAIRNESS_SETPOINT: f64 = 0.8;

/// [`PerformanceBased`] with fairness as a control input — the serving
/// mode's placement policy.
///
/// The serving driver periodically feeds two signals through
/// [`Policy::on_fairness`]: the rolling Jain index over per-app progress
/// (computed with the total, non-panicking
/// [`crate::coordinator::metrics::jain_fairness_total`]) and, per core,
/// which app (if any) is currently *monopolising* it — holding a long
/// uninterrupted run of completions there. While fairness sits at or above
/// [`FAIRNESS_SETPOINT`] this policy makes exactly [`PerformanceBased`]'s
/// decisions. When it dips below, tasks **of the monopolising app** are
/// biased away from the cores that app monopolises:
///
/// - critical tasks search globally avoiding those cores (plain global
///   search as the fallback when every partition touches one);
/// - non-critical tasks deciding *on* a core their own app monopolises
///   widen to the cluster avoiding such cores (plain local width search
///   as the fallback).
///
/// Only the monopolist is displaced — other tenants keep full use of the
/// machine, so the bias opens the monopolised cores to starved apps
/// instead of shuffling everyone.
#[derive(Debug)]
pub struct PttServing {
    /// Rolling fairness is below [`FAIRNESS_SETPOINT`] (bias engaged).
    fairness_low: AtomicBool,
    /// Per-core monopolising app id; `usize::MAX` = none.
    monopolist: Vec<AtomicUsize>,
}

impl PttServing {
    pub fn new(n_cores: usize) -> PttServing {
        PttServing {
            fairness_low: AtomicBool::new(false),
            monopolist: (0..n_cores).map(|_| AtomicUsize::new(usize::MAX)).collect(),
        }
    }

    fn avoids(&self, core: CoreId, app_id: usize) -> bool {
        self.monopolist[core].load(Ordering::Relaxed) == app_id
    }
}

impl Policy for PttServing {
    fn name(&self) -> &'static str {
        "ptt-serving"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        if self.fairness_low.load(Ordering::Relaxed) {
            let avoid = |c: CoreId| self.avoids(c, ctx.app_id);
            if ctx.critical {
                if let Some((p, _)) =
                    ctx.ptt.best_global_avoiding(ctx.type_id, ctx.topo, avoid)
                {
                    return p;
                }
            } else if avoid(ctx.core) {
                if let Some((p, _)) =
                    ctx.ptt.best_in_cluster_avoiding(ctx.type_id, ctx.core, ctx.topo, avoid)
                {
                    return p;
                }
            }
        }
        if ctx.critical {
            ctx.ptt.best_global(ctx.type_id, ctx.topo).0
        } else {
            ctx.ptt.best_width_for(ctx.type_id, ctx.core, ctx.topo).0
        }
    }

    fn on_fairness(&self, jain: f64, monopolist: &[Option<usize>]) {
        self.fairness_low.store(jain < FAIRNESS_SETPOINT, Ordering::Relaxed);
        for (cell, m) in self.monopolist.iter().zip(monopolist) {
            cell.store(m.unwrap_or(usize::MAX), Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic moldable-width scheduler (ROADMAP item 3)
// ---------------------------------------------------------------------------

/// Elastic width selection under the task's moldability cap — XiTAO's
/// defining mechanism (paper §2–§3) made a first-class policy.
///
/// Decision rule:
/// - **Critical tasks** search the whole machine for the partition
///   minimising `time × width`, restricted to widths ≤ the task's
///   [`TaskView::max_width`] and to partitions touching **no flagged or
///   dead core** — wide teams only form on clusters whose estimates are
///   trustworthy and uncontended. If every partition touches a flagged
///   core there is no safe harbour for a team, so the task *narrows all
///   the way to width 1* and takes the globally best single slot.
/// - **Non-critical tasks** keep the paper's cheap local search (width of
///   the partition enclosing the deciding core), capped by moldability.
///   When the deciding core itself is flagged or dead the task narrows to
///   width 1 and escapes within its cluster (a team assembled around an
///   interfered core would convoy every member on the straggler).
///
/// Narrowing triggers, in order of precedence:
/// 1. **Serving backpressure** — while the rolling Jain index reported
///    through [`Policy::on_fairness`] sits below [`FAIRNESS_SETPOINT`],
///    *every* decision is capped at width 1: under fairness pressure,
///    occupying `w` cores for one tenant's task is exactly the
///    monopolisation the serving layer is trying to undo.
/// 2. **Interference/fault flags** — per the rule above.
/// 3. **Moldability** — the kernel's own `max_width` bounds everything.
///
/// With an unflagged machine, no backpressure, and fully moldable tasks
/// this makes exactly [`PerformanceBased`]'s decisions.
#[derive(Debug)]
pub struct PttElastic {
    /// Rolling fairness is below [`FAIRNESS_SETPOINT`] (narrow to width 1).
    backpressure: AtomicBool,
}

impl PttElastic {
    pub fn new() -> PttElastic {
        PttElastic { backpressure: AtomicBool::new(false) }
    }
}

impl Default for PttElastic {
    fn default() -> PttElastic {
        PttElastic::new()
    }
}

impl Policy for PttElastic {
    fn name(&self) -> &'static str {
        "ptt-elastic"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        let cap =
            if self.backpressure.load(Ordering::Relaxed) { 1 } else { ctx.max_width };
        let flagged = |c: CoreId| ctx.ptt.core_flagged(c) || ctx.ptt.core_dead(c);
        if ctx.critical {
            if let Some((p, _)) =
                ctx.ptt.best_global_capped_avoiding(ctx.type_id, ctx.topo, cap, flagged)
            {
                return p;
            }
            // Fully flagged machine: no trustworthy home for a team.
            ctx.ptt.best_global_capped(ctx.type_id, ctx.topo, 1).0
        } else {
            if flagged(ctx.core) {
                if let Some((p, _)) = ctx.ptt.best_in_cluster_capped_avoiding(
                    ctx.type_id,
                    ctx.core,
                    ctx.topo,
                    1,
                    flagged,
                ) {
                    return p;
                }
            }
            ctx.ptt.best_width_for_capped(ctx.type_id, ctx.core, ctx.topo, cap).0
        }
    }

    fn on_fairness(&self, jain: f64, _monopolist: &[Option<usize>]) {
        self.backpressure.store(jain < FAIRNESS_SETPOINT, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Homogeneous random-work-stealing baseline
// ---------------------------------------------------------------------------

/// The "homogeneous scheduler" of §5: plain work stealing, every TAO runs
/// at width 1 on whichever core dequeued it. Load balance comes entirely
/// from random stealing; the PTT is neither read nor written.
#[derive(Debug, Default)]
pub struct HomogeneousWs;

impl Policy for HomogeneousWs {
    fn name(&self) -> &'static str {
        "homogeneous-ws"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        Partition { leader: ctx.core, width: 1 }
    }

    fn uses_ptt(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// CATS-like baseline
// ---------------------------------------------------------------------------

/// Criticality-Aware Task Scheduling, adapted: CATS routes critical tasks to
/// the "big" core cluster and the rest to "LITTLE" cores, always
/// single-threaded. Our heterogeneity-unaware variant learns which cluster
/// is fast from PTT width-1 entries instead of being told.
#[derive(Debug, Default)]
pub struct CatsLike {
    rr: AtomicUsize,
}

impl Policy for CatsLike {
    fn name(&self) -> &'static str {
        "cats-like"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        if !ctx.critical {
            return Partition { leader: ctx.core, width: 1 };
        }
        // Rank clusters by learned width-1 latency; untrained (0) clusters
        // are explored first, matching the PTT bootstrap behaviour.
        let mut best_cluster = ctx.topo.cores[ctx.core].cluster;
        let mut best_t = f64::INFINITY;
        for cl in &ctx.topo.clusters {
            let t = ctx.ptt.cluster_width1_estimate(ctx.type_id, ctx.topo, cl.id);
            if t < best_t {
                best_t = t;
                best_cluster = cl.id;
            }
        }
        // Round-robin across the chosen cluster's cores (CATS's critical
        // queue feeds all big cores).
        let cl = &ctx.topo.clusters[best_cluster];
        let off = self.rr.fetch_add(1, Ordering::Relaxed) % cl.len;
        Partition { leader: cl.first_core + off, width: 1 }
    }
}

// ---------------------------------------------------------------------------
// dHEFT-like baseline
// ---------------------------------------------------------------------------

/// Dynamic HEFT: place every task on the core with the earliest predicted
/// finish time, using learned per-core width-1 latencies and a per-core
/// availability clock. Criticality is ignored (HEFT ranks ahead of time;
/// dynamically the EFT rule is the essence).
pub struct DheftLike {
    /// Per-core next-free-time estimates, f64 bit-cast.
    avail: Vec<AtomicU64>,
}

impl DheftLike {
    pub fn new(n_cores: usize) -> DheftLike {
        DheftLike { avail: (0..n_cores).map(|_| AtomicU64::new(0f64.to_bits())).collect() }
    }

    fn avail_of(&self, c: CoreId) -> f64 {
        f64::from_bits(self.avail[c].load(Ordering::Relaxed))
    }

    fn bump(&self, c: CoreId, until: f64) {
        self.avail[c].store(until.to_bits(), Ordering::Relaxed);
    }
}

impl Policy for DheftLike {
    fn name(&self) -> &'static str {
        "dheft-like"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        let mut best = Partition { leader: ctx.core, width: 1 };
        let mut best_finish = f64::INFINITY;
        for c in 0..ctx.topo.n_cores() {
            let est = ctx.ptt.read(ctx.type_id, c, 1); // 0 ⇒ explore
            let finish = self.avail_of(c).max(ctx.now) + est;
            if finish < best_finish {
                best_finish = finish;
                best = Partition { leader: c, width: 1 };
            }
        }
        // Reserve the slot optimistically; corrected on completion.
        self.bump(best.leader, best_finish);
        best
    }

    fn on_complete(&self, part: Partition, _exec_time: f64, now: f64) {
        // The task finished; the core is free from `now` (the optimistic
        // reservation may have drifted under contention).
        let cur = self.avail_of(part.leader);
        if now > cur {
            self.bump(part.leader, now);
        }
    }
}

// ---------------------------------------------------------------------------
// Energy-minimizing variant (§3.3's alternative objective)
// ---------------------------------------------------------------------------

/// The paper's stated alternative: "a system trying to minimize the energy
/// consumption would instead find the best pair that minimizes energy per
/// task". Identical structure to [`PerformanceBased`], but the search cost
/// is `exec_time × Σ active-power(partition cores)` (joules per task)
/// instead of `exec_time × width`.
#[derive(Debug, Default)]
pub struct EnergyMinimizing;

impl EnergyMinimizing {
    fn energy_cost(ptt: &Ptt, ctx: &PlaceCtx<'_>, p: Partition) -> f64 {
        let t = ptt.read(ctx.type_id, p.leader, p.width);
        t * crate::platform::partition_power(ctx.topo, p)
    }
}

impl Policy for EnergyMinimizing {
    fn name(&self) -> &'static str {
        "energy-minimizing"
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        if ctx.critical {
            let mut best: Option<(Partition, f64)> = None;
            for p in ctx.topo.all_partitions() {
                let cost = Self::energy_cost(ctx.ptt, ctx, p);
                match best {
                    Some((_, c)) if c <= cost => {}
                    _ => best = Some((p, cost)),
                }
            }
            best.expect("at least one partition").0
        } else {
            let cluster = ctx.topo.cluster_of(ctx.core);
            let mut best: Option<(Partition, f64)> = None;
            for w in cluster.valid_widths() {
                let p = ctx.topo.enclosing_partition(ctx.core, w).expect("valid width");
                let cost = Self::energy_cost(ctx.ptt, ctx, p);
                match best {
                    Some((_, c)) if c <= cost => {}
                    _ => best = Some((p, cost)),
                }
            }
            best.expect("width 1 always valid").0
        }
    }
}

/// One row of the policy registry: canonical name (what [`Policy::name`]
/// reports), the CLI aliases accepted by [`policy_by_name`], and a
/// one-line description (`repro policies` prints this table).
#[derive(Debug, Clone, Copy)]
pub struct PolicyInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Width capability: `"1"` = always width 1; `"all"` = PTT searches
    /// over every valid width, ignoring the task's moldability cap;
    /// `"elastic"` = searches over widths *capped by task moldability*
    /// with narrowing triggers; `"plan"` = an offline plan fixes each
    /// task's partition (any width) ahead of time. Listed by
    /// `repro policies` so capability and behavior cannot drift.
    pub widths: &'static str,
    pub description: &'static str,
}

/// The policy registry, in presentation order. [`policy_by_name`] resolves
/// through this same table, so the CLI listing and the accepted names
/// cannot drift.
pub const POLICIES: [PolicyInfo; 12] = [
    PolicyInfo {
        name: "performance-based",
        aliases: &["performance", "ptt"],
        widths: "all",
        description: "the paper's §3.3 scheduler: critical tasks search the PTT globally, \
                      non-critical tasks pick the best local width",
    },
    PolicyInfo {
        name: "ptt-adaptive",
        aliases: &["adaptive", "pttv2"],
        widths: "all",
        description: "performance-based + PTT v2 change detection: critical tasks avoid \
                      flagged (interfered) cores, non-critical tasks widen the local search \
                      when their own core is flagged",
    },
    PolicyInfo {
        name: "ptt-serving",
        aliases: &["serving"],
        widths: "all",
        description: "performance-based + fairness feedback (serving mode): when the rolling \
                      Jain index dips below the setpoint, the monopolising tenant is biased \
                      off the cores it monopolises",
    },
    PolicyInfo {
        name: "ptt-elastic",
        aliases: &["elastic", "moldable"],
        widths: "elastic",
        description: "moldable-width scheduling: critical tasks go wide (≤ the kernel's \
                      moldability cap) on unflagged clusters, narrowing to width 1 under \
                      interference flags or serving backpressure",
    },
    PolicyInfo {
        name: "homogeneous-ws",
        aliases: &["homogeneous", "ws"],
        widths: "1",
        description: "XiTAO's default random work stealing at width 1, PTT-unaware (§5 baseline)",
    },
    PolicyInfo {
        name: "cats-like",
        aliases: &["cats"],
        widths: "1",
        description: "criticality-aware baseline (§6): critical tasks to the learned-fastest \
                      cluster, width 1",
    },
    PolicyInfo {
        name: "dheft-like",
        aliases: &["dheft"],
        widths: "1",
        description: "dynamic-HEFT baseline (§6): earliest-finish-time placement from learned \
                      width-1 latencies",
    },
    PolicyInfo {
        name: "energy-minimizing",
        aliases: &["energy"],
        widths: "all",
        description: "§3.3's alternative objective: minimise exec_time × partition power \
                      (joules per task)",
    },
    PolicyInfo {
        name: "heft",
        aliases: &["heft-static"],
        widths: "plan",
        description: "offline HEFT: whole-DAG upward-rank plan against the episode-free \
                      analytic model, replayed at place() time (the online dheft-like \
                      baseline stays separate)",
    },
    PolicyInfo {
        name: "peft",
        aliases: &["peft-static"],
        widths: "plan",
        description: "offline PEFT: optimistic-cost-table priorities with EFT placement \
                      from a whole-DAG plan",
    },
    PolicyInfo {
        name: "dls",
        aliases: &["dls-static"],
        widths: "plan",
        description: "offline dynamic-level scheduling: joint (task, partition) argmax of \
                      static level minus earliest start time",
    },
    PolicyInfo {
        name: "portfolio",
        aliases: &["plan-portfolio"],
        widths: "plan",
        description: "plans each DAG with every offline planner (heft/peft/dls) and keeps \
                      the best predicted makespan",
    },
];

/// Canonical policy names, in registry order.
pub fn policy_names() -> [&'static str; POLICIES.len()] {
    POLICIES.map(|p| p.name)
}

/// Construct a policy by CLI/config name (canonical or alias — see
/// [`POLICIES`]).
pub fn policy_by_name(name: &str, n_cores: usize) -> Option<Box<dyn Policy>> {
    let canonical =
        POLICIES.iter().find(|p| p.name == name || p.aliases.contains(&name))?.name;
    Some(match canonical {
        "performance-based" => Box::new(PerformanceBased),
        "ptt-adaptive" => Box::new(PttAdaptive::new(n_cores)),
        "ptt-serving" => Box::new(PttServing::new(n_cores)),
        "ptt-elastic" => Box::new(PttElastic::new()),
        "homogeneous-ws" => Box::new(HomogeneousWs),
        "cats-like" => Box::new(CatsLike::default()),
        "dheft-like" => Box::new(DheftLike::new(n_cores)),
        "energy-minimizing" => Box::new(EnergyMinimizing),
        // Plan-ahead policies: the registry cannot see a DAG, so these
        // start planless (width-1 fallback) and the exec layer swaps in a
        // planned instance per DAG via `list_sched::planned_policy`.
        "heft" => Box::new(super::list_sched::PlannedPolicy::unplanned("heft")),
        "peft" => Box::new(super::list_sched::PlannedPolicy::unplanned("peft")),
        "dls" => Box::new(super::list_sched::PlannedPolicy::unplanned("dls")),
        "portfolio" => Box::new(super::list_sched::PlannedPolicy::unplanned("portfolio")),
        _ => unreachable!("registry row without a constructor"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Topology;

    fn tx2() -> Topology {
        Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)])
    }

    fn ctx<'a>(
        core: CoreId,
        critical: bool,
        ptt: &'a Ptt,
        topo: &'a Topology,
    ) -> PlaceCtx<'a> {
        PlaceCtx::new(
            TaskView {
                task: 0,
                type_id: 0,
                critical,
                max_width: usize::MAX,
                app_id: 0,
                qos: QosClass::default(),
            },
            EngineView { core, ptt, topo, now: 0.0 },
        )
    }

    #[test]
    fn performance_critical_goes_global() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 0, 2, 0.05); // denver pair clearly best
        }
        let pol = PerformanceBased;
        let p = pol.place(&ctx(5, true, &ptt, &topo));
        assert_eq!((p.leader, p.width), (0, 2));
    }

    #[test]
    fn performance_noncritical_stays_local() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 0, 1, 1e-6); // denver looks amazing
        }
        let pol = PerformanceBased;
        let p = pol.place(&ctx(5, false, &ptt, &topo));
        // Must remain in core 5's cluster (a57) regardless.
        assert_eq!(topo.cluster_of(p.leader).id, 1);
        assert!(p.contains(5) || p.leader == 5 || p.cores().contains(&5));
    }

    #[test]
    fn homogeneous_is_width1_local_always() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        let pol = HomogeneousWs;
        for core in 0..topo.n_cores() {
            for critical in [false, true] {
                let p = pol.place(&ctx(core, critical, &ptt, &topo));
                assert_eq!(p, Partition { leader: core, width: 1 });
            }
        }
        assert!(!pol.uses_ptt());
    }

    #[test]
    fn cats_sends_critical_to_fast_cluster() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Train: denver (cluster 0) fast, a57 slow.
        for c in 0..2 {
            ptt.update(0, c, 1, 0.5);
        }
        for c in 2..6 {
            ptt.update(0, c, 1, 1.0);
        }
        let pol = CatsLike::default();
        for _ in 0..8 {
            let p = pol.place(&ctx(4, true, &ptt, &topo));
            assert_eq!(topo.cluster_of(p.leader).id, 0);
            assert_eq!(p.width, 1);
        }
    }

    #[test]
    fn cats_noncritical_stays_put() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        let pol = CatsLike::default();
        let p = pol.place(&ctx(3, false, &ptt, &topo));
        assert_eq!(p, Partition { leader: 3, width: 1 });
    }

    #[test]
    fn dheft_spreads_by_finish_time() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for c in 0..6 {
            ptt.update(0, c, 1, 1.0);
        }
        let pol = DheftLike::new(6);
        let mut used = std::collections::HashSet::new();
        for _ in 0..6 {
            let p = pol.place(&ctx(0, true, &ptt, &topo));
            used.insert(p.leader);
        }
        // Equal latencies + EFT ⇒ all six cores get one task each.
        assert_eq!(used.len(), 6);
    }

    #[test]
    fn dheft_prefers_fast_core_until_saturated() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 0.1);
        for c in 1..6 {
            ptt.update(0, c, 1, 1.0);
        }
        let pol = DheftLike::new(6);
        // First several placements should pile onto core 0 while its queue
        // is still the earliest finish.
        let first = pol.place(&ctx(3, true, &ptt, &topo));
        assert_eq!(first.leader, 0);
    }

    #[test]
    fn energy_policy_prefers_low_power_when_times_equal() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0); // equal times everywhere
        }
        let pol = EnergyMinimizing;
        let p = pol.place(&ctx(0, true, &ptt, &topo));
        // Equal times: the cheapest-power width-1 partition wins — an A57
        // core (1.1 W) over a Denver (2.0 W).
        assert_eq!(p.width, 1);
        assert_eq!(topo.cluster_of(p.leader).id, 1, "{p:?}");
    }

    #[test]
    fn energy_policy_accepts_fast_core_when_much_faster() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Denver width-1 is 4× faster: 0.25 s × 2 W = 0.5 J beats 1 s × 1.1 J.
        for _ in 0..60 {
            ptt.update(0, 0, 1, 0.25);
        }
        let pol = EnergyMinimizing;
        let p = pol.place(&ctx(3, true, &ptt, &topo));
        assert_eq!((p.leader, p.width), (0, 1));
    }

    #[test]
    fn policy_by_name_resolves() {
        for (n, expect) in [
            ("performance", "performance-based"),
            ("adaptive", "ptt-adaptive"),
            ("pttv2", "ptt-adaptive"),
            ("homogeneous", "homogeneous-ws"),
            ("cats", "cats-like"),
            ("dheft", "dheft-like"),
            ("energy", "energy-minimizing"),
            ("elastic", "ptt-elastic"),
            ("moldable", "ptt-elastic"),
        ] {
            assert_eq!(policy_by_name(n, 4).unwrap().name(), expect);
        }
        assert!(policy_by_name("nope", 4).is_none());
    }

    #[test]
    fn adaptive_matches_performance_based_without_flags() {
        // With no flags raised the adaptive policy must make exactly the
        // paper scheduler's decisions — both on a trained table and on a
        // fresh (exploring) one.
        let topo = tx2();
        for train in [false, true] {
            let ptt = Ptt::new(1, &topo);
            if train {
                for p in topo.all_partitions() {
                    ptt.update(0, p.leader, p.width, 1.0);
                }
                for _ in 0..50 {
                    ptt.update(0, 0, 2, 0.05);
                }
            }
            assert_eq!(ptt.n_flagged(), 0);
            let adaptive = PttAdaptive::new(topo.n_cores());
            let plain = PerformanceBased;
            for core in 0..topo.n_cores() {
                for critical in [false, true] {
                    let c = ctx(core, critical, &ptt, &topo);
                    assert_eq!(
                        adaptive.place(&c),
                        plain.place(&c),
                        "core {core} critical {critical} train {train}"
                    );
                }
            }
        }
    }

    #[test]
    fn adaptive_steers_critical_tasks_off_flagged_cores() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Denver core 0 is the clear unconstrained winner...
        for _ in 0..50 {
            ptt.update(0, 0, 1, 0.01);
        }
        assert_eq!(PerformanceBased.place(&ctx(5, true, &ptt, &topo)).leader, 0);
        // ...until its behaviour shifts and the detector flags it. Two
        // samples: the first raises the flag, the second sits inside the
        // hysteresis dead band (fast re-learn clears it a few samples
        // later — that reconvergence is pinned in the ptt tests).
        for _ in 0..2 {
            ptt.update(0, 0, 1, 0.05);
        }
        assert!(ptt.core_flagged(0), "5x shift must flag core 0");
        let p = PttAdaptive::new(topo.n_cores()).place(&ctx(5, true, &ptt, &topo));
        assert!(!p.contains(0), "critical task placed onto flagged core: {p:?}");
        // The plain policy keeps trusting the (still attractive) stale row.
        assert_eq!(PerformanceBased.place(&ctx(5, true, &ptt, &topo)).leader, 0);
    }

    #[test]
    fn adaptive_treats_dead_cores_as_permanently_flagged() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Denver core 0 stays the trained winner — only the fault mask
        // (not any latency shift) makes the adaptive policy shun it.
        for _ in 0..50 {
            ptt.update(0, 0, 1, 0.01);
        }
        ptt.set_core_dead(0, true);
        assert!(!ptt.core_flagged(0), "death is not a divergence flag");
        let adaptive = PttAdaptive::new(topo.n_cores());
        let p = adaptive.place(&ctx(5, true, &ptt, &topo));
        assert!(!p.contains(0), "critical task placed onto dead core: {p:?}");
        // Non-critical decisions *on* the dead core always escape — no
        // local probe cycle, a dead core has nothing to re-learn.
        for round in 0..2 * PROBE_PERIOD {
            let p = adaptive.place(&ctx(0, false, &ptt, &topo));
            assert!(!p.contains(0), "round {round} probed a dead core: {p:?}");
        }
        // Recovery restores plain behaviour.
        ptt.set_core_dead(0, false);
        assert_eq!(
            adaptive.place(&ctx(5, true, &ptt, &topo)),
            PerformanceBased.place(&ctx(5, true, &ptt, &topo))
        );
    }

    #[test]
    fn adaptive_noncritical_widens_off_its_flagged_core() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Flag core 2 (a57 cluster leader) via an abrupt shift (two
        // samples: flag raised, then held inside the dead band).
        for _ in 0..2 {
            ptt.update(0, 2, 1, 5.0);
        }
        assert!(ptt.core_flagged(2));
        let adaptive = PttAdaptive::new(topo.n_cores());
        // A non-critical task deciding on the flagged core 2 escapes to an
        // unflagged a57 partition — never to the denver cluster.
        let p = adaptive.place(&ctx(2, false, &ptt, &topo));
        assert!(!p.contains(2), "{p:?}");
        assert_eq!(topo.cluster_of(p.leader).id, 1, "must stay in its cluster: {p:?}");
        // Every PROBE_PERIODth decision stays local so the flagged rows
        // keep learning (recovery depends on it): decisions 2 and 3 escape,
        // decision 4 is the probe.
        assert!(!adaptive.place(&ctx(2, false, &ptt, &topo)).contains(2));
        assert!(!adaptive.place(&ctx(2, false, &ptt, &topo)).contains(2));
        let probe = adaptive.place(&ctx(2, false, &ptt, &topo));
        assert!(probe.contains(2), "4th decision must stay local as a probe: {probe:?}");
        // Deciding on an unflagged core: identical to the plain local search.
        assert_eq!(
            adaptive.place(&ctx(4, false, &ptt, &topo)),
            PerformanceBased.place(&ctx(4, false, &ptt, &topo))
        );
    }

    #[test]
    fn qos_classes_order_resolve_and_carry_slos() {
        // Priority order is load-bearing: the admission path sheds from
        // the bottom of it.
        assert!(QosClass::Latency < QosClass::Batch);
        assert!(QosClass::Batch < QosClass::BestEffort);
        for (i, q) in QosClass::ALL.into_iter().enumerate() {
            assert_eq!(q.index(), i);
            assert_eq!(QosClass::by_name(q.name()), Some(q));
        }
        assert_eq!(QosClass::by_name("best-effort"), Some(QosClass::BestEffort));
        assert_eq!(QosClass::by_name("nope"), None);
        assert!(QosClass::Latency.slo_slowdown() < QosClass::Batch.slo_slowdown());
        assert!(QosClass::BestEffort.slo_slowdown().is_infinite());
    }

    #[test]
    fn serving_matches_performance_based_until_fairness_dips() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 0, 1, 0.01); // core 0 is the clear winner
        }
        let serving = PttServing::new(topo.n_cores());
        let plain = PerformanceBased;
        // No feedback yet (and feedback above the setpoint): identical
        // decisions everywhere.
        let mono = vec![Some(0usize); topo.n_cores()];
        for fed in [false, true] {
            if fed {
                serving.on_fairness(FAIRNESS_SETPOINT + 0.1, &mono);
            }
            for core in 0..topo.n_cores() {
                for critical in [false, true] {
                    let c = ctx(core, critical, &ptt, &topo);
                    assert_eq!(serving.place(&c), plain.place(&c), "fed {fed} core {core}");
                }
            }
        }
    }

    #[test]
    fn serving_biases_monopolist_off_its_cores_when_unfair() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 0, 1, 0.01); // core 0 is everyone's favourite
        }
        let serving = PttServing::new(topo.n_cores());
        // App 7 monopolises core 0; fairness collapsed below the setpoint.
        let mut mono = vec![None; topo.n_cores()];
        mono[0] = Some(7usize);
        serving.on_fairness(0.4, &mono);
        // The monopolist's critical task is steered off core 0...
        let mut c7 = ctx(5, true, &ptt, &topo);
        c7.app_id = 7;
        let p = serving.place(&c7);
        assert!(!p.contains(0), "monopolist kept its core: {p:?}");
        // ...while another tenant still gets the fast core.
        let mut c3 = ctx(5, true, &ptt, &topo);
        c3.app_id = 3;
        assert_eq!(serving.place(&c3).leader, 0);
        // The monopolist's non-critical task escapes its own monopolised
        // core (cluster-local).
        let mut nc7 = ctx(0, false, &ptt, &topo);
        nc7.app_id = 7;
        let p = serving.place(&nc7);
        assert!(!p.contains(0), "{p:?}");
        assert_eq!(topo.cluster_of(p.leader).id, 0, "stays in its cluster: {p:?}");
        // Fairness recovering above the setpoint disengages the bias.
        serving.on_fairness(0.95, &mono);
        assert_eq!(serving.place(&c7).leader, 0);
    }

    #[test]
    fn registry_names_and_aliases_all_construct_their_policy() {
        // The registry is the single source of truth: every canonical name
        // and every alias must resolve, and the constructed policy must
        // report the row's canonical name.
        for info in POLICIES {
            assert_eq!(policy_by_name(info.name, 4).unwrap().name(), info.name);
            for alias in info.aliases {
                assert_eq!(policy_by_name(alias, 4).unwrap().name(), info.name);
            }
            assert!(!info.description.is_empty());
            assert!(
                ["1", "all", "elastic", "plan"].contains(&info.widths),
                "unknown widths capability {:?} for {}",
                info.widths,
                info.name
            );
        }
        assert_eq!(policy_names().len(), POLICIES.len());
        // The capability column must agree with the flagship rows.
        let widths_of = |name: &str| POLICIES.iter().find(|p| p.name == name).unwrap().widths;
        assert_eq!(widths_of("ptt-elastic"), "elastic");
        assert_eq!(widths_of("homogeneous-ws"), "1");
        assert_eq!(widths_of("heft"), "plan");
    }

    #[test]
    fn elastic_matches_performance_based_when_unconstrained() {
        // Fully moldable tasks, no flags, no backpressure: the elastic
        // policy is exactly the paper scheduler.
        let topo = tx2();
        for train in [false, true] {
            let ptt = Ptt::new(1, &topo);
            if train {
                for p in topo.all_partitions() {
                    ptt.update(0, p.leader, p.width, 1.0);
                }
                for _ in 0..50 {
                    ptt.update(0, 0, 2, 0.05);
                }
            }
            let elastic = PttElastic::new();
            for core in 0..topo.n_cores() {
                for critical in [false, true] {
                    let c = ctx(core, critical, &ptt, &topo);
                    assert_eq!(
                        elastic.place(&c),
                        PerformanceBased.place(&c),
                        "core {core} critical {critical} train {train}"
                    );
                }
            }
        }
    }

    #[test]
    fn elastic_honors_moldability_cap() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Width 4 on the a57 quad looks unbeatable (time × width = 0.04)...
        for _ in 0..50 {
            ptt.update(0, 2, 4, 0.01);
        }
        let elastic = PttElastic::new();
        let wide = elastic.place(&ctx(5, true, &ptt, &topo));
        assert_eq!((wide.leader, wide.width), (2, 4));
        // ...but a kernel molded to at most 2 lanes may not use it.
        for cap in [1usize, 2] {
            for core in 0..topo.n_cores() {
                for critical in [false, true] {
                    let mut c = ctx(core, critical, &ptt, &topo);
                    c.max_width = cap;
                    let p = elastic.place(&c);
                    assert!(
                        p.width <= cap,
                        "cap {cap} core {core} critical {critical}: got {p:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn elastic_narrows_under_interference_flags() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // The a57 quad team is the trained winner...
        for _ in 0..50 {
            ptt.update(0, 2, 4, 0.01);
        }
        let elastic = PttElastic::new();
        assert_eq!(elastic.place(&ctx(5, true, &ptt, &topo)).width, 4);
        // ...until core 3 (a team member) gets flagged: critical tasks must
        // not assemble a team across the interfered core.
        for _ in 0..2 {
            ptt.update(0, 3, 1, 5.0);
        }
        assert!(ptt.core_flagged(3));
        let p = elastic.place(&ctx(5, true, &ptt, &topo));
        assert!(!p.contains(3), "team spans the flagged core: {p:?}");
        // A non-critical task deciding on the flagged core narrows to
        // width 1 and escapes it (cluster-local).
        let p = elastic.place(&ctx(3, false, &ptt, &topo));
        assert!(!p.contains(3), "{p:?}");
        assert_eq!(p.width, 1, "must narrow under interference: {p:?}");
        assert_eq!(topo.cluster_of(p.leader).id, 1, "stays in its cluster: {p:?}");
    }

    #[test]
    fn elastic_narrows_under_serving_backpressure() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 2, 4, 0.01); // wide team is the trained winner
        }
        let elastic = PttElastic::new();
        assert_eq!(elastic.place(&ctx(5, true, &ptt, &topo)).width, 4);
        // Fairness collapses: every decision narrows to width 1.
        elastic.on_fairness(FAIRNESS_SETPOINT - 0.2, &[]);
        for core in 0..topo.n_cores() {
            for critical in [false, true] {
                let p = elastic.place(&ctx(core, critical, &ptt, &topo));
                assert_eq!(p.width, 1, "core {core} critical {critical}: {p:?}");
            }
        }
        // Recovery restores wide placement.
        elastic.on_fairness(FAIRNESS_SETPOINT + 0.1, &[]);
        assert_eq!(elastic.place(&ctx(5, true, &ptt, &topo)).width, 4);
    }

    #[test]
    fn place_ctx_new_clamps_degenerate_cap() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        let mut c = ctx(0, true, &ptt, &topo);
        assert_eq!(c.max_width, usize::MAX);
        c.max_width = 1;
        assert_eq!(PttElastic::new().place(&c).width, 1);
        // A zero cap coming through the seam is clamped to 1, never 0.
        let z = PlaceCtx::new(
            TaskView {
                task: 0,
                type_id: 0,
                critical: true,
                max_width: 0,
                app_id: 0,
                qos: QosClass::default(),
            },
            EngineView { core: 0, ptt: &ptt, topo: &topo, now: 0.0 },
        );
        assert_eq!(z.max_width, 1);
    }
}
