//! Real-engine realization of dynamic-heterogeneity episodes.
//!
//! [`crate::platform::episodes`] describes *when* a platform's effective
//! behaviour changes; until now only the virtual-time engine interpreted
//! that schedule, so `interference20`/`dvfs8` were sim-only scenarios.
//! This module makes the real-thread engine honour the same
//! [`EpisodeSchedule`] in **wall-clock** time, so both backends can be
//! driven through the identical dynamic scenario and their *response
//! shapes* compared (the `bench-interference` harness does exactly that).
//!
//! Two mechanisms, one per episode family:
//!
//! - **Duty-cycle throttling** ([`EpisodeDriver::throttle_share`]): after a
//!   worker executes its payload share on an affected core, the driver
//!   stalls (sleeping the bulk, spinning only the sub-millisecond tail)
//!   until the share's wall-clock footprint is stretched by
//!   `1 / speed_factor` — a core at DVFS factor 0.4 takes 2.5× as long per
//!   share, a core whose runtime keeps a 0.45 CPU share takes ≈ 2.2×. The
//!   stretch is attributed to the *executing share*, so the leader's own
//!   timing (the only PTT write, §3.2) observes it exactly like it would
//!   observe a slower core. The factor is sampled at the share's start —
//!   shares are short relative to episode windows, so edge-crossing error
//!   is one share long at most.
//! - **Background spinner threads** ([`EpisodeDriver::spawn_spinners`]):
//!   every [`EpisodeKind::Interference`] episode additionally gets one
//!   *actual* spinner thread per affected core that burns CPU during
//!   `[t_start, t_end)`, best-effort pinned like the workers. On a host
//!   with real affinity these contend for exactly the victim cores; on the
//!   pinning-less offline build they still provide genuine background
//!   load, while the duty-cycle stretch guarantees the *per-core* share
//!   semantics that the scenario specifies. Spinners poll a stop flag so a
//!   run that drains before an episode ends never blocks on them.
//!
//!   Division of labour, explicitly: the **throttle is the authoritative
//!   realization of the per-core share** on hosts without affinity
//!   control (this build's `pin_to_cpu` is a documented no-op). A
//!   deployment that wires real OS pinning back in must disable the
//!   interference-kind throttle (keep DVFS) — a genuinely pinned
//!   same-priority spinner already takes its CPU share, and stretching
//!   the measured (already slowed) share again would square the slowdown.
//!   The rule is *encoded*, not just documented:
//!   [`EpisodeDriver::with_interference_throttle`] takes the decision as
//!   a parameter and the engine derives it from whether its `pin_to_cpu`
//!   actually pins (`worker::pinning_available`).
//!
//! The driver is entirely passive data + spin loops: no locks, no channels,
//! no interaction with the scheduler — exactly like the simulator's episode
//! handling, the scheduler only ever observes episodes through inflated
//! execution times in the PTT.

use crate::platform::CoreId;
use crate::platform::episodes::{EpisodeKind, EpisodeSchedule};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Smallest speed factor the throttle honours — a guard against a
/// misconfigured episode stalling a worker near-forever (factor 1e-3 would
/// stretch every share 1000×).
const MIN_SPEED_FACTOR: f64 = 0.05;

/// Wall-clock realization of an [`EpisodeSchedule`] (see module docs).
#[derive(Debug)]
pub struct EpisodeDriver {
    schedule: EpisodeSchedule,
    /// Whether [`EpisodeKind::Interference`] episodes participate in the
    /// duty-cycle throttle. `true` on hosts without real core pinning
    /// (this build): the stretch is then the authoritative realization of
    /// the per-core CPU share. A deployment whose `pin_to_cpu` actually
    /// pins must pass `false` — its pinned spinners already take their
    /// share, and stretching the measured (already slowed) share again
    /// would square the slowdown. DVFS episodes always throttle: no
    /// spinner can emulate a frequency drop.
    throttle_interference: bool,
}

/// One planned background spinner: burn CPU on (virtually) `core` during
/// `[t_start, t_end)` seconds of run time.
#[derive(Debug, Clone, Copy)]
pub struct SpinnerSpec {
    pub core: CoreId,
    pub t_start: f64,
    pub t_end: f64,
}

impl EpisodeDriver {
    /// Driver with the interference throttle enabled — correct whenever
    /// real core pinning is unavailable (see
    /// [`EpisodeDriver::with_interference_throttle`]).
    pub fn new(schedule: EpisodeSchedule) -> EpisodeDriver {
        Self::with_interference_throttle(schedule, true)
    }

    /// Driver with an explicit interference-throttle policy (the
    /// `throttle_interference` field docs state the rule). The engine
    /// derives the argument from whether its `pin_to_cpu` actually pins,
    /// so the no-double-count rule is encoded, not just documented.
    pub fn with_interference_throttle(
        schedule: EpisodeSchedule,
        throttle_interference: bool,
    ) -> EpisodeDriver {
        EpisodeDriver { schedule, throttle_interference }
    }

    /// Whether the schedule has any episodes at all (the hot path skips
    /// every driver call when it does not).
    pub fn is_active(&self) -> bool {
        !self.schedule.is_empty()
    }

    /// Is `core` fail-stopped at run time `t`? The worker loop checks this
    /// at its top and parks the core for the episode's duration — a dead
    /// core executes nothing, it does not merely slow down.
    pub fn fail_stopped(&self, core: CoreId, t: f64) -> bool {
        self.schedule.fail_stopped(core, t)
    }

    /// Earliest recovery time among fail-stop episodes holding `core` dead
    /// at `t`: `Some(t_recover)` for a finite outage, `None` when the core
    /// never comes back (or is not fail-stopped at all — callers gate on
    /// [`EpisodeDriver::fail_stopped`] first).
    pub fn fail_stop_recovery(&self, core: CoreId, t: f64) -> Option<f64> {
        self.schedule
            .episodes
            .iter()
            .filter(|e| {
                matches!(e.kind, EpisodeKind::FailStop { .. }) && e.active_at(t) && e.affects(core)
            })
            .map(|e| e.t_end)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
            .filter(|t| t.is_finite())
    }

    /// Does any fail-stop episode exist in the schedule (watchdog arming)?
    pub fn any_fail_stop(&self) -> bool {
        self.schedule.episodes.iter().any(|e| matches!(e.kind, EpisodeKind::FailStop { .. }))
    }

    /// Composed speed factor the *throttle* honours on `core` at `t`:
    /// like [`EpisodeSchedule::speed_factor`], but interference episodes
    /// are excluded when the driver was built with the interference
    /// throttle off (real pinning realizes those).
    fn throttled_speed_factor(&self, core: CoreId, t: f64) -> f64 {
        self.schedule
            .episodes
            .iter()
            .filter(|e| e.active_at(t) && e.affects(core))
            .filter(|e| self.throttle_interference || !matches!(e.kind, EpisodeKind::Interference))
            .map(|e| e.speed_factor)
            .product()
    }

    /// Wall-clock stretch factor (≥ 1) for a share on `core` at run time
    /// `t`: the reciprocal of the composed episode speed factor.
    pub fn stretch_factor(&self, core: CoreId, t: f64) -> f64 {
        if self.schedule.is_empty() {
            return 1.0;
        }
        1.0 / self.throttled_speed_factor(core, t).clamp(MIN_SPEED_FACTOR, 1.0)
    }

    /// Throttle the share that started at run time `t_share_start` and just
    /// finished executing: spin until its wall footprint reaches
    /// `executed × stretch_factor`. Returns immediately when no episode
    /// affects `core` at the share's start.
    ///
    /// `now` must be monotonically derived from the same origin as
    /// `t_share_start` (the engine's `Shared::now`).
    pub fn throttle_share(&self, core: CoreId, t_share_start: f64, now: impl Fn() -> f64) {
        let factor = self.stretch_factor(core, t_share_start);
        if factor <= 1.0 {
            return;
        }
        let executed = now() - t_share_start;
        if executed <= 0.0 {
            return;
        }
        // Sleep the bulk of the stretch and spin only the sub-millisecond
        // tail: a throttled core must look *slow*, not *busy* — burning a
        // host CPU for the whole stretch would steal cycles from the
        // workers time-sharing it (the oversubscribed-CI case) and inflate
        // the unaffected cores' timings the response bench compares
        // against. The background-load half of interference is modelled by
        // the dedicated spinner threads, not here.
        let deadline = t_share_start + executed * factor;
        loop {
            let remaining = deadline - now();
            if remaining <= 0.0 {
                return;
            }
            if remaining > 5e-4 {
                std::thread::sleep(Duration::from_secs_f64(remaining - 2e-4));
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// The spinner plan: one entry per (interference episode × affected
    /// core). DVFS episodes throttle without background load.
    pub fn spinner_plan(&self) -> Vec<SpinnerSpec> {
        self.schedule
            .episodes
            .iter()
            .filter(|e| matches!(e.kind, EpisodeKind::Interference))
            .flat_map(|e| {
                e.cores
                    .iter()
                    .map(move |&core| SpinnerSpec { core, t_start: e.t_start, t_end: e.t_end })
            })
            .collect()
    }

    /// Spawn every planned spinner into `scope`. Each spinner sleeps in
    /// short bounded naps until its window opens, burns CPU until the
    /// window closes, and exits early the moment `stop` is observed — so
    /// scoped joins never outlive the run they belong to.
    pub fn spawn_spinners<'scope, 'env: 'scope>(
        &self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        t0: Instant,
        stop: &'env AtomicBool,
        pin: impl Fn(CoreId) + Send + Copy + 'env,
    ) {
        for spec in self.spinner_plan() {
            scope.spawn(move || {
                pin(spec.core);
                run_spinner(spec, t0, stop);
            });
        }
    }
}

/// Body of one background spinner (see [`EpisodeDriver::spawn_spinners`]).
fn run_spinner(spec: SpinnerSpec, t0: Instant, stop: &AtomicBool) {
    let now = || t0.elapsed().as_secs_f64();
    // Nap until the window opens (bounded naps: react to `stop` quickly).
    while now() < spec.t_start {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let behind = spec.t_start - now();
        std::thread::sleep(Duration::from_secs_f64(behind.min(0.001).max(0.0)));
    }
    // Burn the window, checking the stop flag at a coarse period so the
    // spin loop itself stays branch-cheap.
    let mut check = 0u32;
    while now() < spec.t_end {
        check = check.wrapping_add(1);
        if check % 4096 == 0 && stop.load(Ordering::Acquire) {
            return;
        }
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::episodes::Episode;

    fn sched() -> EpisodeSchedule {
        EpisodeSchedule::new(vec![
            Episode::interference(vec![0, 1], 0.05, 0.25, 0.45, 2.0),
            Episode::dvfs(vec![2], 0.10, 0.20, 0.5),
        ])
    }

    #[test]
    fn stretch_factor_is_reciprocal_speed_inside_windows_only() {
        let d = EpisodeDriver::new(sched());
        assert!(d.is_active());
        assert_eq!(d.stretch_factor(0, 0.01), 1.0);
        assert!((d.stretch_factor(0, 0.10) - 1.0 / 0.45).abs() < 1e-12);
        assert!((d.stretch_factor(2, 0.15) - 2.0).abs() < 1e-12);
        assert_eq!(d.stretch_factor(2, 0.30), 1.0);
        assert_eq!(d.stretch_factor(5, 0.10), 1.0);
        let empty = EpisodeDriver::new(EpisodeSchedule::default());
        assert!(!empty.is_active());
        assert_eq!(empty.stretch_factor(0, 0.10), 1.0);
    }

    #[test]
    fn interference_throttle_off_keeps_dvfs_stretch_only() {
        // The pinned-deployment configuration: interference is realized by
        // genuinely pinned spinners, so only DVFS stretches shares.
        let d = EpisodeDriver::with_interference_throttle(sched(), false);
        assert!(d.is_active());
        assert_eq!(d.stretch_factor(0, 0.10), 1.0, "interference must not throttle");
        assert!((d.stretch_factor(2, 0.15) - 2.0).abs() < 1e-12, "DVFS still throttles");
        // Spinners are planned regardless — they are the realization.
        assert_eq!(d.spinner_plan().len(), 2);
    }

    #[test]
    fn stretch_factor_clamps_pathological_speeds() {
        let d = EpisodeDriver::new(EpisodeSchedule::new(vec![Episode::dvfs(
            vec![0],
            0.0,
            1.0,
            1e-6,
        )]));
        assert!(d.stretch_factor(0, 0.5) <= 1.0 / MIN_SPEED_FACTOR + 1e-9);
    }

    #[test]
    fn spinner_plan_covers_interference_cores_only() {
        let d = EpisodeDriver::new(sched());
        let plan = d.spinner_plan();
        assert_eq!(plan.len(), 2, "one spinner per interfered core");
        let cores: Vec<CoreId> = plan.iter().map(|s| s.core).collect();
        assert_eq!(cores, vec![0, 1]);
        for s in &plan {
            assert_eq!((s.t_start, s.t_end), (0.05, 0.25));
        }
    }

    #[test]
    fn throttle_share_stretches_wall_time() {
        let d = EpisodeDriver::new(EpisodeSchedule::new(vec![Episode::dvfs(
            vec![0],
            0.0,
            10.0,
            0.5,
        )]));
        let t0 = Instant::now();
        let now = || t0.elapsed().as_secs_f64();
        let start = now();
        // Simulate a ~2 ms payload, then throttle at factor 2.
        std::thread::sleep(Duration::from_millis(2));
        d.throttle_share(0, start, now);
        let total = now() - start;
        assert!(total >= 0.004 * 0.9, "2 ms share at 0.5 speed must take ~4 ms, took {total}");
        // An unaffected core is not stretched: the throttle itself returns
        // promptly (generous bound — shared CI runners deschedule freely).
        let start = now();
        std::thread::sleep(Duration::from_millis(1));
        let before = now();
        d.throttle_share(3, start, now);
        assert!(now() - before < 0.05, "unaffected core must not be throttled");
    }

    #[test]
    fn fail_stop_queries_track_outage_and_recovery() {
        let d = EpisodeDriver::new(EpisodeSchedule::new(vec![
            Episode::fail_stop(vec![1], 0.1, Some(0.3)),
            Episode::fail_stop(vec![2], 0.2, None),
        ]));
        assert!(d.any_fail_stop());
        assert!(!d.fail_stopped(1, 0.05));
        assert!(d.fail_stopped(1, 0.2));
        assert!(!d.fail_stopped(1, 0.3));
        assert_eq!(d.fail_stop_recovery(1, 0.2), Some(0.3));
        // Permanent outage: dead, and no recovery time to wait for.
        assert!(d.fail_stopped(2, 5.0));
        assert_eq!(d.fail_stop_recovery(2, 5.0), None);
        // A fail-stopped core is not *stretched* — death is absence.
        assert_eq!(d.stretch_factor(1, 0.2), 1.0);
        assert!(!EpisodeDriver::new(sched()).any_fail_stop());
    }

    #[test]
    fn spinner_honours_stop_flag_before_window_opens() {
        let spec = SpinnerSpec { core: 0, t_start: 60.0, t_end: 120.0 };
        let stop = AtomicBool::new(true);
        let t0 = Instant::now();
        // Far-future window + stop already set: must return immediately.
        run_spinner(spec, t0, &stop);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
