//! Mutex-guarded baseline queues — the hot path this repository *used* to
//! run on, kept only as the measurement baseline.
//!
//! The engines now use the lock-free [`super::wsq::WsQueue`] (Chase–Lev)
//! and [`super::aq::AssemblyQueue`] (Vyukov MPSC). These mutex variants
//! exist for two consumers:
//!
//! - `repro bench-overhead --compare` / `cargo bench --bench
//!   sched_overhead`, which pit lock-free against mutex on a steal-heavy
//!   workload and record the ratio in `BENCH_sched_overhead.json`;
//! - `tests/lockfree_queues.rs`, which uses them as trivially correct
//!   reference implementations to pin the lock-free queues' ordering
//!   semantics (LIFO pop / FIFO steal, strict AQ FIFO).
//!
//! Do **not** use them in engine code.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Mutex-guarded work-stealing queue: owner pushes/pops at the back,
/// thieves steal from the front. Same API as [`super::wsq::WsQueue`].
#[derive(Debug, Default)]
pub struct MutexWsQueue<T> {
    q: Mutex<VecDeque<T>>,
}

impl<T> MutexWsQueue<T> {
    pub fn new() -> MutexWsQueue<T> {
        MutexWsQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Owner-side push (back).
    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
    }

    /// Owner-side pop (back, LIFO).
    pub fn pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop_back()
    }

    /// Thief-side steal (front, FIFO).
    pub fn steal(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }

    /// Batched thief-side steal: take half of the queue (rounded up,
    /// capped at [`super::wsq::MAX_BATCH_STEAL`]) from the front, FIFO.
    /// Same window policy as [`super::wsq::WsQueue::steal_half`], so the
    /// lockstep conformance tests can compare the two batch-for-batch.
    pub fn steal_half(&self, mut sink: impl FnMut(T)) -> usize {
        let mut q = self.q.lock().unwrap();
        let want = q.len().div_ceil(2).min(super::wsq::MAX_BATCH_STEAL);
        for _ in 0..want {
            sink(q.pop_front().unwrap());
        }
        want
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Mutex-guarded FIFO assembly queue. Same API as
/// [`super::aq::AssemblyQueue`].
#[derive(Debug, Default)]
pub struct MutexAssemblyQueue<T> {
    q: Mutex<VecDeque<T>>,
}

impl<T> MutexAssemblyQueue<T> {
    pub fn new() -> MutexAssemblyQueue<T> {
        MutexAssemblyQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Insert at the tail (placement time).
    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
    }

    /// Fetch from the head (execution time).
    pub fn pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_wsq_owner_lifo_thief_fifo() {
        let q = MutexWsQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.steal(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mutex_wsq_steal_half_matches_policy() {
        let q = MutexWsQueue::new();
        for i in 0..7 {
            q.push(i);
        }
        let mut got = Vec::new();
        assert_eq!(q.steal_half(|v| got.push(v)), 4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 3);
        assert_eq!(q.steal_half(|_: i32| ()), 2);
        let empty = MutexWsQueue::<i32>::new();
        assert_eq!(empty.steal_half(|_| panic!("empty queue yielded items")), 0);
    }

    #[test]
    fn mutex_aq_strict_fifo() {
        let q = MutexAssemblyQueue::new();
        q.push("a");
        q.push("b");
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
    }
}
