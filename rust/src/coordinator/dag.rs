//! TAO-DAGs and criticality (§2).
//!
//! The critical path of a task-DAG is its longest path; the *criticality*
//! of a node is `1 + max(criticality of children)` (leaves have criticality
//! 1), assigned by a bottom-up traversal. The first node of the longest
//! path then carries the highest criticality, equal to the critical-path
//! length. The paper's runtime rule re-derives per-task criticality when a
//! parent wakes a child: the child is critical iff
//! `parent.criticality - child.criticality == 1`.
//!
//! Average DAG parallelism is `total tasks / critical-path length` (§2).

use super::tao::TaoPayload;
use crate::platform::KernelClass;
use std::sync::Arc;

/// Node index within a [`TaoDag`].
pub type TaskId = usize;

/// One TAO node of a DAG.
pub struct TaoNode {
    pub id: TaskId,
    pub class: KernelClass,
    /// PTT row group — the paper's "TAO type". Tasks sharing a `type_id`
    /// share latency estimates (random-DAG kernels: one type per class;
    /// VGG: one type per layer shape).
    pub type_id: usize,
    /// Work multiplier relative to the class's base work (simulation).
    pub work_scale: f64,
    /// Real-mode body; `None` for simulation-only DAGs.
    pub payload: Option<Arc<dyn TaoPayload>>,
    /// Moldability descriptor: the widest resource partition this TAO's
    /// kernel can exploit (its internal parallelism cap). Policies clamp
    /// their width choice to `min(max_width, cluster width)`; the default
    /// is the class's [`crate::platform::ClassTraits::max_parallelism`].
    /// A value of 1 marks the task inelastic (always width-1).
    pub max_width: usize,
    /// Successor task ids (edges point forward in execution order).
    pub succs: Vec<TaskId>,
    /// Bytes the producer hands each successor (data item per edge),
    /// parallel to `succs`. 0 = control dependency only. Placement and the
    /// offline planners weigh cluster-crossing transfers by this.
    pub succ_bytes: Vec<u64>,
    /// Predecessor task ids.
    pub preds: Vec<TaskId>,
    /// Bottom-up criticality; valid after [`TaoDag::finalize`].
    pub criticality: u32,
    /// The successor this node hands the critical path to (the first child
    /// whose criticality is exactly one less), if the node is on the path.
    /// Valid after [`TaoDag::finalize`].
    pub cp_child: Option<TaskId>,
}

impl std::fmt::Debug for TaoNode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TaoNode")
            .field("id", &self.id)
            .field("class", &self.class)
            .field("type_id", &self.type_id)
            .field("crit", &self.criticality)
            .field("succs", &self.succs)
            .finish()
    }
}

/// A directed acyclic graph of TAOs.
#[derive(Debug, Default)]
pub struct TaoDag {
    pub nodes: Vec<TaoNode>,
    finalized: bool,
}

impl TaoDag {
    pub fn new() -> TaoDag {
        TaoDag::default()
    }

    /// Add a simulation-only task.
    pub fn add_task(&mut self, class: KernelClass, type_id: usize, work_scale: f64) -> TaskId {
        self.add_task_payload(class, type_id, work_scale, None)
    }

    /// Add a task with a real-mode payload.
    pub fn add_task_payload(
        &mut self,
        class: KernelClass,
        type_id: usize,
        work_scale: f64,
        payload: Option<Arc<dyn TaoPayload>>,
    ) -> TaskId {
        assert!(!self.finalized, "cannot add tasks after finalize()");
        let id = self.nodes.len();
        self.nodes.push(TaoNode {
            id,
            class,
            type_id,
            work_scale,
            payload,
            max_width: class.traits().max_parallelism,
            succs: Vec::new(),
            succ_bytes: Vec::new(),
            preds: Vec::new(),
            criticality: 0,
            cp_child: None,
        });
        id
    }

    /// Override a task's moldability cap (see [`TaoNode::max_width`]).
    /// `max_width` must be at least 1 — width 0 is not a partition.
    pub fn set_max_width(&mut self, task: TaskId, max_width: usize) {
        assert!(!self.finalized, "cannot change moldability after finalize()");
        assert!(max_width >= 1, "max_width must be at least 1");
        self.nodes[task].max_width = max_width;
    }

    /// A copy of this DAG with every task's moldability clamped to
    /// `min(max_width, cap)`: identical structure, criticalities and
    /// payloads. Unlike [`TaoDag::set_max_width`] this works on a
    /// *finalized* DAG — the cap is a placement hint, not structure — so
    /// benchmark twins (`bench-elastic`'s width-1-forced runs) can be
    /// derived from an already-generated DAG without re-rolling the seed.
    pub fn with_max_width_cap(&self, cap: usize) -> TaoDag {
        assert!(cap >= 1, "cap must be at least 1");
        TaoDag {
            nodes: self
                .nodes
                .iter()
                .map(|n| TaoNode {
                    id: n.id,
                    class: n.class,
                    type_id: n.type_id,
                    work_scale: n.work_scale,
                    payload: n.payload.clone(),
                    max_width: n.max_width.min(cap),
                    succs: n.succs.clone(),
                    succ_bytes: n.succ_bytes.clone(),
                    preds: n.preds.clone(),
                    criticality: n.criticality,
                    cp_child: n.cp_child,
                })
                .collect(),
            finalized: self.finalized,
        }
    }

    /// Add a control-only dependency edge `from → to` (`to` runs after
    /// `from`, no data item attached).
    pub fn add_edge(&mut self, from: TaskId, to: TaskId) {
        self.add_edge_bytes(from, to, 0);
    }

    /// Add a dependency edge carrying a data item of `bytes` bytes (the
    /// producer's output consumed by `to`). A duplicate edge keeps the
    /// larger byte count — re-proposing an edge can only add data, never
    /// silently drop it.
    pub fn add_edge_bytes(&mut self, from: TaskId, to: TaskId, bytes: u64) {
        assert!(!self.finalized, "cannot add edges after finalize()");
        assert!(from < self.nodes.len() && to < self.nodes.len(), "edge endpoints must exist");
        assert_ne!(from, to, "self-edges are cycles");
        // Ignore duplicate edges (the random generator can propose repeats).
        match self.nodes[from].succs.iter().position(|&s| s == to) {
            Some(i) => {
                let cell = &mut self.nodes[from].succ_bytes[i];
                *cell = (*cell).max(bytes);
            }
            None => {
                self.nodes[from].succs.push(to);
                self.nodes[from].succ_bytes.push(bytes);
                self.nodes[to].preds.push(from);
            }
        }
    }

    /// Bytes carried by the edge `from → to`; `None` when no such edge
    /// exists, `Some(0)` for a control-only dependency.
    pub fn edge_bytes(&self, from: TaskId, to: TaskId) -> Option<u64> {
        self.nodes[from]
            .succs
            .iter()
            .position(|&s| s == to)
            .map(|i| self.nodes[from].succ_bytes[i])
    }

    /// Total bytes over all data edges (comm-bound scenario diagnostics).
    pub fn total_edge_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.succ_bytes.iter().sum::<u64>()).sum()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Root tasks (no predecessors).
    pub fn roots(&self) -> Vec<TaskId> {
        self.nodes.iter().filter(|n| n.preds.is_empty()).map(|n| n.id).collect()
    }

    /// Topological order; `Err` if the graph contains a cycle.
    pub fn topo_order(&self) -> Result<Vec<TaskId>, String> {
        let n = self.nodes.len();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.preds.len()).collect();
        let mut queue: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.nodes[u].succs {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() == n {
            Ok(order)
        } else {
            Err(format!("cycle detected: {} of {} nodes ordered", order.len(), n))
        }
    }

    /// Compute criticalities bottom-up and freeze the DAG. Must be called
    /// before execution. Returns `Err` on cyclic graphs.
    pub fn finalize(&mut self) -> Result<(), String> {
        let order = self.topo_order()?;
        for &u in order.iter().rev() {
            let max_child =
                self.nodes[u].succs.iter().map(|&v| self.nodes[v].criticality).max().unwrap_or(0);
            self.nodes[u].criticality = max_child + 1;
            self.nodes[u].cp_child = self.nodes[u]
                .succs
                .iter()
                .copied()
                .find(|&v| self.nodes[v].criticality == max_child && max_child > 0);
        }
        self.finalized = true;
        Ok(())
    }

    /// Whether `task` starts the critical path (a root of maximal
    /// criticality). §3.3: initial tasks are *placed* as non-critical, but
    /// they still hand the critical path to their children.
    pub fn is_cp_root(&self, task: TaskId) -> bool {
        assert!(self.finalized);
        self.nodes[task].preds.is_empty()
            && self.nodes[task].criticality == self.critical_path_len()
    }

    pub fn is_finalized(&self) -> bool {
        self.finalized
    }

    /// Critical-path length (max criticality). 0 for an empty DAG.
    pub fn critical_path_len(&self) -> u32 {
        assert!(self.finalized, "finalize() first");
        self.nodes.iter().map(|n| n.criticality).max().unwrap_or(0)
    }

    /// Average DAG parallelism = tasks / critical-path length (§2).
    pub fn parallelism(&self) -> f64 {
        let cp = self.critical_path_len();
        if cp == 0 {
            return 0.0;
        }
        self.nodes.len() as f64 / cp as f64
    }

    /// The paper's runtime criticality test, applied when `parent` wakes
    /// `child`: the child is critical iff the criticalities differ by 1.
    pub fn is_critical_edge(&self, parent: TaskId, child: TaskId) -> bool {
        self.nodes[parent].criticality == self.nodes[child].criticality + 1
    }

    /// One maximal-length path (node ids), for tests and trace annotation.
    pub fn critical_path(&self) -> Vec<TaskId> {
        assert!(self.finalized);
        let mut path = Vec::new();
        let Some(start) = self
            .nodes
            .iter()
            .max_by_key(|n| n.criticality)
            .map(|n| n.id)
        else {
            return path;
        };
        let mut cur = start;
        path.push(cur);
        loop {
            let next = self.nodes[cur]
                .succs
                .iter()
                .copied()
                .find(|&v| self.nodes[v].criticality + 1 == self.nodes[cur].criticality);
            match next {
                Some(v) => {
                    path.push(v);
                    cur = v;
                }
                None => break,
            }
        }
        path
    }

    /// Seed critical-path membership for execution: per application, the
    /// roots of maximal criticality start that app's critical path
    /// (§3.3: initial tasks are *placed* as non-critical but still hand
    /// the path to their children). `app_of[task]` maps tasks to
    /// applications; an empty slice treats the whole DAG as one app, in
    /// which case this is exactly "roots of global max criticality". The
    /// shared scheduling core ([`crate::coordinator::core::SchedCore`])
    /// seeds its critical-path state from this one implementation, so
    /// sim/real criticality parity cannot drift.
    pub fn cp_root_seeds(&self, app_of: &[usize]) -> Vec<bool> {
        assert!(self.finalized, "finalize() first");
        let n_apps = app_of.iter().copied().max().map_or(1, |m| m + 1);
        let mut max_crit = vec![0u32; n_apps];
        for node in &self.nodes {
            let app = app_of.get(node.id).copied().unwrap_or(0);
            max_crit[app] = max_crit[app].max(node.criticality);
        }
        self.nodes
            .iter()
            .map(|n| {
                let app = app_of.get(n.id).copied().unwrap_or(0);
                n.preds.is_empty() && n.criticality == max_crit[app]
            })
            .collect()
    }

    /// Validate a workload-stream admission schedule against this DAG —
    /// the precondition check of the shared
    /// [`crate::coordinator::core::AdmissionSource`] both stream engines
    /// admit through, kept in one place so the backends cannot drift.
    /// Panics on: an unfinalized
    /// or empty DAG, an empty schedule, an `app_of` map of the wrong
    /// length, unsorted or negative arrival times, and an admission set
    /// that does not cover every root exactly once — a miss would
    /// deadlock the sim and hang the real worker pool forever, so this
    /// last check is a hard assert (O(n log n) once per run) rather than
    /// a debug-only one.
    pub fn validate_admissions(&self, app_of: &[usize], admissions: &[(f64, Vec<TaskId>)]) {
        assert!(self.finalized, "finalize() the DAG first");
        assert!(!self.is_empty(), "empty DAG");
        assert!(!admissions.is_empty(), "a stream needs at least one admission");
        assert!(
            app_of.is_empty() || app_of.len() == self.len(),
            "app_of must be empty or cover every task"
        );
        for w in admissions.windows(2) {
            assert!(w[0].0 <= w[1].0, "admissions must be sorted by arrival time");
        }
        assert!(admissions[0].0 >= 0.0, "arrival times must be non-negative");
        let mut adm: Vec<TaskId> =
            admissions.iter().flat_map(|(_, r)| r.iter().copied()).collect();
        adm.sort_unstable();
        assert_eq!(adm, self.roots(), "admissions must cover every root exactly once");
    }

    /// Count of distinct TAO types referenced (PTT sizing).
    pub fn n_types(&self) -> usize {
        self.nodes.iter().map(|n| n.type_id).max().map_or(0, |m| m + 1)
    }

    /// Total modelled work units (for sanity checks in benches).
    pub fn total_work(&self) -> f64 {
        self.nodes.iter().map(|n| n.class.traits().base_work * n.work_scale).sum()
    }
}

/// Build the 7-task example DAG from Figure 1 of the paper:
/// `A→C→G→D→F` is the critical path (length 5), `B` and `E` are non-critical.
/// Returns (dag, [A,B,C,E,G,D,F] ids).
pub fn paper_figure1_dag() -> (TaoDag, [TaskId; 7]) {
    let mut d = TaoDag::new();
    let a = d.add_task(KernelClass::MatMul, 0, 1.0);
    let b = d.add_task(KernelClass::Sort, 1, 1.0);
    let c = d.add_task(KernelClass::Copy, 2, 1.0);
    let e = d.add_task(KernelClass::Sort, 1, 1.0);
    let g = d.add_task(KernelClass::MatMul, 0, 1.0);
    let dd = d.add_task(KernelClass::Copy, 2, 1.0);
    let f = d.add_task(KernelClass::MatMul, 0, 1.0);
    d.add_edge(a, c);
    d.add_edge(a, e);
    d.add_edge(b, g);
    d.add_edge(c, g);
    d.add_edge(e, dd); // E feeds D but off the critical path
    d.add_edge(g, dd);
    d.add_edge(dd, f);
    d.finalize().unwrap();
    (d, [a, b, c, e, g, dd, f])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_criticalities() {
        let (d, [a, b, c, e, g, dd, f]) = paper_figure1_dag();
        assert_eq!(d.nodes[a].criticality, 5);
        assert_eq!(d.nodes[c].criticality, 4);
        assert_eq!(d.nodes[g].criticality, 3);
        assert_eq!(d.nodes[dd].criticality, 2);
        assert_eq!(d.nodes[f].criticality, 1);
        assert_eq!(d.nodes[b].criticality, 4); // B→G chain
        assert_eq!(d.nodes[e].criticality, 3); // E→D chain
        assert_eq!(d.critical_path_len(), 5);
        assert!((d.parallelism() - 7.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn figure1_critical_edges() {
        let (d, [a, _b, c, e, g, dd, f]) = paper_figure1_dag();
        assert!(d.is_critical_edge(a, c));
        assert!(d.is_critical_edge(c, g));
        assert!(d.is_critical_edge(g, dd));
        assert!(d.is_critical_edge(dd, f));
        assert!(!d.is_critical_edge(a, e)); // 5 vs 3
    }

    #[test]
    fn figure1_critical_path_nodes() {
        let (d, [a, _b, c, _e, g, dd, f]) = paper_figure1_dag();
        assert_eq!(d.critical_path(), vec![a, c, g, dd, f]);
    }

    #[test]
    fn cycle_detected() {
        let mut d = TaoDag::new();
        let x = d.add_task(KernelClass::MatMul, 0, 1.0);
        let y = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, y);
        d.add_edge(y, x);
        assert!(d.finalize().is_err());
    }

    #[test]
    fn chain_parallelism_is_one() {
        let mut d = TaoDag::new();
        let ids: Vec<_> = (0..10).map(|_| d.add_task(KernelClass::Copy, 0, 1.0)).collect();
        for w in ids.windows(2) {
            d.add_edge(w[0], w[1]);
        }
        d.finalize().unwrap();
        assert_eq!(d.critical_path_len(), 10);
        assert_eq!(d.parallelism(), 1.0);
    }

    #[test]
    fn independent_tasks_full_parallelism() {
        let mut d = TaoDag::new();
        for _ in 0..8 {
            d.add_task(KernelClass::Sort, 0, 1.0);
        }
        d.finalize().unwrap();
        assert_eq!(d.critical_path_len(), 1);
        assert_eq!(d.parallelism(), 8.0);
        assert_eq!(d.roots().len(), 8);
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut d = TaoDag::new();
        let x = d.add_task(KernelClass::MatMul, 0, 1.0);
        let y = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, y);
        d.add_edge(x, y);
        assert_eq!(d.nodes[x].succs.len(), 1);
        assert_eq!(d.nodes[y].preds.len(), 1);
    }

    #[test]
    #[should_panic]
    fn self_edge_panics() {
        let mut d = TaoDag::new();
        let x = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, x);
    }

    #[test]
    fn cp_root_seeds_single_app_matches_global_rule() {
        let (d, [a, b, ..]) = paper_figure1_dag();
        let seeds = d.cp_root_seeds(&[]);
        for (id, &seeded) in seeds.iter().enumerate() {
            assert_eq!(seeded, d.is_cp_root(id), "task {id}");
        }
        assert!(seeds[a]); // A starts the length-5 critical path
        assert!(!seeds[b]); // B is a root but criticality 4 < 5
    }

    #[test]
    fn cp_root_seeds_are_per_application() {
        // Two independent components: a 3-chain (app 0) and a single task
        // (app 1). A global max would deny the short app a critical path;
        // per-app seeding marks both components' top roots.
        let mut d = TaoDag::new();
        let c0 = d.add_task(KernelClass::MatMul, 0, 1.0);
        let c1 = d.add_task(KernelClass::MatMul, 0, 1.0);
        let c2 = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_edge(c0, c1);
        d.add_edge(c1, c2);
        let _solo = d.add_task(KernelClass::Sort, 1, 1.0);
        d.finalize().unwrap();
        let seeds = d.cp_root_seeds(&[0, 0, 0, 1]);
        assert_eq!(seeds, vec![true, false, false, true]);
        // The app-blind view seeds only the long chain's root.
        assert_eq!(d.cp_root_seeds(&[]), vec![true, false, false, false]);
    }

    #[test]
    fn validate_admissions_accepts_a_sound_schedule() {
        let (d, _) = paper_figure1_dag();
        // A and B are the two roots, split across two admissions.
        d.validate_admissions(&[], &[(0.0, vec![0]), (0.5, vec![1])]);
    }

    #[test]
    #[should_panic(expected = "sorted by arrival")]
    fn validate_admissions_rejects_unsorted_arrivals() {
        let (d, _) = paper_figure1_dag();
        d.validate_admissions(&[], &[(0.5, vec![0]), (0.0, vec![1])]);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn validate_admissions_rejects_negative_arrivals() {
        let (d, _) = paper_figure1_dag();
        d.validate_admissions(&[], &[(-0.1, vec![0, 1])]);
    }

    #[test]
    fn n_types_counts_max() {
        let mut d = TaoDag::new();
        d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_task(KernelClass::Sort, 3, 1.0);
        assert_eq!(d.n_types(), 4);
    }

    #[test]
    fn max_width_defaults_to_class_parallelism_and_overrides() {
        let mut d = TaoDag::new();
        let m = d.add_task(KernelClass::MatMul, 0, 1.0);
        let s = d.add_task(KernelClass::Sort, 1, 1.0);
        assert_eq!(d.nodes[m].max_width, KernelClass::MatMul.traits().max_parallelism);
        assert_eq!(d.nodes[s].max_width, KernelClass::Sort.traits().max_parallelism);
        d.set_max_width(s, 1);
        assert_eq!(d.nodes[s].max_width, 1);
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn zero_max_width_rejected() {
        let mut d = TaoDag::new();
        let x = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.set_max_width(x, 0);
    }

    #[test]
    fn max_width_cap_twin_preserves_structure() {
        let mut d = TaoDag::new();
        let m = d.add_task(KernelClass::MatMul, 0, 1.0); // class cap 8
        let s = d.add_task(KernelClass::Sort, 1, 2.0); // class cap 4
        d.add_edge_bytes(m, s, 512);
        d.finalize().unwrap();
        let narrow = d.with_max_width_cap(1);
        assert!(narrow.is_finalized());
        assert!(narrow.nodes.iter().all(|n| n.max_width == 1));
        assert_eq!(narrow.edge_bytes(m, s), Some(512));
        assert_eq!(narrow.nodes[m].criticality, d.nodes[m].criticality);
        assert_eq!(narrow.nodes[s].work_scale, 2.0);
        // A cap above the class defaults changes nothing.
        let same = d.with_max_width_cap(64);
        assert_eq!(same.nodes[m].max_width, d.nodes[m].max_width);
        assert_eq!(same.nodes[s].max_width, d.nodes[s].max_width);
    }

    #[test]
    fn edge_bytes_recorded_and_duplicates_keep_max() {
        let mut d = TaoDag::new();
        let x = d.add_task(KernelClass::MatMul, 0, 1.0);
        let y = d.add_task(KernelClass::MatMul, 0, 1.0);
        let z = d.add_task(KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, y); // control-only
        d.add_edge_bytes(x, z, 4096);
        d.add_edge_bytes(x, z, 1024); // duplicate keeps the larger item
        assert_eq!(d.edge_bytes(x, y), Some(0));
        assert_eq!(d.edge_bytes(x, z), Some(4096));
        assert_eq!(d.edge_bytes(y, z), None);
        assert_eq!(d.total_edge_bytes(), 4096);
        assert_eq!(d.nodes[x].succs.len(), d.nodes[x].succ_bytes.len());
    }

    #[test]
    fn topo_order_respects_edges() {
        let (d, _) = paper_figure1_dag();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; d.len()];
            for (i, &t) in order.iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for n in &d.nodes {
            for &s in &n.succs {
                assert!(pos[n.id] < pos[s]);
            }
        }
    }
}
