//! Execution traces and derived metrics.
//!
//! Both engines record one [`TraceRecord`] per executed TAO. The figure
//! harnesses derive everything from these records: throughput (Fig 5/6),
//! speedups (Fig 7), per-core scheduling timelines (Fig 8), scaling
//! (Fig 9) and width histograms (Fig 10).

use crate::platform::{KernelClass, Partition};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One executed TAO.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub task: usize,
    pub class: KernelClass,
    pub type_id: usize,
    pub critical: bool,
    pub partition: Partition,
    /// Seconds since run start (virtual or wall).
    pub t_start: f64,
    pub t_end: f64,
}

impl TraceRecord {
    pub fn exec_time(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// Thread-safe trace collector.
#[derive(Debug, Default)]
pub struct Trace {
    records: Mutex<Vec<TraceRecord>>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn push(&self, r: TraceRecord) {
        self.records.lock().unwrap().push(r);
    }

    pub fn into_records(self) -> Vec<TraceRecord> {
        self.records.into_inner().unwrap()
    }

    pub fn snapshot(&self) -> Vec<TraceRecord> {
        self.records.lock().unwrap().clone()
    }
}

/// Result of one DAG execution.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: String,
    pub platform: String,
    /// Total run time, seconds (virtual or wall).
    pub makespan: f64,
    pub records: Vec<TraceRecord>,
}

impl RunResult {
    pub fn n_tasks(&self) -> usize {
        self.records.len()
    }

    /// Tasks per second — the paper's throughput metric (Fig 5/6).
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / self.makespan
    }

    /// `width → number of TAOs` (Fig 10).
    pub fn width_histogram(&self) -> BTreeMap<usize, usize> {
        let mut h = BTreeMap::new();
        for r in &self.records {
            *h.entry(r.partition.width).or_insert(0) += 1;
        }
        h
    }

    /// `width → percentage of TAOs` (Fig 10's Y axis).
    pub fn width_percentages(&self) -> BTreeMap<usize, f64> {
        let n = self.records.len().max(1) as f64;
        self.width_histogram().into_iter().map(|(w, c)| (w, 100.0 * c as f64 / n)).collect()
    }

    /// Records of critical tasks only (Fig 8 plots these).
    pub fn critical_records(&self) -> Vec<&TraceRecord> {
        self.records.iter().filter(|r| r.critical).collect()
    }

    /// Distinct leader cores used by critical tasks inside `[t0, t1)`.
    pub fn critical_leaders_in_window(&self, t0: f64, t1: f64) -> Vec<usize> {
        let mut cores: Vec<usize> = self
            .records
            .iter()
            .filter(|r| r.critical && r.t_start >= t0 && r.t_start < t1)
            .map(|r| r.partition.leader)
            .collect();
        cores.sort_unstable();
        cores.dedup();
        cores
    }

    /// Mean execution time of records matching `class`.
    pub fn mean_exec_time(&self, class: KernelClass) -> f64 {
        let times: Vec<f64> =
            self.records.iter().filter(|r| r.class == class).map(|r| r.exec_time()).collect();
        crate::util::stats::mean(&times)
    }

    /// Per-core busy time (sum over records of exec_time for every core in
    /// the partition). Index = core id.
    pub fn core_busy_time(&self, n_cores: usize) -> Vec<f64> {
        let mut busy = vec![0.0; n_cores];
        for r in &self.records {
            for c in r.partition.cores() {
                if c < n_cores {
                    busy[c] += r.exec_time();
                }
            }
        }
        busy
    }

    /// Overall resource utilisation in `[0,1]`: busy core-seconds over
    /// `n_cores × makespan`.
    pub fn utilisation(&self, n_cores: usize) -> f64 {
        if self.makespan <= 0.0 || n_cores == 0 {
            return 0.0;
        }
        self.core_busy_time(n_cores).iter().sum::<f64>() / (n_cores as f64 * self.makespan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(task: usize, critical: bool, leader: usize, width: usize, t0: f64, t1: f64) -> TraceRecord {
        TraceRecord {
            task,
            class: KernelClass::MatMul,
            type_id: 0,
            critical,
            partition: Partition { leader, width },
            t_start: t0,
            t_end: t1,
        }
    }

    fn result(records: Vec<TraceRecord>, makespan: f64) -> RunResult {
        RunResult { policy: "test".into(), platform: "test".into(), makespan, records }
    }

    #[test]
    fn throughput_tasks_over_makespan() {
        let r = result(vec![rec(0, false, 0, 1, 0.0, 1.0), rec(1, false, 1, 1, 0.0, 2.0)], 4.0);
        assert_eq!(r.throughput(), 0.5);
    }

    #[test]
    fn width_histogram_counts() {
        let r = result(
            vec![
                rec(0, false, 0, 1, 0.0, 1.0),
                rec(1, false, 0, 4, 0.0, 1.0),
                rec(2, false, 0, 4, 1.0, 2.0),
            ],
            2.0,
        );
        let h = r.width_histogram();
        assert_eq!(h[&1], 1);
        assert_eq!(h[&4], 2);
        let p = r.width_percentages();
        assert!((p[&4] - 66.666).abs() < 0.01);
    }

    #[test]
    fn critical_window_filter() {
        let r = result(
            vec![
                rec(0, true, 2, 1, 0.0, 1.0),
                rec(1, true, 5, 1, 2.0, 3.0),
                rec(2, false, 7, 1, 2.0, 3.0),
            ],
            3.0,
        );
        assert_eq!(r.critical_leaders_in_window(0.0, 1.5), vec![2]);
        assert_eq!(r.critical_leaders_in_window(1.5, 3.0), vec![5]);
        assert_eq!(r.critical_records().len(), 2);
    }

    #[test]
    fn busy_time_spans_partition() {
        let r = result(vec![rec(0, false, 0, 2, 0.0, 3.0)], 3.0);
        let busy = r.core_busy_time(4);
        assert_eq!(busy, vec![3.0, 3.0, 0.0, 0.0]);
        assert!((r.utilisation(4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trace_collects_concurrently() {
        use std::sync::Arc;
        let trace = Arc::new(Trace::new());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let t = trace.clone();
                std::thread::spawn(move || {
                    t.push(rec(i, false, 0, 1, 0.0, 1.0));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(trace.snapshot().len(), 4);
    }
}
