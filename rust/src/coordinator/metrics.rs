//! Execution traces and derived metrics.
//!
//! Both engines record one [`TraceRecord`] per executed TAO — constructed
//! in one place, the shared scheduling core's commit
//! ([`crate::coordinator::core::SchedCore::commit`]); the substrates only
//! decide where the record is stored. The figure harnesses derive
//! everything from these records: throughput (Fig 5/6), speedups (Fig 7),
//! per-core scheduling timelines (Fig 8), scaling (Fig 9) and width
//! histograms (Fig 10).
//!
//! Multi-application runs (see [`crate::workload`]) tag every record with
//! the submitting application's `app_id`; the per-app accounting —
//! [`AppMetrics`], [`per_app_metrics`], [`jain_fairness_index`] — lives
//! here so both backends and the bench harnesses share one definition of
//! per-app makespan, slowdown and fairness.

pub mod lower_bound;

use crate::platform::{KernelClass, Partition};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One executed TAO.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub task: usize,
    /// Submitting application (0 for single-DAG runs).
    pub app_id: usize,
    pub class: KernelClass,
    pub type_id: usize,
    pub critical: bool,
    pub partition: Partition,
    /// Seconds since run start (virtual or wall).
    pub t_start: f64,
    pub t_end: f64,
}

impl TraceRecord {
    pub fn exec_time(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// Mutex-guarded trace collector for ad-hoc/test use: every `push` takes
/// the one global lock. The real engine no longer records through it —
/// each worker commits into its own private shard (a plain `&mut
/// Vec<TraceRecord>`, see `coordinator::worker`) merged after the workers
/// join and sorted with [`sort_by_commit`].
#[derive(Debug, Default)]
pub struct Trace {
    records: Mutex<Vec<TraceRecord>>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn push(&self, r: TraceRecord) {
        self.records.lock().unwrap().push(r);
    }

    pub fn into_records(self) -> Vec<TraceRecord> {
        self.records.into_inner().unwrap()
    }

    pub fn snapshot(&self) -> Vec<TraceRecord> {
        self.records.lock().unwrap().clone()
    }
}

/// Deterministic total order for merged wall-clock traces: commit time
/// (`t_end`), then task id. Task ids are unique, so the comparator is
/// total — the real engine concatenates its per-worker trace shards and
/// sorts with this, so the shard layout (which worker committed what) can
/// never leak into `RunResult::records`: the same record set sorts to the
/// same sequence, bit for bit. (The sim backend keeps its historical
/// stable-by-`t_start` sort; its single-threaded completion order is
/// already deterministic.)
pub fn sort_by_commit(records: &mut [TraceRecord]) {
    records.sort_unstable_by(|a, b| a.t_end.total_cmp(&b.t_end).then(a.task.cmp(&b.task)));
}

/// Result of one DAG execution.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: String,
    pub platform: String,
    /// Total run time, seconds (virtual or wall).
    pub makespan: f64,
    pub records: Vec<TraceRecord>,
    /// Makespan lower bound for this run, filled by the exec-layer
    /// drivers (`run_triple` and friends) with the variant that is sound
    /// for the backend that produced the result — see
    /// [`lower_bound`]. `None` for raw engine results and for untraced
    /// wall-clock runs (nothing to bound from).
    pub bound: Option<lower_bound::MakespanBound>,
}

impl RunResult {
    pub fn n_tasks(&self) -> usize {
        self.records.len()
    }

    /// Tasks per second — the paper's throughput metric (Fig 5/6).
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / self.makespan
    }

    /// `width → number of TAOs` (Fig 10).
    pub fn width_histogram(&self) -> BTreeMap<usize, usize> {
        let mut h = BTreeMap::new();
        for r in &self.records {
            *h.entry(r.partition.width).or_insert(0) += 1;
        }
        h
    }

    /// `width → percentage of TAOs` (Fig 10's Y axis).
    pub fn width_percentages(&self) -> BTreeMap<usize, f64> {
        let n = self.records.len().max(1) as f64;
        self.width_histogram().into_iter().map(|(w, c)| (w, 100.0 * c as f64 / n)).collect()
    }

    /// Records of critical tasks only (Fig 8 plots these).
    pub fn critical_records(&self) -> Vec<&TraceRecord> {
        self.records.iter().filter(|r| r.critical).collect()
    }

    /// Distinct leader cores used by critical tasks inside `[t0, t1)`.
    pub fn critical_leaders_in_window(&self, t0: f64, t1: f64) -> Vec<usize> {
        let mut cores: Vec<usize> = self
            .records
            .iter()
            .filter(|r| r.critical && r.t_start >= t0 && r.t_start < t1)
            .map(|r| r.partition.leader)
            .collect();
        cores.sort_unstable();
        cores.dedup();
        cores
    }

    /// Mean execution time of records matching `class`.
    pub fn mean_exec_time(&self, class: KernelClass) -> f64 {
        let times: Vec<f64> =
            self.records.iter().filter(|r| r.class == class).map(|r| r.exec_time()).collect();
        crate::util::stats::mean(&times)
    }

    /// Per-core busy time (sum over records of exec_time for every core in
    /// the partition). Index = core id.
    pub fn core_busy_time(&self, n_cores: usize) -> Vec<f64> {
        let mut busy = vec![0.0; n_cores];
        for r in &self.records {
            for c in r.partition.cores() {
                if c < n_cores {
                    busy[c] += r.exec_time();
                }
            }
        }
        busy
    }

    /// Overall resource utilisation in `[0,1]`: busy core-seconds over
    /// `n_cores × makespan`.
    pub fn utilisation(&self, n_cores: usize) -> f64 {
        if self.makespan <= 0.0 || n_cores == 0 {
            return 0.0;
        }
        self.core_busy_time(n_cores).iter().sum::<f64>() / (n_cores as f64 * self.makespan)
    }

    // --- per-application views (multi-app workload streams) ---------------

    /// Distinct application ids present in the trace, ascending. A
    /// single-DAG run yields `[0]` (every record carries `app_id` 0).
    pub fn app_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.records.iter().map(|r| r.app_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Records belonging to one application, in trace order.
    pub fn records_for_app(&self, app_id: usize) -> Vec<&TraceRecord> {
        self.records.iter().filter(|r| r.app_id == app_id).collect()
    }

    /// Number of executed TAOs attributed to `app_id`.
    pub fn app_task_count(&self, app_id: usize) -> usize {
        self.records.iter().filter(|r| r.app_id == app_id).count()
    }

    /// Completion time of one application: the latest `t_end` among its
    /// records (0.0 if the app has no records).
    pub fn app_completion(&self, app_id: usize) -> f64 {
        self.records
            .iter()
            .filter(|r| r.app_id == app_id)
            .map(|r| r.t_end)
            .fold(0.0, f64::max)
    }

    /// Per-app throughput: the app's task count over its response time
    /// (completion − arrival). 0.0 when the app completed no tasks.
    pub fn app_throughput(&self, app_id: usize, arrival: f64) -> f64 {
        let n = self.app_task_count(app_id);
        let span = self.app_completion(app_id) - arrival;
        if n == 0 || span <= 0.0 {
            return 0.0;
        }
        n as f64 / span
    }

    /// Critical records of one application (the app-aware counterpart of
    /// [`RunResult::critical_records`], which spans all apps).
    pub fn critical_records_for_app(&self, app_id: usize) -> Vec<&TraceRecord> {
        self.records.iter().filter(|r| r.critical && r.app_id == app_id).collect()
    }
}

/// Per-application accounting for one multi-app run.
///
/// `makespan()` is the app's *response time* — last task completion minus
/// the arrival (admission) time, the quantity the co-scheduling literature
/// compares against an isolated run to obtain slowdown.
#[derive(Debug, Clone)]
pub struct AppMetrics {
    pub app_id: usize,
    pub name: String,
    /// Admission time of the app's root tasks (virtual or wall seconds).
    pub arrival: f64,
    pub n_tasks: usize,
    /// Earliest `t_start` among the app's records (= arrival when a root
    /// starts immediately).
    pub first_start: f64,
    /// Latest `t_end` among the app's records.
    pub completion: f64,
    /// Makespan of the same app run alone (same backend/platform/policy,
    /// fresh PTT); filled by baseline-aware drivers, `None` otherwise.
    pub isolated_makespan: Option<f64>,
    /// `makespan() / isolated_makespan` — ≥ 1 under contention (up to
    /// scheduler noise). `None` until a baseline run is attached.
    pub slowdown: Option<f64>,
    /// Observed lower bound on this app's response time
    /// ([`lower_bound::observed_app_bound`]); filled by the exec-layer
    /// stream drivers, `None` for apps with no records.
    pub bound: Option<f64>,
}

impl AppMetrics {
    /// Response time: completion − arrival, clamped at 0.
    pub fn makespan(&self) -> f64 {
        (self.completion - self.arrival).max(0.0)
    }

    /// Response time as a percentage of the observed lower bound
    /// (`≥ 100` up to timer resolution); `None` without a bound or for a
    /// degenerate (zero) bound.
    pub fn pct_of_bound(&self) -> Option<f64> {
        match self.bound {
            Some(b) if b > 0.0 => Some(100.0 * self.makespan() / b),
            _ => None,
        }
    }

    /// Attach an isolated-run baseline and derive the slowdown.
    pub fn with_isolated(mut self, isolated_makespan: f64) -> AppMetrics {
        self.isolated_makespan = Some(isolated_makespan);
        self.slowdown = if isolated_makespan > 0.0 {
            Some(self.makespan() / isolated_makespan)
        } else {
            None
        };
        self
    }
}

/// Derive [`AppMetrics`] for every `(app_id, name, arrival)` triple from a
/// tagged trace. Apps with no records report zero tasks and a zero-length
/// makespan (completion = arrival), which keeps aggregate fairness math
/// well-defined mid-stream.
pub fn per_app_metrics(result: &RunResult, apps: &[(usize, String, f64)]) -> Vec<AppMetrics> {
    apps.iter()
        .map(|(app_id, name, arrival)| {
            let recs = result.records_for_app(*app_id);
            let first_start =
                recs.iter().map(|r| r.t_start).fold(f64::INFINITY, f64::min);
            let completion = recs.iter().map(|r| r.t_end).fold(*arrival, f64::max);
            AppMetrics {
                app_id: *app_id,
                name: name.clone(),
                arrival: *arrival,
                n_tasks: recs.len(),
                first_start: if recs.is_empty() { *arrival } else { first_start },
                completion,
                isolated_makespan: None,
                slowdown: None,
                bound: None,
            }
        })
        .collect()
}

/// Jain's fairness index over positive allocations:
/// `J = (Σx)² / (n · Σx²)`, in `(0, 1]`; 1 iff all allocations are equal,
/// approaching `1/n` as one app dominates. Returns 1.0 for an empty slice
/// (a degenerate stream is trivially fair). Non-positive entries are
/// rejected — fairness over "negative progress" has no meaning here.
pub fn jain_fairness_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    assert!(
        xs.iter().all(|&x| x > 0.0 && x.is_finite()),
        "Jain index needs positive finite allocations, got {xs:?}"
    );
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|&x| x * x).sum();
    (sum * sum) / (xs.len() as f64 * sum_sq)
}

/// Total (non-panicking) Jain index for *live* control loops.
///
/// The strict [`jain_fairness_index`] is right for offline metrics, where
/// a non-positive allocation is a harness bug worth crashing on. Fed live
/// into the serving fairness loop it is fatal: an app admitted moments ago
/// legitimately has **zero** completed tasks in the current window.
///
/// Epsilon semantics: non-positive and non-finite entries are clamped to
/// `1e-12` rather than skipped — zero progress is the *worst* allocation,
/// so starvation must drag the index toward `1/n` instead of silently
/// vanishing from the denominator. Returns 1.0 for an empty slice.
pub fn jain_fairness_total(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let clamped: Vec<f64> =
        xs.iter().map(|&x| if x.is_finite() && x > 0.0 { x } else { 1e-12 }).collect();
    let sum: f64 = clamped.iter().sum();
    let sum_sq: f64 = clamped.iter().map(|&x| x * x).sum();
    (sum * sum) / (clamped.len() as f64 * sum_sq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(task: usize, critical: bool, leader: usize, width: usize, t0: f64, t1: f64) -> TraceRecord {
        TraceRecord {
            task,
            app_id: 0,
            class: KernelClass::MatMul,
            type_id: 0,
            critical,
            partition: Partition { leader, width },
            t_start: t0,
            t_end: t1,
        }
    }

    fn rec_app(task: usize, app_id: usize, critical: bool, t0: f64, t1: f64) -> TraceRecord {
        TraceRecord { app_id, ..rec(task, critical, 0, 1, t0, t1) }
    }

    fn result(records: Vec<TraceRecord>, makespan: f64) -> RunResult {
        RunResult { policy: "test".into(), platform: "test".into(), makespan, records, bound: None }
    }

    #[test]
    fn throughput_tasks_over_makespan() {
        let r = result(vec![rec(0, false, 0, 1, 0.0, 1.0), rec(1, false, 1, 1, 0.0, 2.0)], 4.0);
        assert_eq!(r.throughput(), 0.5);
    }

    #[test]
    fn width_histogram_counts() {
        let r = result(
            vec![
                rec(0, false, 0, 1, 0.0, 1.0),
                rec(1, false, 0, 4, 0.0, 1.0),
                rec(2, false, 0, 4, 1.0, 2.0),
            ],
            2.0,
        );
        let h = r.width_histogram();
        assert_eq!(h[&1], 1);
        assert_eq!(h[&4], 2);
        let p = r.width_percentages();
        assert!((p[&4] - 66.666).abs() < 0.01);
    }

    #[test]
    fn critical_window_filter() {
        let r = result(
            vec![
                rec(0, true, 2, 1, 0.0, 1.0),
                rec(1, true, 5, 1, 2.0, 3.0),
                rec(2, false, 7, 1, 2.0, 3.0),
            ],
            3.0,
        );
        assert_eq!(r.critical_leaders_in_window(0.0, 1.5), vec![2]);
        assert_eq!(r.critical_leaders_in_window(1.5, 3.0), vec![5]);
        assert_eq!(r.critical_records().len(), 2);
    }

    #[test]
    fn busy_time_spans_partition() {
        let r = result(vec![rec(0, false, 0, 2, 0.0, 3.0)], 3.0);
        let busy = r.core_busy_time(4);
        assert_eq!(busy, vec![3.0, 3.0, 0.0, 0.0]);
        assert!((r.utilisation(4) - 0.5).abs() < 1e-12);
    }

    // Single-DAG behavior pins: adding the app dimension must not change
    // what the old helpers report for an untagged (all-app-0) trace.
    #[test]
    fn single_dag_helpers_unchanged_by_app_dimension() {
        let r = result(
            vec![
                rec(0, true, 0, 1, 0.0, 1.0),
                rec(1, false, 1, 1, 0.5, 2.0),
                rec(2, false, 2, 1, 1.0, 4.0),
            ],
            4.0,
        );
        // throughput() still counts ALL records over the global makespan.
        assert_eq!(r.throughput(), 0.75);
        // critical_records() still spans every app.
        assert_eq!(r.critical_records().len(), 1);
        assert_eq!(r.n_tasks(), 3);
        // The whole trace is app 0.
        assert_eq!(r.app_ids(), vec![0]);
        assert_eq!(r.app_task_count(0), 3);
        assert_eq!(r.app_completion(0), 4.0);
    }

    #[test]
    fn app_views_partition_the_trace() {
        let r = result(
            vec![
                rec_app(0, 0, true, 0.0, 1.0),
                rec_app(1, 1, false, 0.5, 2.0),
                rec_app(2, 0, false, 1.0, 3.0),
                rec_app(3, 1, true, 2.0, 5.0),
            ],
            5.0,
        );
        assert_eq!(r.app_ids(), vec![0, 1]);
        assert_eq!(r.app_task_count(0), 2);
        assert_eq!(r.app_task_count(1), 2);
        assert_eq!(r.app_task_count(7), 0);
        assert_eq!(r.app_completion(0), 3.0);
        assert_eq!(r.app_completion(1), 5.0);
        assert_eq!(r.critical_records_for_app(0).len(), 1);
        assert_eq!(r.critical_records_for_app(1).len(), 1);
        // Per-app counts sum to the trace length.
        let total: usize = r.app_ids().iter().map(|&a| r.app_task_count(a)).sum();
        assert_eq!(total, r.records.len());
        // App 1 arrived at 0.5: 2 tasks over 4.5 s.
        assert!((r.app_throughput(1, 0.5) - 2.0 / 4.5).abs() < 1e-12);
    }

    #[test]
    fn per_app_metrics_and_slowdown() {
        let r = result(
            vec![rec_app(0, 0, false, 0.0, 2.0), rec_app(1, 1, false, 1.0, 4.0)],
            4.0,
        );
        let apps =
            vec![(0usize, "a".to_string(), 0.0), (1usize, "b".to_string(), 1.0)];
        let m = per_app_metrics(&r, &apps);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].n_tasks, 1);
        assert_eq!(m[0].makespan(), 2.0);
        assert_eq!(m[1].makespan(), 3.0); // 4.0 end − 1.0 arrival
        assert_eq!(m[1].first_start, 1.0);
        let with_base = m[1].clone().with_isolated(1.5);
        assert_eq!(with_base.slowdown, Some(2.0));
        // An app with no records yet: zero tasks, zero-length makespan.
        let empty = per_app_metrics(&r, &[(9usize, "late".to_string(), 3.0)]);
        assert_eq!(empty[0].n_tasks, 0);
        assert_eq!(empty[0].makespan(), 0.0);
    }

    #[test]
    fn jain_index_bounds_and_extremes() {
        assert_eq!(jain_fairness_index(&[]), 1.0);
        assert_eq!(jain_fairness_index(&[3.7]), 1.0);
        assert!((jain_fairness_index(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // One app hogging everything: J → 1/n.
        let j = jain_fairness_index(&[100.0, 1e-9, 1e-9, 1e-9]);
        assert!(j > 0.0 && j < 0.2601, "{j}");
        let j2 = jain_fairness_index(&[1.0, 3.0]);
        assert!(j2 > 0.0 && j2 < 1.0);
    }

    #[test]
    #[should_panic]
    fn jain_index_rejects_nonpositive() {
        jain_fairness_index(&[1.0, 0.0]);
    }

    #[test]
    fn jain_total_is_total_and_matches_strict_on_positive_input() {
        // Agrees with the strict variant wherever the strict one is defined.
        for xs in [vec![3.7], vec![2.0, 2.0, 2.0], vec![1.0, 3.0]] {
            assert_eq!(jain_fairness_total(&xs), jain_fairness_index(&xs));
        }
        assert_eq!(jain_fairness_total(&[]), 1.0);
        // Inputs that panic the strict variant: zero progress clamps to
        // epsilon and drags fairness down (starvation ≠ fairness).
        let j = jain_fairness_total(&[1.0, 0.0]);
        assert!(j > 0.0 && j < 0.51, "{j}");
        let j = jain_fairness_total(&[1.0, f64::NAN, -2.0, f64::INFINITY]);
        assert!(j > 0.0 && j < 0.26, "{j}");
        // All-zero window: every app is equally (non-)progressing.
        assert!((jain_fairness_total(&[0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    // Regression pin for the sharded real-engine trace: the final record
    // order must be a pure function of the record *set* — two different
    // merge interleavings (different shard assignments of the same
    // commits) sort identically, including tied commit times.
    #[test]
    fn merged_trace_order_is_deterministic_regardless_of_shard_order() {
        let recs = vec![
            rec(3, false, 1, 1, 0.0, 2.0),
            rec(1, true, 0, 1, 0.5, 1.0),
            rec(2, false, 2, 1, 0.2, 1.0), // ties task 1 on t_end
            rec(0, false, 3, 1, 0.1, 3.0),
        ];
        let mut a = recs.clone();
        let mut b: Vec<TraceRecord> = recs.iter().rev().copied().collect();
        sort_by_commit(&mut a);
        sort_by_commit(&mut b);
        assert_eq!(a, b, "merge order must not leak into the sorted trace");
        // (t_end, task): the t_end tie between tasks 1 and 2 breaks by id.
        let order: Vec<usize> = a.iter().map(|r| r.task).collect();
        assert_eq!(order, vec![1, 2, 3, 0]);
    }

    #[test]
    fn trace_collects_concurrently() {
        use std::sync::Arc;
        let trace = Arc::new(Trace::new());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let t = trace.clone();
                std::thread::spawn(move || {
                    t.push(rec(i, false, 0, 1, 0.0, 1.0));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(trace.snapshot().len(), 4);
    }
}
