//! Real-thread execution engine.
//!
//! One worker thread per (virtual) core runs the XiTAO loop from §3.1/§3.3:
//!
//! 1. fetch from the own **assembly queue** and execute the next TAO share;
//! 2. otherwise pop the own **work-stealing queue**, decide the placement
//!    with the active [`Policy`] and insert the TAO into the AQs of the
//!    chosen partition;
//! 3. otherwise **steal** from a victim's WSQ (the thief becomes the
//!    deciding core — §3.3's "locally executed or randomly stolen");
//!    victims are probed in topology order — random picks inside the own
//!    cluster first, the cross-cluster full sweep only as a last resort
//!    before parking, and that sweep takes half the victim's queue in one
//!    batched visit ([`super::wsq::WsQueue::steal_half`]).
//!
//! TAO instances are executed cooperatively: each member core claims a rank
//! on arrival at its AQ head and runs `payload.execute(rank, width)`
//! immediately (XiTAO's asynchronous entry/exit — no entry barrier). The
//! last rank to finish performs *commit-and-wake-up* via the shared
//! scheduling core ([`SchedCore::commit`]): dependency release, the §3.3
//! criticality re-derivation and the trace record are the *same code
//! objects* the sim engine runs; this substrate only decides that released
//! children land on the committer's own WSQ. Likewise every placement
//! decision is [`SchedCore::place`] — this file owns no PlaceCtx-building
//! or wake-up logic of its own, only the lock-free queues, the parking
//! protocol, and wall-clock execution.
//!
//! The **leader core** times its own share and is the only writer of the
//! PTT entry ([`SchedCore::record_leader_share`], invoked from the
//! leader's thread) — the paper's design for avoiding cache-line
//! migration.
//!
//! On the single-core build host this engine validates *functionality*
//! (the perf figures come from `crate::sim`); on a real multicore it is a
//! faithful runtime, including optional thread pinning.
//!
//! ## Hot-path concurrency
//!
//! The paper calls the PTT "a lightweight, lock-free manifest of per-core
//! latency"; this engine's own bookkeeping is held to the same standard —
//! no scheduling operation takes a lock:
//!
//! - **WSQs** are Chase–Lev deques ([`super::wsq`]): owner LIFO push/pop,
//!   thief FIFO steal via one CAS on `top`; starving thieves use the
//!   batched [`super::wsq::WsQueue::steal_half`] to amortize the CAS
//!   cache-line traffic across up to half the victim's queue.
//! - **Steal order is topology-aware**: each core's victim order is built
//!   once from [`Topology`] — own cluster first, then by cluster
//!   distance, rotated within each tier so core 0 isn't everyone's first
//!   guess. The cheap random probe stays inside the near (same-cluster)
//!   tier, bounded-retried before the O(n) sweep; only the sweep crosses
//!   clusters, so steals stay cache-local until locality has provably
//!   nothing left to offer.
//! - **AQs** are Vyukov MPSC queues ([`super::aq`]): any placer pushes,
//!   only the owning core pops. They carry word-sized [`FrameId`]s into a
//!   per-run [`FrameArena`] ([`super::arena`]) — placement bump-allocates
//!   a frame, nothing on the execute/commit path touches the allocator,
//!   and frames are freed wholesale when the run's `Shared` drops (after
//!   the thread scope joins — the whole reclamation argument).
//! - **Trace commits** go to per-worker cache-padded shards: each worker
//!   owns a disjoint `&mut Vec<TraceRecord>` (no sharing, no unsafe),
//!   merged once after the workers join and sorted by the deterministic
//!   `(t_end, task)` order ([`super::metrics::sort_by_commit`]).
//! - **Admission** crosses into live workers through per-core lock-free
//!   inboxes ([`super::inbox`]) — the deque's bottom end is owner-only.
//!
//! Idle workers do not burn the cores the PTT is profiling: after a short
//! spin/yield backoff and one full steal sweep, a worker parks. The
//! sleep/wake race is closed by a store-buffer (Dekker) handshake: the
//! sleeper advertises itself (parked flag + `n_parked` counter), issues a
//! `SeqCst` fence, then re-scans *every* work source and only sleeps if
//! all are still empty; a producer publishes its work, issues a `SeqCst`
//! fence, and unparks flagged sleepers only when `n_parked > 0`. The
//! paired fences guarantee at least one side observes the other: either
//! the producer sees the counter (and its unpark token makes a pre-park
//! `unpark` stick) or the sleeper's re-scan sees the published work. On
//! the common busy path the producer cost is one fence + one load of a
//! read-mostly counter — no contended RMW. A bounded `park_timeout`
//! backstops the protocol. See DESIGN.md §Hot-path concurrency.
//!
//! ## Multi-application admission
//!
//! [`run_stream_real`] executes a workload stream: a dedicated *submitter*
//! thread sleeps until each application's wall-clock arrival time and then
//! hands that app's root tasks to the live worker pool through the
//! per-core admission inboxes (round-robin, like the initial root
//! distribution); each owner drains its inbox into its own work-stealing
//! queue, so workers never notice the difference between bootstrap roots
//! and admitted roots and the engine's deadlock-freedom argument is
//! unchanged. [`run_dag_real`] is the degenerate stream (one app,
//! arrival 0).
//!
//! ## Fault tolerance
//!
//! Three independent mechanisms (see DESIGN.md §Fault tolerance):
//!
//! - **Panic isolation**: every payload runs under `catch_unwind`. A
//!   panicking TAO is counted failed ([`SchedCore::note_failed`]), its
//!   timing never reaches the PTT, but its instance still commits — a
//!   failed task is a *terminal* state, not a wedge, so dependents release
//!   and the run completes.
//! - **Cooperative fail-stop**: fail-stop episodes are served by the dying
//!   worker itself — it publishes its death through the core's dead mask,
//!   drains its own inbox/AQ/deque to live cores (owner-side drains are
//!   the only safe ones on live single-consumer structures) and naps
//!   outside the park handshake until its recovery boundary. Strays that
//!   race into its queues around the failure edge are re-routed on every
//!   nap slice.
//! - **Watchdog**: a supervisor thread reclaims the queues of *departed*
//!   workers (a panic that escaped a worker loop — caught at the thread
//!   boundary so the scope's join doesn't propagate it) and steal-drains
//!   the deque of workers whose heartbeat goes stale (hung or crawling) —
//!   the only thief-safe operation on a live worker. Reclaimed tasks
//!   re-enter through live inboxes; the shared core's commit latch makes
//!   re-admission idempotent, so every task commits exactly once.

use super::aq::AssemblyQueue;
use super::arena::{FrameArena, FrameId, LEADER_UNSET};
use super::core::{
    AdmissionSource, CommitInfo, SchedCore, ServingApp, ServingOpts, ServingRun, ServingSource,
};
use super::dag::{TaoDag, TaskId};
use super::episodes_rt::EpisodeDriver;
use super::inbox::Inbox;
use super::metrics::{RunResult, TraceRecord, jain_fairness_total, sort_by_commit};
use super::ptt::Ptt;
use super::scheduler::{Policy, QosClass};
use super::wsq::WsQueue;
use crate::error::SchedError;
use crate::platform::{EpisodeKind, EpisodeSchedule, Topology};
use crate::util::Pcg32;
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering, fence};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Engine options.
#[derive(Debug, Clone)]
pub struct RealEngineOpts {
    /// Pin worker `i` to cpu `i % online` (only meaningful on multicore).
    pub pin_threads: bool,
    /// Seed for victim selection and root distribution.
    pub seed: u64,
    /// Backstop period for parked idle workers. The wake handshake makes
    /// lost wakeups impossible by construction, so this only bounds the
    /// damage of a protocol bug; tests stretch it to prove the handshake
    /// (not the timeout) delivers admissions.
    pub park_timeout: Duration,
    /// Dynamic-heterogeneity episodes realized in wall-clock time by the
    /// [`EpisodeDriver`]: interference episodes spawn background spinner
    /// threads, and shares on affected cores are duty-cycle throttled so
    /// the leader's own PTT observation sees the slowdown (empty = none;
    /// `exec::RealBackend` fills this from the platform scenario).
    pub episodes: EpisodeSchedule,
}

impl Default for RealEngineOpts {
    fn default() -> Self {
        RealEngineOpts {
            pin_threads: false,
            seed: 0x7a0,
            park_timeout: Duration::from_millis(1),
            episodes: EpisodeSchedule::default(),
        }
    }
}

/// Park/unpark state of one worker (cache-padded in `Shared` so flag
/// traffic never false-shares between workers).
#[derive(Default)]
struct Parker {
    /// Registered by the worker before its first loop iteration.
    thread: OnceLock<std::thread::Thread>,
    /// Set (SeqCst) by the worker just before sleeping; producers unpark
    /// only flagged workers. Cleared by the worker itself on wake.
    parked: AtomicBool,
}

struct Shared<'a> {
    /// The shared task-lifecycle core (placement, commit-and-wake-up,
    /// criticality, per-app attribution) — identical code to the sim
    /// engine's. All its state is atomic; workers drive it through
    /// `&self` with no locks.
    core: SchedCore<'a>,
    wsqs: Vec<WsQueue<TaskId>>,
    aqs: Vec<AssemblyQueue<FrameId>>,
    /// Per-run task-frame arena: placement bump-allocates, queues carry
    /// word-sized ids, everything is freed when this struct drops
    /// ([`super::arena`] — no allocator traffic on the execute path).
    frames: FrameArena,
    /// `victim_order[c]` — every core but `c`, own cluster first, then by
    /// cluster distance, rotated within each tier (module docs).
    victim_order: Vec<Vec<usize>>,
    /// `near[c]` — length of the same-cluster prefix of `victim_order[c]`
    /// (the random-probe tier).
    near: Vec<usize>,
    /// Per-core admission inboxes: late roots may not be pushed into a
    /// live worker's deque (owner-only bottom end), so the submitter puts
    /// them here and the owner drains them into its own WSQ.
    inboxes: Vec<Inbox<TaskId>>,
    /// Per-worker park/unpark state.
    parkers: Vec<CachePadded<Parker>>,
    /// Number of workers currently advertising themselves as parked (or
    /// committed to parking). Producers read it after a `SeqCst` fence and
    /// skip the wake scan entirely while it is zero — the busy-path common
    /// case (module docs).
    n_parked: AtomicUsize,
    /// Park backstop period (see [`RealEngineOpts::park_timeout`]).
    park_timeout: Duration,
    /// Wall-clock realization of the platform's episode schedule
    /// ([`super::episodes_rt`]): duty-cycle throttling of shares on
    /// affected cores; inert when the schedule is empty.
    episodes: EpisodeDriver,
    /// Run-termination flag, observed by the worker loops. Set by the
    /// worker whose commit the core reports as the run's last.
    done: AtomicBool,
    /// Per-worker wall-clock heartbeat (f64 bits), stored at the top of
    /// every loop iteration. The watchdog reads it to spot hung workers.
    hearts: Vec<CachePadded<AtomicU64>>,
    /// Per-worker departed flag: set at the thread boundary when a panic
    /// escapes the worker loop. Once set, the worker will never touch its
    /// queues again, so the watchdog may act as their owner.
    departed: Vec<CachePadded<AtomicBool>>,
    t0: Instant,
}

impl<'a> Shared<'a> {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn n_cores(&self) -> usize {
        self.core.topo().n_cores()
    }

    /// Producer half of the sleep/wake handshake: call *after* the work
    /// has been published. The fence pairs with the sleeper's pre-park
    /// fence (module docs); the wake scan runs only when someone is
    /// parked, so the busy-path cost is one fence + one load.
    fn wake_after_publish(&self, wake: impl FnOnce(&Self)) {
        fence(Ordering::SeqCst);
        if self.n_parked.load(Ordering::SeqCst) > 0 {
            wake(self);
        }
    }

    /// Read-only probe of every source that could feed `core`: its inbox,
    /// its AQ (the in-flight counter covers the MPSC link transient), its
    /// own deque, and every victim deque. Used by the sleeper's post-fence
    /// re-scan.
    fn has_visible_work(&self, core: usize) -> bool {
        if !self.inboxes[core].is_empty()
            || !self.aqs[core].is_empty()
            || !self.wsqs[core].is_empty()
        {
            return true;
        }
        (0..self.wsqs.len()).any(|v| v != core && !self.wsqs[v].is_empty())
    }

    /// Unpark worker `c` if it flagged itself parked.
    fn wake_core(&self, c: usize) {
        let p = &*self.parkers[c];
        if p.parked.load(Ordering::SeqCst) {
            if let Some(t) = p.thread.get() {
                t.unpark();
            }
        }
    }

    /// Unpark up to `k` parked workers other than `origin` — stealable
    /// work appeared on `origin`'s deque and any thief will do.
    fn wake_thieves(&self, origin: usize, k: usize) {
        let n = self.n_cores();
        let mut woken = 0usize;
        for off in 1..n {
            if woken >= k {
                break;
            }
            let c = (origin + off) % n;
            let p = &*self.parkers[c];
            if p.parked.load(Ordering::SeqCst) {
                if let Some(t) = p.thread.get() {
                    t.unpark();
                    woken += 1;
                }
            }
        }
    }

    /// Unpark every registered worker (run end).
    fn wake_all(&self) {
        for p in &self.parkers {
            if let Some(t) = p.thread.get() {
                t.unpark();
            }
        }
    }

    /// Insert a placed TAO into all member AQs, then wake any parked
    /// members. No cross-queue ordering lock is needed: members execute
    /// their share immediately on arrival (asynchronous entry, no
    /// barrier), so inconsistent interleavings cannot produce a circular
    /// wait.
    fn insert_into_aqs(&self, placer: usize, frame: FrameId) {
        let partition = self.frames.frame(frame).partition();
        for c in partition.cores() {
            self.aqs[c].push(frame);
        }
        self.wake_after_publish(|s| {
            for c in partition.cores() {
                if c != placer {
                    s.wake_core(c);
                }
            }
        });
    }

    /// Place one ready task from the perspective of `core`: the decision
    /// (PlaceCtx + policy dispatch) is the shared core's; this substrate
    /// only materialises the instance and routes it into the member AQs.
    fn place_task(&self, core: usize, task: TaskId) {
        let placed = self.core.place(core, task, self.now());
        let frame = self.frames.alloc(task, placed.partition, placed.critical);
        self.insert_into_aqs(core, frame);
    }

    /// First live lane at or after `lane` (wrapping); `None` when every
    /// core is currently dead. Used by the submitters to keep admissions
    /// off fail-stopped cores.
    fn live_lane(&self, lane: usize) -> Option<usize> {
        let n = self.n_cores();
        (0..n).map(|k| (lane + k) % n).find(|&c| !self.core.is_core_dead(c))
    }

    /// First live core other than `this`, preferring neighbours (and, for
    /// the watchdog, skipping departed workers — their inboxes have no
    /// owner left to drain them).
    fn live_target(&self, this: usize) -> Option<usize> {
        let n = self.n_cores();
        (1..n).map(|off| (this + off) % n).find(|&c| {
            !self.core.is_core_dead(c) && !self.departed[c].load(Ordering::Acquire)
        })
    }

    /// Owner-side drain of `core`'s inbox, AQ and deque into a live
    /// neighbour's inbox. Only the owning worker may call this (the inbox
    /// `take_all`, AQ `pop` and deque `pop` bottom end are single-consumer);
    /// the watchdog gets the same rights for *departed* workers, whose
    /// owner provably never touches the queues again.
    fn reclaim_own(&self, core: usize) {
        let Some(target) = self.live_target(core) else {
            // Nowhere to put the work: hold it. Either a recovery boundary
            // revives someone (the nap loop re-drains every slice) or the
            // schedule was rejected up front by `check_substrate`.
            return;
        };
        let mut moved = 0usize;
        for task in self.inboxes[core].take_all() {
            self.inboxes[target].push(task);
            moved += 1;
        }
        while let Some(task) = self.wsqs[core].pop() {
            self.inboxes[target].push(task);
            moved += 1;
        }
        // Re-route whole instances: members claim ranks on AQ arrival, so
        // pushing the same frame id into the target's AQ lets the target
        // run this core's share (ranks are claimed per-arrival, not
        // per-core).
        while let Some(frame) = self.aqs[core].pop() {
            self.aqs[target].push(frame);
            moved += 1;
        }
        if moved > 0 {
            self.wake_after_publish(|s| {
                s.wake_core(target);
                s.wake_thieves(target, moved);
            });
        }
    }

    /// Thief-side drain of a *live* worker's deque — steal is the only
    /// operation a non-owner may perform on a Chase–Lev deque, so this is
    /// all the watchdog can safely take from a hung-but-alive worker.
    fn drain_wsq_of(&self, victim: usize) {
        let Some(target) = self.live_target(victim) else { return };
        let mut moved = 0usize;
        // Batched: one `thieves` bracket and one victim visit per half-
        // queue instead of per task — the watchdog contends with the hung
        // worker's own (possibly crawling) pops as little as possible.
        loop {
            let got = self.wsqs[victim].steal_half(|task| {
                self.inboxes[target].push(task);
            });
            if got == 0 {
                break;
            }
            moved += got;
        }
        if moved > 0 {
            self.wake_after_publish(|s| {
                s.wake_core(target);
                s.wake_thieves(target, moved);
            });
        }
    }

    /// Full reclamation of a departed worker's queues. The departed flag
    /// is set only after the worker's loop has unwound, so the watchdog is
    /// now the sole consumer of its inbox/AQ/deque and the owner-side
    /// drain is safe. Re-run on every watchdog tick: placers may still
    /// route shares into a departed core's AQ until its death is noticed.
    fn reclaim_departed(&self, core: usize) {
        if !self.core.is_core_dead(core) {
            self.core.set_core_dead(core, true);
        }
        self.reclaim_own(core);
    }

    /// Serve a fail-stop episode covering `core` at the current time, if
    /// any: publish death through the shared core's dead mask (placement
    /// remaps off dead cores — `SchedCore::place`), drain our queues to a
    /// live neighbour, then nap until the recovery boundary — *outside*
    /// the park handshake, so producers never count us as wakeable.
    /// Returns whether an episode was served (the caller re-enters its
    /// loop to re-read the clock).
    fn fail_stop_nap(&self, core: usize) -> bool {
        if !self.episodes.fail_stopped(core, self.now()) {
            return false;
        }
        self.core.set_core_dead(core, true);
        loop {
            // Every slice: re-drain strays that raced into our queues
            // around the failure edge (a placer that read the dead mask
            // just before we set it may still push to our AQ).
            self.reclaim_own(core);
            if self.done.load(Ordering::Acquire) {
                break;
            }
            if !self.episodes.fail_stopped(core, self.now()) {
                break;
            }
            std::thread::sleep(Duration::from_micros(500));
            // Keep the heartbeat fresh: a fail-stopped worker is dead to
            // the scheduler but the *thread* is healthy — the watchdog
            // must not steal-drain on top of our own drains.
            self.hearts[core].store(self.now().to_bits(), Ordering::Relaxed);
        }
        self.core.set_core_dead(core, false);
        true
    }

    /// Execute this core's share of a TAO instance; commit if last.
    /// `sink` is this worker's private trace shard.
    ///
    /// The payload runs under `catch_unwind`: a panicking TAO is counted
    /// failed and its timing never reaches the PTT, but the share still
    /// completes — failure is a terminal state, dependents must release,
    /// and the worker thread survives to run the next share.
    fn execute_share(&self, core: usize, frame: FrameId, sink: &mut Vec<TraceRecord>) {
        let inst = self.frames.frame(frame);
        let task = inst.task();
        let partition = inst.partition();
        let rank = inst.arrivals.fetch_add(1, Ordering::AcqRel);
        debug_assert!(rank < partition.width);
        let node = &self.core.dag().nodes[task];
        let is_leader = core == partition.leader;
        let t_start = self.now();
        let ok = match &node.payload {
            Some(p) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                p.execute(rank, partition.width)
            }))
            .is_ok(),
            None => true,
        };
        // Realize dynamic heterogeneity: a share on an episode-affected
        // core is stretched *before* t_end is taken, so the leader's own
        // timing — the only PTT write — observes the slowdown exactly as
        // it would observe a genuinely slower core.
        if self.episodes.is_active() {
            self.episodes.throttle_share(core, t_start, || self.now());
        }
        let t_end = self.now();
        if !ok {
            self.core.note_failed(task);
        }
        if is_leader {
            inst.leader_start.store(t_start.to_bits(), Ordering::Relaxed);
            inst.leader_end.store(t_end.to_bits(), Ordering::Release);
            // §3.2: the leader records its own execution time from its own
            // thread (no PTT cache-line migration); the 4:1 moving average
            // absorbs rank-imbalance skew. An aborted share's duration is
            // not a latency observation — keep it out of the table.
            if ok {
                self.core.record_leader_share(task, partition, t_end - t_start);
            }
        }
        if inst.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.commit_and_wake(core, frame, t_end, sink);
        }
    }

    /// Commit-and-wake-up (§3.3), delegated to [`SchedCore::commit`]: the
    /// substrate derives the leader-share timings, routes released
    /// children onto the committer's own deque, and stores the record in
    /// this worker's private shard (a plain lock-free `Vec::push`).
    fn commit_and_wake(
        &self,
        core: usize,
        frame: FrameId,
        t_end: f64,
        sink: &mut Vec<TraceRecord>,
    ) {
        let inst = self.frames.frame(frame);
        let le_bits = inst.leader_end.load(Ordering::Acquire);
        let (ls, le) = if le_bits == LEADER_UNSET {
            (t_end, t_end) // leader still mid-share; attribute to committer
        } else {
            (f64::from_bits(inst.leader_start.load(Ordering::Relaxed)), f64::from_bits(le_bits))
        };
        let info = CommitInfo {
            task: inst.task(),
            partition: inst.partition(),
            critical: inst.critical(),
            t_start: ls,
            t_end: le.max(t_end),
            exec: le - ls,
            now: t_end,
        };
        let mut woken = 0usize;
        // The commit latch absorbs duplicates (a task reclaimed by the
        // watchdog *and* finished by its original instance): the second
        // commit is a counted no-op whose callback never runs.
        let Some(out) = self.core.commit(&info, |child| {
            self.wsqs[core].push(child);
            woken += 1;
        }) else {
            return;
        };
        sink.push(out.record);
        if woken > 0 {
            // New stealable work on our deque: offer it to as many parked
            // thieves as there are new tasks.
            self.wake_after_publish(|s| s.wake_thieves(core, woken));
        }
        if out.done {
            self.done.store(true, Ordering::Release);
            // Unconditional: every worker must observe the end of the run.
            self.wake_all();
        }
    }
}

/// Spin-backoff bounds: probe attempts before escalating to `yield_now`,
/// then to the full-sweep-and-park regime.
const SPIN_LIMIT: u32 = 16;
const YIELD_LIMIT: u32 = 32;

/// Random-steal attempts per loop iteration before conceding the probe
/// tier. One probe (the old behaviour) made a single CAS race
/// indistinguishable from "the tier is empty" and escalated straight to
/// the O(n) sweep; three keeps the probe cheap while making a false
/// empty-verdict need three independent misses.
const STEAL_PROBES: u32 = 3;

/// Per-core victim orders for topology-aware stealing. Returns
/// `(victim_order, near)`: `victim_order[c]` lists every core but `c`
/// sorted by `(cluster distance, rotation)` — the same-cluster tier
/// first, each tier rotated by the prober's index so `n` simultaneous
/// sweeps don't all hammer the lowest-numbered victim — and `near[c]` is
/// the length of the same-cluster prefix (the random-probe tier).
fn build_victim_orders(topo: &Topology) -> (Vec<Vec<usize>>, Vec<usize>) {
    let n = topo.n_cores();
    let mut orders = Vec::with_capacity(n);
    let mut nears = Vec::with_capacity(n);
    for c in 0..n {
        let own = topo.cores[c].cluster;
        let mut order: Vec<usize> = (0..n).filter(|&v| v != c).collect();
        order.sort_by_key(|&v| (topo.cores[v].cluster.abs_diff(own), (v + n - c) % n));
        let near = order.iter().take_while(|&&v| topo.cores[v].cluster == own).count();
        orders.push(order);
        nears.push(near);
    }
    (orders, nears)
}

/// Cap on the parked-worker sleep backoff. A serving run can hold workers
/// idle for long stretches (admission gaps, drained lanes); re-waking
/// every `park_timeout` (1 ms) just to find nothing is a busy-wakeup in
/// slow motion — thousands of pointless sweeps a second across the pool.
/// Consecutive fruitless park timeouts therefore double the sleep from
/// `park_timeout` up to this cap; finding *any* work resets it. The wake
/// handshake is untouched — producers unpark sleepers explicitly, so a
/// long sleep only bounds how late a worker notices a protocol bug, not
/// how late it notices work.
const PARK_BACKOFF_CAP: Duration = Duration::from_millis(100);

/// Watchdog sweep period.
const WATCHDOG_PERIOD: Duration = Duration::from_millis(2);

/// A worker whose heartbeat is older than this is treated as hung and has
/// its deque steal-drained. Parked workers refresh their heartbeat at
/// least every `PARK_BACKOFF_CAP` (100 ms), so this must sit well above
/// the cap to avoid draining a healthy sleeper — stale tasks would still
/// complete (the inbox re-route is harmless), but the drain churn isn't
/// free.
const HUNG_AFTER: f64 = 0.25;

/// Supervisor loop: reclaim the queues of departed workers (owner-side
/// drain — the owner is gone) and steal-drain the deques of workers whose
/// heartbeat went stale (thief-side — the owner may still be alive).
/// Module docs, "Fault tolerance".
fn watchdog_loop(shared: &Shared<'_>) {
    while !shared.done.load(Ordering::Acquire) {
        std::thread::sleep(WATCHDOG_PERIOD);
        let now = shared.now();
        for c in 0..shared.n_cores() {
            if shared.departed[c].load(Ordering::Acquire) {
                shared.reclaim_departed(c);
            } else if !shared.core.is_core_dead(c) {
                let beat = f64::from_bits(shared.hearts[c].load(Ordering::Relaxed));
                if now - beat > HUNG_AFTER {
                    shared.drain_wsq_of(c);
                }
            }
        }
    }
}

fn worker_loop(shared: &Shared<'_>, core: usize, mut rng: Pcg32, sink: &mut Vec<TraceRecord>) {
    let _ = shared.parkers[core].thread.set(std::thread::current());
    let n = shared.n_cores();
    let mut idle = 0u32;
    let fail_stops = shared.episodes.any_fail_stop();
    // Tests stretch `park_timeout` past the cap to prove the handshake
    // (not the timeout) delivers wakeups; the backoff must not shrink it.
    let park_cap = shared.park_timeout.max(PARK_BACKOFF_CAP);
    let mut park_backoff = shared.park_timeout;
    while !shared.done.load(Ordering::Acquire) {
        shared.hearts[core].store(shared.now().to_bits(), Ordering::Relaxed);
        if fail_stops && shared.fail_stop_nap(core) {
            idle = 0;
            continue;
        }
        if idle == 0 {
            park_backoff = shared.park_timeout;
        }
        // 0. Admission inbox: late roots handed over by the submitter are
        // drained into our own deque (owner-only push).
        let admitted = shared.inboxes[core].take_all();
        if !admitted.is_empty() {
            let k = admitted.len();
            for task in admitted {
                shared.wsqs[core].push(task);
            }
            // The roots are stealable from our deque now; let parked
            // neighbours help.
            shared.wake_after_publish(|s| s.wake_thieves(core, k));
            idle = 0;
            continue;
        }
        // 1. Assembly queue: committed work for this core.
        if let Some(frame) = shared.aqs[core].pop() {
            shared.execute_share(core, frame, sink);
            idle = 0;
            continue;
        }
        // 2. Own WSQ: ready tasks needing a placement decision.
        if let Some(task) = shared.wsqs[core].pop() {
            shared.place_task(core, task);
            idle = 0;
            continue;
        }
        // 3. Random steal probes in the near (same-cluster) tier: cheap,
        // keeps victim choice fair, and keeps the stolen task's working
        // set inside the cluster's shared cache. Bounded retries — one
        // missed probe used to fall straight through to the O(n) sweep
        // even when the victim had merely raced; a couple of re-picks are
        // far cheaper than scanning every deque. Cores whose cluster has
        // no other member probe the global order instead.
        if n > 1 {
            let near = shared.near[core];
            let tier: &[usize] = if near > 0 {
                &shared.victim_order[core][..near]
            } else {
                &shared.victim_order[core]
            };
            let mut stolen = None;
            for _ in 0..STEAL_PROBES {
                let victim = tier[rng.gen_usize(0, tier.len())];
                if let Some(task) = shared.wsqs[victim].steal() {
                    stolen = Some(task);
                    break;
                }
            }
            if let Some(task) = stolen {
                shared.place_task(core, task);
                idle = 0;
                continue;
            }
        }
        // 4. Exponential backoff: spin, then yield (crucial on hosts with
        // fewer physical cores than workers), then sweep-and-park.
        idle += 1;
        if idle < SPIN_LIMIT {
            std::hint::spin_loop();
            continue;
        }
        if idle < YIELD_LIMIT {
            std::thread::yield_now();
            continue;
        }
        // 5. Full steal sweep: the near-tier probes above may simply have
        // missed the one victim holding work — never park on a sampling
        // miss. The sweep walks the topology order (own cluster first, so
        // a cross-socket steal happens only when the whole near tier is
        // provably empty) and takes *half* the first non-empty victim's
        // queue in one batched visit: a worker that reached the sweep is
        // starving, so grabbing one task just to sweep again per task
        // would pay the O(n) scan and the `top` CAS line transfer per
        // task. The first stolen task is placed right away; the rest land
        // on our own deque (owner push) where near-tier thieves can
        // re-share them.
        if n > 1 {
            let mut first = None;
            let mut kept = 0usize;
            for &v in &shared.victim_order[core] {
                let got = shared.wsqs[v].steal_half(|task| {
                    if first.is_none() {
                        first = Some(task);
                    } else {
                        shared.wsqs[core].push(task);
                        kept += 1;
                    }
                });
                if got > 0 {
                    break;
                }
            }
            if let Some(task) = first {
                if kept > 0 {
                    // The surplus is stealable from our deque now.
                    shared.wake_after_publish(|s| s.wake_thieves(core, kept));
                }
                shared.place_task(core, task);
                idle = 0;
                continue;
            }
        }
        // 6. Park. Sleeper half of the handshake: advertise (flag +
        // counter), fence, then re-scan every work source; sleep only if
        // all are still empty. Producers fence after publishing and scan
        // the flags when the counter is non-zero, so either they see us
        // (their unpark token makes a pre-park `unpark` stick) or the
        // re-scan below sees their work (module docs).
        let parker = &*shared.parkers[core];
        parker.parked.store(true, Ordering::SeqCst);
        shared.n_parked.fetch_add(1, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        if shared.done.load(Ordering::Acquire) || shared.has_visible_work(core) {
            shared.n_parked.fetch_sub(1, Ordering::SeqCst);
            parker.parked.store(false, Ordering::SeqCst);
            idle = 0;
            continue;
        }
        // About to go idle: reclaim any retired deque buffers while no
        // thief brackets our queue (owner-only; cheap no-op when empty).
        shared.wsqs[core].maintain();
        std::thread::park_timeout(park_backoff);
        shared.n_parked.fetch_sub(1, Ordering::SeqCst);
        parker.parked.store(false, Ordering::SeqCst);
        // A fruitless timeout doubles the next sleep (capped); finding
        // work on the re-scan below resets `idle`, and with it the
        // backoff, at the top of the loop.
        park_backoff = (park_backoff * 2).min(park_cap);
        // Re-scan everything once, then fall straight back to the
        // sweep-and-park regime while idleness persists.
        idle = YIELD_LIMIT - 1;
    }
}

/// Pin the calling thread to `cpu` (best effort).
///
/// Actual pinning needs `sched_setaffinity` via the `libc` crate, which the
/// offline build intentionally avoids; this hook is kept (and plumbed
/// through `RunOpts::pin_threads`) so multicore deployments have one place
/// to wire OS affinity back in.
fn pin_to_cpu(_cpu: usize) {}

/// Whether [`pin_to_cpu`] actually pins on this build. The episode driver
/// keys its interference-throttle rule off this: with real pinning, a
/// pinned background spinner takes its CPU share by itself and the
/// duty-cycle stretch must not be applied on top (it would square the
/// slowdown — see `episodes_rt`). Flip together with `pin_to_cpu`.
fn pinning_available() -> bool {
    false
}

/// Reject episode schedules this engine cannot survive: one that
/// fail-stops *every* core with no recovery leaves no live worker to
/// finish the run, and unlike the sim engine (which detects the wedge at
/// its event horizon) a wall-clock engine would simply hang. Checked up
/// front so the failure is an error, not a deadlock.
fn check_substrate(topo: &Topology, episodes: &EpisodeSchedule) -> Result<(), SchedError> {
    let forever_dead = |c: usize| {
        episodes.episodes.iter().any(|e| {
            matches!(e.kind, EpisodeKind::FailStop { .. })
                && e.cores.contains(&c)
                && e.t_end.is_infinite()
        })
    };
    if (0..topo.n_cores()).all(forever_dead) {
        let t = episodes
            .episodes
            .iter()
            .filter(|e| matches!(e.kind, EpisodeKind::FailStop { .. }))
            .map(|e| e.t_start)
            .fold(0.0, f64::max);
        return Err(SchedError::AllCoresDead { t });
    }
    Ok(())
}

/// Execute `dag` with `policy` on `topo.n_cores()` worker threads.
///
/// The PTT is created fresh unless `ptt` is provided (warm-started PTTs let
/// callers chain DAGs, as the paper's VGG port does between layers).
///
/// This is the degenerate workload stream: one application whose roots are
/// admitted before the workers start (see [`run_stream_real`]).
pub fn run_dag_real(
    dag: &TaoDag,
    topo: &Topology,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &RealEngineOpts,
) -> Result<RunResult, SchedError> {
    run_stream_real(dag, &[], &[(0.0, dag.roots())], topo, policy, ptt, opts)
}

/// Execute a multi-application workload stream on real worker threads.
///
/// `dag` is the combined DAG over all applications (independent
/// components); `app_of[task]` maps tasks to applications (empty = all
/// app 0); `admissions` lists `(arrival_seconds, roots)` sorted by arrival.
/// Apps arriving at `t ≤ 0` are admitted before the workers start (so the
/// single-app path is byte-identical to the historical bootstrap); later
/// apps are injected by a submitter thread that sleeps until each wall-
/// clock arrival and hands the roots to the owning workers through the
/// per-core admission inboxes (waking any parked owner). Workers cannot
/// distinguish admitted roots from bootstrap roots, and the run ends only
/// when every task of every app has committed.
pub fn run_stream_real(
    dag: &TaoDag,
    app_of: &[usize],
    admissions: &[(f64, Vec<TaskId>)],
    topo: &Topology,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &RealEngineOpts,
) -> Result<RunResult, SchedError> {
    check_substrate(topo, &opts.episodes)?;
    let source = AdmissionSource::new(dag, app_of, admissions);
    let fresh;
    let ptt = match ptt {
        Some(p) => p,
        None => {
            fresh = Ptt::new(dag.n_types(), topo);
            &fresh
        }
    };
    let (victim_order, near) = build_victim_orders(topo);
    let shared = Shared {
        core: SchedCore::new(dag, app_of, topo, policy, ptt),
        wsqs: (0..topo.n_cores()).map(|_| WsQueue::new()).collect(),
        aqs: (0..topo.n_cores()).map(|_| AssemblyQueue::new()).collect(),
        frames: FrameArena::with_capacity(dag.nodes.len()),
        victim_order,
        near,
        inboxes: (0..topo.n_cores()).map(|_| Inbox::new()).collect(),
        parkers: (0..topo.n_cores()).map(|_| CachePadded::new(Parker::default())).collect(),
        n_parked: AtomicUsize::new(0),
        park_timeout: opts.park_timeout,
        // Interference episodes are throttled only while spinners cannot
        // be genuinely pinned — with real affinity the pinned spinner IS
        // the share realization and throttling too would double-count.
        episodes: EpisodeDriver::with_interference_throttle(
            opts.episodes.clone(),
            !(pinning_available() && opts.pin_threads),
        ),
        done: AtomicBool::new(false),
        hearts: (0..topo.n_cores())
            .map(|_| CachePadded::new(AtomicU64::new(0f64.to_bits())))
            .collect(),
        departed: (0..topo.n_cores()).map(|_| CachePadded::new(AtomicBool::new(false))).collect(),
        t0: Instant::now(),
    };
    // One private, cache-padded trace shard per worker: commits are plain
    // `Vec::push`es through a disjoint `&mut` — no locks, no sharing.
    let mut trace_shards: Vec<CachePadded<Vec<TraceRecord>>> =
        (0..topo.n_cores()).map(|_| CachePadded::new(Vec::new())).collect();
    // Admit everything due at the start (arrival ≤ 0) before the workers
    // spawn, through the same shared source the sim engine consumes —
    // round-robin root distribution (§3.3's "default policy"); initial
    // tasks are non-critical by definition.
    let n_cores = topo.n_cores();
    source.admit_due(0.0, n_cores, |lane, root| shared.wsqs[lane].push(root));

    let mut root_rng = Pcg32::seeded(opts.seed);
    let online = crate::platform::detect::online_cpus();
    std::thread::scope(|s| {
        // Background interferers first (they nap until their window): one
        // spinner per (interference episode × affected core), best-effort
        // pinned like the workers, stopped early by the run's `done` flag.
        if shared.episodes.is_active() {
            let pin_threads = opts.pin_threads;
            shared.episodes.spawn_spinners(s, shared.t0, &shared.done, move |c| {
                if pin_threads {
                    pin_to_cpu(c % online);
                }
            });
        }
        for (core, shard) in trace_shards.iter_mut().enumerate() {
            let rng = root_rng.split(core as u64);
            let shared = &shared;
            let pin = opts.pin_threads;
            s.spawn(move || {
                if pin {
                    pin_to_cpu(core % online);
                }
                // Thread boundary of panic isolation: a panic that escapes
                // the worker loop (engine-internal, not a sandboxed
                // payload) must not tear down the run through the scope's
                // join. Mark the worker departed; the watchdog becomes the
                // owner of its queues.
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    worker_loop(shared, core, rng, shard);
                }));
                if caught.is_err() {
                    shared.departed[core].store(true, Ordering::Release);
                    fence(Ordering::SeqCst);
                }
            });
        }
        {
            let shared = &shared;
            s.spawn(move || watchdog_loop(shared));
        }
        if !source.is_exhausted() {
            let (shared, source) = (&shared, &source);
            s.spawn(move || {
                // The submitter: sleep until each arrival, then hand the
                // app's roots to the live workers through their admission
                // inboxes (the deque bottom end is owner-only). Short
                // bounded naps keep the arrival error in the low
                // milliseconds without burning a core.
                while let Some(arrival) = source.next_arrival() {
                    loop {
                        let behind = arrival - shared.now();
                        if behind <= 0.0 {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            behind.min(0.002),
                        ));
                    }
                    let pushed = source.admit_due(shared.now(), n_cores, |lane, root| {
                        // Admissions avoid fail-stopped lanes: a dead
                        // worker's own drain would bounce the root anyway,
                        // but routing straight to a live lane is cheaper
                        // and keeps arrival latency flat through a fault.
                        let lane = shared.live_lane(lane).unwrap_or(lane);
                        shared.inboxes[lane].push(root);
                    });
                    // Producer half of the park handshake: wake every
                    // core that received a root (each due batch fills
                    // lanes from 0, so the prefix covers them all —
                    // unless the dead-lane redirect scattered them, in
                    // which case wake everyone; a spurious unpark is one
                    // cheap re-scan).
                    shared.wake_after_publish(|sh| {
                        let k = if sh.episodes.any_fail_stop() { n_cores } else { pushed };
                        for c in 0..n_cores.min(k) {
                            sh.wake_core(c);
                        }
                    });
                }
            });
        }
    });

    assert!(shared.core.is_done(), "worker pool exited with incomplete tasks");
    let makespan = shared.now();
    // Merge the per-worker shards and impose the deterministic
    // `(t_end, task)` total order — the shard layout (which worker
    // committed what) must never show through in the result.
    let mut records: Vec<TraceRecord> =
        trace_shards.into_iter().flat_map(CachePadded::into_inner).collect();
    sort_by_commit(&mut records);
    Ok(RunResult {
        policy: policy.name().to_string(),
        platform: topo.name.clone(),
        makespan,
        records,
        bound: None,
    })
}

/// Serving-mode admission state owned by the submitter thread. Boxed in a
/// `Mutex` only so the scoped thread can mutate it and the caller can take
/// it back after the join — the lock is held once, uncontended.
struct ServingState {
    source: ServingSource,
    /// `shed[app]` — refused by backpressure (excluded from fairness).
    shed: Vec<bool>,
    shed_apps: Vec<usize>,
    fairness: Vec<(f64, f64)>,
    last_feedback: f64,
}

/// One tick of the serving fairness feedback loop: at most once per
/// `period`, sample the Jain index over the *offered*, non-shed apps'
/// completion fractions and report it — with the per-core monopolist
/// view — to the policy's `on_fairness` hook (only `ptt-serving` reacts).
/// `app_meta[app] = (arrival, n_tasks)`.
fn fairness_tick(
    shared: &Shared<'_>,
    policy: &dyn Policy,
    app_meta: &[(f64, usize)],
    shed: &[bool],
    opts: &ServingOpts,
    last: &mut f64,
    out: &mut Vec<(f64, f64)>,
) {
    let now = shared.now();
    if now - *last < opts.fairness_period {
        return;
    }
    *last = now;
    let xs: Vec<f64> = app_meta
        .iter()
        .enumerate()
        .filter(|&(a, &(arrival, _))| arrival <= now && !shed[a])
        .map(|(a, &(_, n))| shared.core.app_done(a) as f64 / n as f64)
        .collect();
    if xs.len() < 2 {
        return; // fairness over one tenant is vacuous
    }
    let jain = jain_fairness_total(&xs);
    policy.on_fairness(jain, &shared.core.monopolists(opts.min_streak));
    out.push((now, jain));
}

/// Execute a serving-mode workload on real worker threads: the open-loop
/// admission schedule in `apps` is offered at wall-clock arrival times
/// through [`ServingSource`] — per-core inbox depth is the backpressure
/// reading, pressured offers are delayed (batch) or shed (best-effort,
/// tasks cancelled in the core so the run still terminates), and the
/// fairness feedback loop runs from the submitter thread. At
/// `serving.drain_after` the source switches to drain mode and the
/// backlog quiesces; the run ends when every admitted task committed and
/// every shed task was cancelled.
///
/// `app_qos[app]` must cover every app in `app_of` (it feeds placement
/// contexts); `apps` carries the offer schedule, QoS and root sets.
#[allow(clippy::too_many_arguments)]
pub fn run_serving_real(
    dag: &TaoDag,
    app_of: &[usize],
    apps: Vec<ServingApp>,
    app_qos: Vec<QosClass>,
    topo: &Topology,
    policy: &dyn Policy,
    ptt: Option<&Ptt>,
    opts: &RealEngineOpts,
    serving: &ServingOpts,
) -> Result<ServingRun, SchedError> {
    check_substrate(topo, &opts.episodes)?;
    // (arrival, n_tasks) per app id, for the fairness sampler. Apps not in
    // the serving schedule keep arrival = ∞ and are never sampled.
    let n_apps = apps.iter().map(|a| a.app_id + 1).max().unwrap_or(1);
    let mut app_meta = vec![(f64::INFINITY, 1usize); n_apps];
    for a in &apps {
        app_meta[a.app_id] = (a.arrival, a.n_tasks.max(1));
    }
    let state = Mutex::new(ServingState {
        source: ServingSource::new(apps, serving.max_lane_depth, serving.delay_step),
        shed: vec![false; n_apps],
        shed_apps: Vec::new(),
        fairness: Vec::new(),
        last_feedback: 0.0,
    });
    let fresh;
    let ptt = match ptt {
        Some(p) => p,
        None => {
            fresh = Ptt::new(dag.n_types(), topo);
            &fresh
        }
    };
    let (victim_order, near) = build_victim_orders(topo);
    let shared = Shared {
        core: SchedCore::new(dag, app_of, topo, policy, ptt).with_app_qos(app_qos),
        wsqs: (0..topo.n_cores()).map(|_| WsQueue::new()).collect(),
        aqs: (0..topo.n_cores()).map(|_| AssemblyQueue::new()).collect(),
        frames: FrameArena::with_capacity(dag.nodes.len()),
        victim_order,
        near,
        inboxes: (0..topo.n_cores()).map(|_| Inbox::new()).collect(),
        parkers: (0..topo.n_cores()).map(|_| CachePadded::new(Parker::default())).collect(),
        n_parked: AtomicUsize::new(0),
        park_timeout: opts.park_timeout,
        episodes: EpisodeDriver::with_interference_throttle(
            opts.episodes.clone(),
            !(pinning_available() && opts.pin_threads),
        ),
        done: AtomicBool::new(false),
        hearts: (0..topo.n_cores())
            .map(|_| CachePadded::new(AtomicU64::new(0f64.to_bits())))
            .collect(),
        departed: (0..topo.n_cores()).map(|_| CachePadded::new(AtomicBool::new(false))).collect(),
        t0: Instant::now(),
    };
    let mut trace_shards: Vec<CachePadded<Vec<TraceRecord>>> =
        (0..topo.n_cores()).map(|_| CachePadded::new(Vec::new())).collect();
    let n_cores = topo.n_cores();
    // Bootstrap: apps due at t ≤ 0 go straight into the deques. No worker
    // is running yet, so every lane is empty and no offer can be pressured.
    // A poisoned mutex here means a *previous* holder panicked mid-update;
    // the admission source's state is a monotonic cursor (never left
    // half-written), so recovering the inner value is sound — and aborting
    // the whole serving run over a submitter panic is exactly the fragility
    // this engine is built to avoid.
    state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).source.admit_due(
        0.0,
        n_cores,
        |_lane| 0,
        |lane, root| shared.wsqs[lane].push(root),
        |_app| unreachable!("empty lanes cannot pressure a bootstrap offer"),
    );

    let mut root_rng = Pcg32::seeded(opts.seed);
    let online = crate::platform::detect::online_cpus();
    std::thread::scope(|s| {
        if shared.episodes.is_active() {
            let pin_threads = opts.pin_threads;
            shared.episodes.spawn_spinners(s, shared.t0, &shared.done, move |c| {
                if pin_threads {
                    pin_to_cpu(c % online);
                }
            });
        }
        for (core, shard) in trace_shards.iter_mut().enumerate() {
            let rng = root_rng.split(core as u64);
            let shared = &shared;
            let pin = opts.pin_threads;
            s.spawn(move || {
                if pin {
                    pin_to_cpu(core % online);
                }
                // Thread boundary of panic isolation: a panic that escapes
                // the worker loop (engine-internal, not a sandboxed
                // payload) must not tear down the run through the scope's
                // join. Mark the worker departed; the watchdog becomes the
                // owner of its queues.
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    worker_loop(shared, core, rng, shard);
                }));
                if caught.is_err() {
                    shared.departed[core].store(true, Ordering::Release);
                    fence(Ordering::SeqCst);
                }
            });
        }
        {
            let shared = &shared;
            s.spawn(move || watchdog_loop(shared));
        }
        let (shared, state) = (&shared, &state);
        s.spawn(move || {
            // The serving submitter: the single admitter. Like the stream
            // submitter it naps in short bounded slices towards the next
            // offer, but it also drives the fairness feedback from the
            // same naps and flips the source into drain mode at the
            // quiesce deadline.
            let st = &mut *state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let ServingState { source, shed, shed_apps, fairness, last_feedback } = st;
            let mut draining = false;
            while let Some(offer) = source.next_offer() {
                loop {
                    let now = shared.now();
                    if !draining && now >= serving.drain_after {
                        source.begin_drain();
                        draining = true;
                    }
                    if offer <= now {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs_f64((offer - now).min(0.002)));
                    fairness_tick(
                        shared,
                        policy,
                        &app_meta,
                        shed,
                        serving,
                        last_feedback,
                        fairness,
                    );
                }
                let pushed = source.admit_due(
                    shared.now(),
                    n_cores,
                    // Graceful degradation under core loss: a dead lane
                    // reads as its live stand-in's depth, so fewer live
                    // cores ⇒ deeper readings ⇒ QoS backpressure sheds
                    // best-effort apps first instead of wedging.
                    |lane| {
                        let lane = shared.live_lane(lane).unwrap_or(lane);
                        shared.inboxes[lane].depth()
                    },
                    |lane, root| {
                        let lane = shared.live_lane(lane).unwrap_or(lane);
                        shared.inboxes[lane].push(root)
                    },
                    |app| {
                        shed[app.app_id] = true;
                        shed_apps.push(app.app_id);
                        // Shed roots were never pushed: the whole subgraph
                        // is unreachable, so account it as done wholesale.
                        if shared.core.cancel_tasks(app.n_tasks) {
                            shared.done.store(true, Ordering::Release);
                            shared.wake_all();
                        }
                    },
                );
                if pushed > 0 {
                    shared.wake_after_publish(|sh| {
                        let k = if sh.episodes.any_fail_stop() { n_cores } else { pushed };
                        for c in 0..n_cores.min(k) {
                            sh.wake_core(c);
                        }
                    });
                }
                fairness_tick(shared, policy, &app_meta, shed, serving, last_feedback, fairness);
            }
        });
    });

    assert!(shared.core.is_done(), "worker pool exited with incomplete tasks");
    let makespan = shared.now();
    let mut records: Vec<TraceRecord> =
        trace_shards.into_iter().flat_map(CachePadded::into_inner).collect();
    sort_by_commit(&mut records);
    let lane_high_water = shared.inboxes.iter().map(Inbox::high_water).max().unwrap_or(0);
    let wsq_retired = shared.wsqs.iter().map(WsQueue::retired_len).max().unwrap_or(0);
    let st = state.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    Ok(ServingRun {
        result: RunResult {
            policy: policy.name().to_string(),
            platform: topo.name.clone(),
            makespan,
            records,
            bound: None,
        },
        counters: st.source.counters(),
        shed_apps: st.shed_apps,
        lane_high_water,
        wsq_retired,
        fairness: st.fairness,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};
    use crate::coordinator::scheduler::{HomogeneousWs, PerformanceBased};
    use crate::coordinator::tao::payload_fn;
    use crate::dag_gen::fixtures::{counting_dag, paper_figure1_dag};
    use crate::platform::KernelClass;

    #[test]
    fn victim_orders_put_own_cluster_first_and_rotate() {
        // big.LITTLE-ish: clusters {0,1} and {2,3,4,5}.
        let topo = Topology::from_clusters("bl", &[(2, "big", 2 << 20), (4, "little", 2 << 20)]);
        let (orders, near) = build_victim_orders(&topo);
        // Core 0's near tier is its lone cluster-mate; the far tier holds
        // the whole little cluster.
        assert_eq!(near[0], 1);
        assert_eq!(orders[0], vec![1, 2, 3, 4, 5]);
        // Core 3: near tier {2,4,5} rotated from 3's vantage → 4,5,2.
        assert_eq!(near[3], 3);
        assert_eq!(&orders[3][..3], &[4, 5, 2]);
        // Homogeneous: everyone is near, rotation starts at the neighbour
        // (no shared first victim across cores).
        let hom = Topology::homogeneous(4);
        let (o, nr) = build_victim_orders(&hom);
        for c in 0..4 {
            assert_eq!(nr[c], 3);
            assert_eq!(o[c][0], (c + 1) % 4);
            assert_eq!(o[c].len(), 3);
        }
    }

    #[test]
    fn executes_every_task_exactly_width_times() {
        let topo = Topology::homogeneous(4);
        let (dag, hits) = counting_dag(40, false);
        let res = run_dag_real(&dag, &topo, &HomogeneousWs, None, &Default::default()).unwrap();
        assert_eq!(res.n_tasks(), 40);
        // HomogeneousWs is width-1: exactly one execute() per task.
        assert_eq!(hits.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn chain_respects_order() {
        let topo = Topology::homogeneous(2);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut d = TaoDag::new();
        let ids: Vec<_> = (0..10)
            .map(|i| {
                let o = order.clone();
                d.add_task_payload(
                    KernelClass::MatMul,
                    0,
                    1.0,
                    // Record once per TAO (rank 0), not once per member —
                    // the scheduler may legally choose width > 1.
                    Some(payload_fn(KernelClass::MatMul, move |r, _w| {
                        if r == 0 {
                            o.lock().unwrap().push(i);
                        }
                    })),
                )
            })
            .collect();
        for w in ids.windows(2) {
            d.add_edge(w[0], w[1]);
        }
        d.finalize().unwrap();
        run_dag_real(&d, &topo, &PerformanceBased, None, &Default::default()).unwrap();
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn figure1_runs_with_performance_policy() {
        let topo =
            Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)]);
        let (dag, _) = paper_figure1_dag();
        let res =
            run_dag_real(&dag, &topo, &PerformanceBased, None, &Default::default()).unwrap();
        assert_eq!(res.n_tasks(), 7);
        // Initial tasks are non-critical; at least one woken task on the
        // critical path must be tagged critical.
        assert!(res.records.iter().any(|r| r.critical));
        // Every partition recorded must be valid.
        for r in &res.records {
            assert!(topo.is_valid_partition(r.partition));
        }
    }

    #[test]
    fn wide_tao_executes_all_ranks() {
        let topo = Topology::homogeneous(4);
        let ranks_seen = Arc::new(Mutex::new(Vec::new()));
        let mut d = TaoDag::new();
        // Force width 4 by pre-training the PTT: leader 0 width 4 is best.
        let rs = ranks_seen.clone();
        d.add_task_payload(
            KernelClass::MatMul,
            0,
            1.0,
            Some(payload_fn(KernelClass::MatMul, move |r, w| {
                rs.lock().unwrap().push((r, w));
            })),
        );
        d.finalize().unwrap();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        for _ in 0..50 {
            ptt.update(0, 0, 4, 0.01); // width 4 wins even ×4
        }
        // Mark critical? Roots are non-critical; local search from any core
        // in the single cluster can still pick width 4.
        let res =
            run_dag_real(&d, &topo, &PerformanceBased, Some(&ptt), &Default::default()).unwrap();
        assert_eq!(res.records[0].partition.width, 4);
        let mut seen = ranks_seen.lock().unwrap().clone();
        seen.sort();
        assert_eq!(seen, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn ptt_gets_trained_by_execution() {
        let topo = Topology::homogeneous(2);
        let (dag, _) = counting_dag(30, false);
        let ptt = Ptt::new(1, &topo);
        run_dag_real(&dag, &topo, &PerformanceBased, Some(&ptt), &Default::default()).unwrap();
        // After 30 width-free placements at least one entry is trained.
        assert!(ptt.untrained_fraction(&topo) < 1.0);
    }

    #[test]
    fn single_core_topology_works() {
        let topo = Topology::homogeneous(1);
        let (dag, hits) = counting_dag(10, true);
        let res = run_dag_real(&dag, &topo, &HomogeneousWs, None, &Default::default()).unwrap();
        assert_eq!(res.n_tasks(), 10);
        assert_eq!(hits.load(Ordering::SeqCst), 10);
        assert!(res.makespan > 0.0);
    }

    #[test]
    fn dvfs_episode_throttles_affected_core_in_wall_clock() {
        // Core 0 runs at 20% speed for the whole run; payloads *sleep* (a
        // wall-clock cost immune to host CPU contention), so the throttle
        // stretch is the only per-core asymmetry. Shares led by core 0
        // must take several times longer than shares led by core 1.
        let topo = Topology::homogeneous(2);
        let mut d = TaoDag::new();
        for _ in 0..16 {
            d.add_task_payload(
                KernelClass::MatMul,
                0,
                1.0,
                Some(payload_fn(KernelClass::MatMul, |_r, _w| {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                })),
            );
        }
        d.finalize().unwrap();
        let opts = RealEngineOpts {
            episodes: EpisodeSchedule::new(vec![crate::platform::Episode::dvfs(
                vec![0],
                0.0,
                1e9,
                0.2,
            )]),
            ..Default::default()
        };
        let res = run_dag_real(&d, &topo, &HomogeneousWs, None, &opts).unwrap();
        assert_eq!(res.n_tasks(), 16);
        let mean_on = |leader: usize| -> f64 {
            let v: Vec<f64> = res
                .records
                .iter()
                .filter(|r| r.partition.leader == leader)
                .map(|r| r.exec_time())
                .collect();
            assert!(!v.is_empty(), "no shares led by core {leader}");
            v.iter().sum::<f64>() / v.len() as f64
        };
        let (m0, m1) = (mean_on(0), mean_on(1));
        // 2 ms stretched by 5x vs 2 ms plain: expect ~5x, assert > 2x to
        // stay robust on noisy shared runners.
        assert!(m0 > 2.0 * m1, "throttled core not slower: {m0} vs {m1}");
    }

    #[test]
    fn run_ending_before_interference_window_does_not_hang_on_spinners() {
        // An interference episode far in the future spawns spinners that
        // nap until their window; the run drains in milliseconds and the
        // `done` flag must release them — the scoped join cannot wait for
        // the window to open.
        let topo = Topology::homogeneous(2);
        let (dag, _) = counting_dag(8, false);
        let opts = RealEngineOpts {
            episodes: EpisodeSchedule::new(vec![crate::platform::Episode::interference(
                vec![0, 1],
                30.0,
                60.0,
                0.5,
                0.0,
            )]),
            ..Default::default()
        };
        let t = Instant::now();
        let res = run_dag_real(&dag, &topo, &HomogeneousWs, None, &opts).unwrap();
        assert_eq!(res.n_tasks(), 8);
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "spinners outlived the run: {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn panicking_payload_does_not_wedge_the_run() {
        let topo = Topology::homogeneous(2);
        let mut d = TaoDag::new();
        let a = d.add_task_payload(
            KernelClass::MatMul,
            0,
            1.0,
            Some(payload_fn(KernelClass::MatMul, |_r, _w| panic!("injected TAO fault"))),
        );
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let b = d.add_task_payload(
            KernelClass::MatMul,
            0,
            1.0,
            Some(payload_fn(KernelClass::MatMul, move |_r, _w| {
                h.fetch_add(1, Ordering::SeqCst);
            })),
        );
        d.add_edge(a, b);
        d.finalize().unwrap();
        let res = run_dag_real(&d, &topo, &HomogeneousWs, None, &Default::default()).unwrap();
        // Failure is terminal, not a wedge: the panicking task commits,
        // releasing its dependent, which then runs normally.
        assert_eq!(res.n_tasks(), 2);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn fail_stop_episode_loses_no_tasks() {
        // Cores 0–1 die at t=0 and recover at 50 ms; the 1 ms payloads
        // force the run through the fault window. Every task must commit
        // exactly once regardless of which side of the edge placed it.
        let topo = Topology::homogeneous(4);
        let mut d = TaoDag::new();
        for _ in 0..32 {
            d.add_task_payload(
                KernelClass::MatMul,
                0,
                1.0,
                Some(payload_fn(KernelClass::MatMul, |_r, _w| {
                    std::thread::sleep(Duration::from_millis(1));
                })),
            );
        }
        d.finalize().unwrap();
        let opts = RealEngineOpts {
            episodes: EpisodeSchedule::new(vec![crate::platform::Episode::fail_stop(
                vec![0, 1],
                0.0,
                Some(0.05),
            )]),
            ..Default::default()
        };
        let res = run_dag_real(&d, &topo, &HomogeneousWs, None, &opts).unwrap();
        assert_eq!(res.n_tasks(), 32);
        let mut tasks: Vec<_> = res.records.iter().map(|r| r.task).collect();
        tasks.sort_unstable();
        tasks.dedup();
        assert_eq!(tasks.len(), 32, "a task committed twice or not at all");
    }

    #[test]
    fn schedule_killing_every_core_forever_is_rejected() {
        let topo = Topology::homogeneous(2);
        let (dag, _) = counting_dag(4, false);
        let opts = RealEngineOpts {
            episodes: EpisodeSchedule::new(vec![crate::platform::Episode::fail_stop(
                vec![0, 1],
                0.0,
                None,
            )]),
            ..Default::default()
        };
        let err = run_dag_real(&dag, &topo, &HomogeneousWs, None, &opts).unwrap_err();
        assert!(matches!(err, SchedError::AllCoresDead { .. }), "got {err}");
    }
}
