//! Offline *plan-ahead* list schedulers: HEFT, PEFT, DLS and a portfolio
//! meta-policy.
//!
//! The paper's baselines are *online*: `dheft-like` keeps availability
//! clocks and decides per task at `place()` time, never seeing the DAG as
//! a whole. The classical heterogeneous-scheduling literature instead
//! *plans ahead* — it ranks the entire DAG against a static performance
//! model and fixes every placement before the first task runs. This
//! module adds that family so the experiment matrix
//! (`repro experiment`) can quantify what whole-DAG lookahead buys (or
//! costs) relative to the PTT's measured-online approach:
//!
//! - **HEFT** (Topcuoglu et al.): upward-rank priority (mean compute +
//!   mean communication along the heaviest child chain), earliest-finish-
//!   time placement;
//! - **PEFT** (Arabnejad & Barbosa): optimistic-cost-table priority with
//!   `EFT + OCT(task, partition)` placement. The OCT is
//!   partition-dependent here because DAG edges carry data bytes
//!   ([`TaoDag::edge_bytes`]) priced by the platform transfer model
//!   ([`Platform::edge_transfer_time`]) — on byte-free DAGs it
//!   degenerates to EFT under a different priority order, as before;
//! - **DLS** (Sih & Lee): joint `(task, partition)` argmax of the dynamic
//!   level — static level minus earliest start time, with a Δ term
//!   rewarding partitions faster than the task's average;
//! - **portfolio**: plans the DAG with every planner above and keeps the
//!   plan with the best predicted makespan (ties break in registry
//!   order).
//!
//! All planners consult the *episode-free* analytic model
//! ([`Platform::ideal_exec_time`] with the episode schedule stripped):
//! plans are made against nominal machine capability, exactly like their
//! literature counterparts, and dynamic interference is what they are
//! expected to be blind to. Costs are per `(kernel class, partition)`,
//! scaled by each node's `work_scale`.
//!
//! The plan is replayed through the ordinary [`Policy`] seam by
//! [`PlannedPolicy`]: `place()` looks the task id up in the precomputed
//! assignment, so `SchedCore`, both execution backends and the
//! conformance tests are untouched. A `PlannedPolicy` constructed without
//! a plan (what [`super::scheduler::policy_by_name`] returns, since it
//! cannot see a DAG) falls back to width-1 local placement; the exec
//! layer swaps in a planned instance per DAG via [`planned_policy`].
//!
//! Planners guarantee precedence feasibility by construction: the shared
//! scheduling loop only ever picks from the *ready* set, whatever the
//! priority order says.

use super::dag::{TaoDag, TaskId};
use super::scheduler::{EngineView, PlaceCtx, Policy, TaskView};
use crate::platform::{EpisodeSchedule, KernelClass, Partition, Platform};

/// Canonical planner names, in registry (and portfolio tie-break) order.
pub const PLANNER_NAMES: [&str; 4] = ["heft", "peft", "dls", "portfolio"];

/// A whole-DAG placement plan: one partition per task id, plus the
/// model-predicted makespan of the schedule that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// Canonical name of the planner that produced this plan (for
    /// `portfolio`, the meta-policy's own name, not the winner's).
    pub planner: &'static str,
    /// `assignment[task]` is the planned partition of `task`.
    pub assignment: Vec<Partition>,
    /// Schedule length under the episode-free analytic cost model.
    pub predicted_makespan: f64,
}

/// Per-`(partition, kernel class)` cost table from the episode-free
/// analytic model. Shared by every planner for one `(dag, platform)`.
struct CostModel {
    parts: Vec<Partition>,
    /// `cost[part_idx][class.index()]` — uncontended, episode-free
    /// execution time of one unit of work (`work_scale == 1.0`).
    cost: Vec<[f64; 4]>,
    /// The episode-free platform, kept for the data-transfer model
    /// ([`Platform::edge_transfer_time`]).
    plat: Platform,
}

impl CostModel {
    fn new(plat: &Platform) -> CostModel {
        // Strip the episode schedule: planners (and the literature they
        // come from) see nominal machine capability only. Keeping the
        // schedule would also poison costs with whatever episode happens
        // to be active at t = 0.
        let clean = Platform {
            topo: plat.topo.clone(),
            dram_bw_gbps: plat.dram_bw_gbps,
            episodes: EpisodeSchedule::default(),
        };
        let parts = clean.topo.all_partitions();
        let cost = parts
            .iter()
            .map(|&p| {
                let mut row = [0.0f64; 4];
                for class in KernelClass::ALL {
                    row[class.index()] = clean.ideal_exec_time(class, p);
                }
                row
            })
            .collect();
        CostModel { parts, cost, plat: clean }
    }

    fn node_cost(&self, dag: &TaoDag, t: TaskId, pi: usize) -> f64 {
        self.cost[pi][dag.nodes[t].class.index()] * dag.nodes[t].work_scale
    }

    /// Mean cost over all partitions — the `w̄(i)` of the HEFT/DLS papers.
    fn mean_cost(&self, dag: &TaoDag, t: TaskId) -> f64 {
        let sum: f64 = (0..self.parts.len()).map(|pi| self.node_cost(dag, t, pi)).sum();
        sum / self.parts.len() as f64
    }

    /// Best-case cost over all partitions (PEFT's OCT recursion).
    fn min_cost(&self, dag: &TaoDag, t: TaskId) -> f64 {
        (0..self.parts.len())
            .map(|pi| self.node_cost(dag, t, pi))
            .fold(f64::INFINITY, f64::min)
    }

    /// Communication cost of the `from → to` edge when the producer ran
    /// on `from_part` and the consumer is placed on `to_part`. Zero for
    /// control-only edges (no bytes) and when both partitions share a
    /// leader (the data never moves).
    fn edge_cost(
        &self,
        dag: &TaoDag,
        from: TaskId,
        to: TaskId,
        from_part: Partition,
        to_part: Partition,
    ) -> f64 {
        let bytes = dag.edge_bytes(from, to).unwrap_or(0);
        if bytes == 0 || from_part.leader == to_part.leader {
            return 0.0;
        }
        self.plat.edge_transfer_time(bytes, from_part, to_part)
    }

    /// Mean communication cost of `from → to` over cluster pairs — the
    /// `c̄(i,j)` of the HEFT rank (partition-agnostic by definition).
    fn mean_edge_cost(&self, dag: &TaoDag, from: TaskId, to: TaskId) -> f64 {
        let bytes = dag.edge_bytes(from, to).unwrap_or(0);
        if bytes == 0 {
            return 0.0;
        }
        let clusters = &self.plat.topo.clusters;
        let n = clusters.len() as f64;
        let sum: f64 = clusters
            .iter()
            .flat_map(|a| {
                clusters.iter().map(move |b| {
                    self.plat.transfer_time(bytes, a.id == b.id, b.cache_bytes)
                })
            })
            .sum();
        sum / (n * n)
    }
}

/// Mutable state of one list-scheduling pass: per-core availability
/// clocks, per-task ready times, the ready set and the growing plan.
struct ListState<'a> {
    dag: &'a TaoDag,
    model: &'a CostModel,
    avail: Vec<f64>,
    /// Model finish time of each committed task (data-arrival input for
    /// the per-partition EST below).
    finish: Vec<f64>,
    indeg: Vec<usize>,
    ready: Vec<TaskId>,
    assignment: Vec<Partition>,
    makespan: f64,
}

impl<'a> ListState<'a> {
    fn new(dag: &'a TaoDag, model: &'a CostModel, n_cores: usize) -> ListState<'a> {
        let n = dag.len();
        let indeg: Vec<usize> = dag.nodes.iter().map(|node| node.preds.len()).collect();
        let ready: Vec<TaskId> =
            (0..n).filter(|&t| indeg[t] == 0).collect();
        ListState {
            dag,
            model,
            avail: vec![0.0; n_cores],
            finish: vec![0.0; n],
            indeg,
            ready,
            assignment: vec![Partition { leader: 0, width: 1 }; n],
            makespan: 0.0,
        }
    }

    /// Earliest start of `t` on partition `pi`: data-arrival time (each
    /// predecessor's finish plus the edge's transfer cost from where it
    /// actually ran — `t` is ready, so every predecessor is committed) vs
    /// the latest availability clock among the partition's cores
    /// (non-insertion variant — gaps are not back-filled, matching the
    /// runtime's work-conserving queues).
    fn est(&self, t: TaskId, pi: usize) -> f64 {
        let part = self.model.parts[pi];
        let data_ready = self.dag.nodes[t].preds.iter().fold(0.0f64, |acc, &p| {
            acc.max(
                self.finish[p]
                    + self.model.edge_cost(self.dag, p, t, self.assignment[p], part),
            )
        });
        part.cores().fold(data_ready, |acc, c| acc.max(self.avail[c]))
    }

    /// Min-EFT partition for `t`; strict `<` keeps the first (smallest
    /// leader, then narrowest width — `all_partitions` order) on ties,
    /// so plans are deterministic.
    fn best_eft(&self, t: TaskId) -> (usize, f64) {
        self.best_eft_biased(t, |_| 0.0)
    }

    /// Min of `EFT + bias(partition)` for `t`, returning the *actual* EFT
    /// of the argmin (PEFT's `O_EFT = EFT + OCT` selection rule; a zero
    /// bias is plain EFT).
    fn best_eft_biased(&self, t: TaskId, bias: impl Fn(usize) -> f64) -> (usize, f64) {
        let mut best = (0usize, f64::INFINITY);
        let mut best_score = f64::INFINITY;
        for pi in 0..self.model.parts.len() {
            let eft = self.est(t, pi) + self.model.node_cost(self.dag, t, pi);
            let score = eft + bias(pi);
            if score < best_score {
                best_score = score;
                best = (pi, eft);
            }
        }
        best
    }

    /// Commit `t` to partition `pi` finishing at `eft`: bump the member
    /// cores' clocks, release successors whose last predecessor this was.
    fn commit(&mut self, t: TaskId, pi: usize, eft: f64) {
        let part = self.model.parts[pi];
        self.assignment[t] = part;
        self.finish[t] = eft;
        for c in part.cores() {
            self.avail[c] = eft;
        }
        self.makespan = self.makespan.max(eft);
        let pos = self.ready.iter().position(|&r| r == t).expect("t was ready");
        self.ready.swap_remove(pos);
        let succs = self.dag.nodes[t].succs.clone();
        for succ in succs {
            self.indeg[succ] -= 1;
            if self.indeg[succ] == 0 {
                self.ready.push(succ);
            }
        }
    }
}

/// Shared loop of the rank-based planners (HEFT, PEFT): repeatedly take
/// the ready task with the highest `priority` (ties: lowest task id) and
/// place it on the partition minimising `EFT + bias(task, partition)`
/// (HEFT passes a zero bias — plain min-EFT; PEFT passes its OCT).
fn schedule_by_priority(
    planner: &'static str,
    dag: &TaoDag,
    model: &CostModel,
    n_cores: usize,
    priority: &[f64],
    bias: impl Fn(TaskId, usize) -> f64,
) -> Plan {
    let mut st = ListState::new(dag, model, n_cores);
    while !st.ready.is_empty() {
        let mut pick = st.ready[0];
        for &t in &st.ready[1..] {
            if priority[t] > priority[pick] || (priority[t] == priority[pick] && t < pick)
            {
                pick = t;
            }
        }
        let (pi, eft) = st.best_eft_biased(pick, |p| bias(pick, p));
        st.commit(pick, pi, eft);
    }
    Plan { planner, assignment: st.assignment, predicted_makespan: st.makespan }
}

/// HEFT/DLS upward rank (static level): `rank[i] = w̄(i) + max over
/// successors (c̄(i,s) + rank[s])`, computed in reverse topological order
/// with the mean transfer cost `c̄` over cluster pairs.
fn upward_rank(dag: &TaoDag, model: &CostModel) -> Vec<f64> {
    let order = dag.topo_order().expect("planner needs an acyclic DAG");
    let mut rank = vec![0.0f64; dag.len()];
    for &t in order.iter().rev() {
        let succ_max = dag.nodes[t]
            .succs
            .iter()
            .fold(0.0f64, |acc, &s| acc.max(model.mean_edge_cost(dag, t, s) + rank[s]));
        rank[t] = model.mean_cost(dag, t) + succ_max;
    }
    rank
}

/// PEFT optimistic cost table, per `(task, partition)`:
/// `OCT(i, p) = max over successors s of min over partitions q of
/// (OCT(s, q) + cost(s, q) + c(i→s, p, q))`, 0 at exits. With byte-free
/// edges every column is identical (the historical degenerate case).
fn optimistic_cost(dag: &TaoDag, model: &CostModel) -> Vec<Vec<f64>> {
    let order = dag.topo_order().expect("planner needs an acyclic DAG");
    let np = model.parts.len();
    let mut oct = vec![vec![0.0f64; np]; dag.len()];
    for &t in order.iter().rev() {
        for pi in 0..np {
            let from_part = model.parts[pi];
            oct[t][pi] = dag.nodes[t].succs.iter().fold(0.0f64, |acc, &s| {
                let best = (0..np)
                    .map(|pj| {
                        oct[s][pj]
                            + model.node_cost(dag, s, pj)
                            + model.edge_cost(dag, t, s, from_part, model.parts[pj])
                    })
                    .fold(f64::INFINITY, f64::min);
                acc.max(best)
            });
        }
    }
    oct
}

fn heft(dag: &TaoDag, model: &CostModel, n_cores: usize) -> Plan {
    let rank = upward_rank(dag, model);
    schedule_by_priority("heft", dag, model, n_cores, &rank, |_, _| 0.0)
}

fn peft(dag: &TaoDag, model: &CostModel, n_cores: usize) -> Plan {
    let oct = optimistic_cost(dag, model);
    // Priority = mean OCT over partitions (the paper's rank_oct).
    let rank: Vec<f64> = oct
        .iter()
        .map(|row| row.iter().sum::<f64>() / row.len().max(1) as f64)
        .collect();
    schedule_by_priority("peft", dag, model, n_cores, &rank, |t, pi| oct[t][pi])
}

/// DLS: at every step pick the `(ready task, partition)` pair maximising
/// the dynamic level `SL(i) − EST(i,p) + (w̄(i) − cost(i,p))`.
fn dls(dag: &TaoDag, model: &CostModel, n_cores: usize) -> Plan {
    let sl = upward_rank(dag, model);
    let mut st = ListState::new(dag, model, n_cores);
    while !st.ready.is_empty() {
        // Iterate tasks in ascending id and partitions in registry order;
        // strict `>` keeps the first maximiser, so plans are
        // deterministic.
        let mut ready = st.ready.clone();
        ready.sort_unstable();
        let mut best: Option<(TaskId, usize, f64, f64)> = None;
        for &t in &ready {
            let wbar = model.mean_cost(dag, t);
            for pi in 0..model.parts.len() {
                let est = st.est(t, pi);
                let cost = model.node_cost(dag, t, pi);
                let dl = sl[t] - est + (wbar - cost);
                let better = match best {
                    None => true,
                    Some((_, _, _, b)) => dl > b,
                };
                if better {
                    best = Some((t, pi, est + cost, dl));
                }
            }
        }
        let (t, pi, eft, _) = best.expect("ready set was non-empty");
        st.commit(t, pi, eft);
    }
    Plan { planner: "dls", assignment: st.assignment, predicted_makespan: st.makespan }
}

/// Plan with every base planner and keep the best predicted makespan.
fn portfolio(dag: &TaoDag, model: &CostModel, n_cores: usize) -> Plan {
    let candidates =
        [heft(dag, model, n_cores), peft(dag, model, n_cores), dls(dag, model, n_cores)];
    let mut best = 0usize;
    for i in 1..candidates.len() {
        // Strict `<`: ties keep the earlier planner (registry order).
        if candidates[i].predicted_makespan < candidates[best].predicted_makespan {
            best = i;
        }
    }
    let won = candidates[best].clone();
    Plan { planner: "portfolio", ..won }
}

/// Resolve `name` (canonical or registry alias) to a planner name, or
/// `None` if it names an online policy or nothing at all.
pub fn canonical_planner(name: &str) -> Option<&'static str> {
    let canon = super::scheduler::POLICIES
        .iter()
        .find(|p| p.name == name || p.aliases.contains(&name))
        .map(|p| p.name)
        .unwrap_or(name);
    PLANNER_NAMES.into_iter().find(|&p| p == canon)
}

/// Plan `dag` for `plat` with the named planner. `None` for non-planner
/// names (callers fall back to the online registry) and for empty DAGs
/// (nothing to plan).
pub fn plan_dag(name: &str, dag: &TaoDag, plat: &Platform) -> Option<Plan> {
    let canon = canonical_planner(name)?;
    if dag.is_empty() {
        return None;
    }
    let model = CostModel::new(plat);
    let n_cores = plat.topo.n_cores();
    Some(match canon {
        "heft" => heft(dag, &model, n_cores),
        "peft" => peft(dag, &model, n_cores),
        "dls" => dls(dag, &model, n_cores),
        "portfolio" => portfolio(dag, &model, n_cores),
        _ => unreachable!("canonical_planner only returns PLANNER_NAMES"),
    })
}

/// Plan `dag` and wrap the result as a ready-to-run [`Policy`]. `None`
/// when `name` is not a planner — the caller should resolve it through
/// the ordinary online registry instead.
pub fn planned_policy(
    name: &str,
    dag: &TaoDag,
    plat: &Platform,
) -> Option<Box<dyn Policy>> {
    plan_dag(name, dag, plat)
        .map(|plan| Box::new(PlannedPolicy::from_plan(plan)) as Box<dyn Policy>)
}

/// Replays a precomputed [`Plan`] through the online [`Policy`] seam.
///
/// The runtime calls `place()` exactly when the classical planners assume
/// — at task release, every predecessor committed — so replaying the
/// static assignment preserves the plan's precedence structure; only the
/// *timing* differs from the prediction (queues, interference, the real
/// machine). Tasks outside the plan (or a planless instance from
/// `policy_by_name`, which cannot see a DAG) fall back to width-1
/// placement on the asking core.
pub struct PlannedPolicy {
    name: &'static str,
    plan: Vec<Partition>,
}

impl PlannedPolicy {
    /// Registry constructor: reports the planner's canonical name but
    /// holds no plan. The exec layer replaces it per DAG via
    /// [`planned_policy`]; if one ever runs as-is, the width-1 fallback
    /// keeps it a valid (if unremarkable) policy.
    pub fn unplanned(name: &'static str) -> PlannedPolicy {
        PlannedPolicy { name, plan: Vec::new() }
    }

    pub fn from_plan(plan: Plan) -> PlannedPolicy {
        PlannedPolicy { name: plan.planner, plan: plan.assignment }
    }

    /// Number of tasks covered by the held plan (0 when unplanned).
    pub fn planned_tasks(&self) -> usize {
        self.plan.len()
    }
}

impl Policy for PlannedPolicy {
    fn name(&self) -> &'static str {
        self.name
    }

    fn place(&self, ctx: &PlaceCtx<'_>) -> Partition {
        self.plan
            .get(ctx.task)
            .copied()
            .unwrap_or(Partition { leader: ctx.core, width: 1 })
    }

    fn uses_ptt(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dag::paper_figure1_dag;
    use crate::dag_gen::fixtures::{chain_dag, independent_dag};
    use crate::platform::scenarios;

    fn tx2() -> Platform {
        scenarios::by_name("tx2").expect("tx2 is registered")
    }

    /// A plan must cover every task with a partition valid on the
    /// platform, and scheduling must respect precedence by construction
    /// (checked here through the predicted finish ordering of a chain).
    #[test]
    fn plans_cover_every_task_with_valid_partitions() {
        let plat = tx2();
        let (dag, _) = paper_figure1_dag();
        for name in PLANNER_NAMES {
            let plan = plan_dag(name, &dag, &plat).expect("planner name");
            assert_eq!(plan.assignment.len(), dag.len(), "{name}");
            assert!(plan.predicted_makespan > 0.0, "{name}");
            for &p in &plan.assignment {
                assert!(plat.topo.is_valid_partition(p), "{name}: invalid {p:?}");
            }
        }
    }

    #[test]
    fn chain_prediction_is_sum_of_best_costs() {
        // A strict chain admits no overlap: every planner must predict
        // exactly the sum of per-task best-partition costs.
        let plat = tx2();
        let dag = chain_dag(6, KernelClass::MatMul);
        let model = CostModel::new(&plat);
        let best: f64 = (0..dag.len()).map(|t| model.min_cost(&dag, t)).sum();
        for name in ["heft", "peft", "dls"] {
            let plan = plan_dag(name, &dag, &plat).unwrap();
            assert!(
                (plan.predicted_makespan - best).abs() < 1e-12,
                "{name}: predicted {} vs chain bound {best}",
                plan.predicted_makespan
            );
        }
    }

    #[test]
    fn est_charges_cross_cluster_data_movement() {
        let plat = tx2();
        let mut dag = TaoDag::new();
        let a = dag.add_task(KernelClass::MatMul, 0, 1.0);
        let b = dag.add_task(KernelClass::MatMul, 0, 1.0);
        dag.add_edge_bytes(a, b, 64 << 20);
        dag.finalize().unwrap();
        let model = CostModel::new(&plat);
        let mut st = ListState::new(&dag, &model, plat.topo.n_cores());
        let part_idx = |leader: usize| {
            model.parts.iter().position(|p| p.leader == leader && p.width == 1).unwrap()
        };
        // Commit A on denver core 0, finishing at t = 1.
        st.commit(a, part_idx(0), 1.0);
        // Consuming on the producer's leader is free; a sibling core in
        // the same cluster pays cache-to-cache bandwidth; the other
        // cluster pays the hop plus (spilled) DRAM bandwidth.
        let local = st.est(b, part_idx(0));
        let same_cluster = st.est(b, part_idx(1));
        let cross = st.est(b, part_idx(2));
        assert!((local - 1.0).abs() < 1e-12, "co-located data must be free: {local}");
        assert!(same_cluster > 1.0);
        assert!(cross > same_cluster, "{cross} vs {same_cluster}");
    }

    #[test]
    fn peft_oct_is_partition_dependent_with_data_bytes() {
        let plat = tx2();
        let mut dag = TaoDag::new();
        let a = dag.add_task(KernelClass::MatMul, 0, 1.0);
        let b = dag.add_task(KernelClass::MatMul, 0, 1.0);
        dag.add_edge_bytes(a, b, 64 << 20);
        dag.finalize().unwrap();
        let model = CostModel::new(&plat);
        let oct = optimistic_cost(&dag, &model);
        assert!(
            oct[a].iter().any(|&v| (v - oct[a][0]).abs() > 1e-12),
            "with data bytes the OCT must vary by partition: {:?}",
            oct[a]
        );
        // Byte-free edges keep the historical degenerate (uniform) OCT.
        let mut dag0 = TaoDag::new();
        let a0 = dag0.add_task(KernelClass::MatMul, 0, 1.0);
        let b0 = dag0.add_task(KernelClass::MatMul, 0, 1.0);
        dag0.add_edge(a0, b0);
        dag0.finalize().unwrap();
        let oct0 = optimistic_cost(&dag0, &model);
        assert!(oct0[a0].iter().all(|&v| (v - oct0[a0][0]).abs() < 1e-15));
    }

    #[test]
    fn independent_tasks_spread_across_the_machine() {
        // 12 independent tasks on 6 cores: any planner must beat the
        // serial schedule by a wide margin.
        let plat = tx2();
        let dag = independent_dag(12, KernelClass::Sort);
        let model = CostModel::new(&plat);
        let serial: f64 = (0..dag.len()).map(|t| model.min_cost(&dag, t)).sum();
        for name in PLANNER_NAMES {
            let plan = plan_dag(name, &dag, &plat).unwrap();
            assert!(
                plan.predicted_makespan < 0.75 * serial,
                "{name}: predicted {} vs serial {serial}",
                plan.predicted_makespan
            );
        }
    }

    #[test]
    fn portfolio_keeps_the_best_prediction() {
        let plat = tx2();
        let (dag, _) = paper_figure1_dag();
        let preds: Vec<f64> = ["heft", "peft", "dls"]
            .iter()
            .map(|n| plan_dag(n, &dag, &plat).unwrap().predicted_makespan)
            .collect();
        let best = preds.iter().copied().fold(f64::INFINITY, f64::min);
        let port = plan_dag("portfolio", &dag, &plat).unwrap();
        assert_eq!(port.planner, "portfolio");
        assert!(
            (port.predicted_makespan - best).abs() < 1e-15,
            "portfolio {} vs best base {best}",
            port.predicted_makespan
        );
    }

    #[test]
    fn planning_is_deterministic() {
        let plat = scenarios::by_name("haswell20").unwrap();
        let (dag, _) = crate::dag_gen::generate(&crate::dag_gen::DagParams::mix(40, 4.0, 9));
        for name in PLANNER_NAMES {
            let a = plan_dag(name, &dag, &plat).unwrap();
            let b = plan_dag(name, &dag, &plat).unwrap();
            assert_eq!(a, b, "{name}");
        }
    }

    #[test]
    fn aliases_and_non_planners_resolve_correctly() {
        assert_eq!(canonical_planner("heft"), Some("heft"));
        assert_eq!(canonical_planner("heft-static"), Some("heft"));
        assert_eq!(canonical_planner("plan-portfolio"), Some("portfolio"));
        assert_eq!(canonical_planner("dheft"), None, "online dheft-like is not a planner");
        assert_eq!(canonical_planner("performance"), None);
        assert_eq!(canonical_planner("no-such"), None);
        let plat = tx2();
        let (dag, _) = paper_figure1_dag();
        assert!(plan_dag("dheft-like", &dag, &plat).is_none());
    }

    #[test]
    fn unplanned_policy_falls_back_to_local_width1() {
        use crate::coordinator::ptt::Ptt;
        let plat = tx2();
        let ptt = Ptt::new(1, &plat.topo);
        let pol = PlannedPolicy::unplanned("heft");
        assert_eq!(pol.name(), "heft");
        assert_eq!(pol.planned_tasks(), 0);
        assert!(!pol.uses_ptt());
        let ctx = PlaceCtx::new(
            TaskView {
                task: 17,
                type_id: 0,
                critical: true,
                max_width: usize::MAX,
                app_id: 0,
                qos: Default::default(),
            },
            EngineView { core: 3, ptt: &ptt, topo: &plat.topo, now: 0.0 },
        );
        assert_eq!(pol.place(&ctx), Partition { leader: 3, width: 1 });
    }
}
