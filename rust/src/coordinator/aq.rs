//! Per-core FIFO assembly queues (§3.1).
//!
//! When a ready TAO's resource partition is decided, a pointer to the TAO
//! is inserted into the AQ of **every core in the partition**; each core
//! then fetches its pointer asynchronously and executes its share. AQs are
//! strictly FIFO: placement is irrevocable, and consistent insertion order
//! across AQs (one placement inserts to all member queues before the next
//! placement's inserts can interleave on the same queues — guaranteed by
//! the engines) keeps multi-queue fetches deadlock-free.

use std::collections::VecDeque;
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct AssemblyQueue<T> {
    q: Mutex<VecDeque<T>>,
}

impl<T> AssemblyQueue<T> {
    pub fn new() -> AssemblyQueue<T> {
        AssemblyQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Insert at the tail (placement time).
    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
    }

    /// Fetch from the head (execution time).
    pub fn pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_fifo() {
        let q = AssemblyQueue::new();
        q.push("a");
        q.push("b");
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
    }
}
