//! Per-core FIFO assembly queues (§3.1) — a lock-free MPSC queue.
//!
//! When a ready TAO's resource partition is decided, a pointer to the TAO
//! is inserted into the AQ of **every core in the partition**; each core
//! then fetches its pointer asynchronously and executes its share. AQs are
//! strictly FIFO: placement is irrevocable, and because members execute
//! their share immediately on arrival (asynchronous entry, no barrier),
//! inconsistent insertion interleavings across AQs cannot produce a
//! circular wait (see `coordinator::worker`).
//!
//! The access pattern is **multi-producer, single-consumer**: any worker
//! that makes a placement decision pushes (into several AQs at once), but
//! only the queue's own core pops. This implementation is Vyukov's
//! intrusive MPSC queue: a push is one `swap` on the head plus one link
//! store — wait-free for producers — and the owner's pop is a plain
//! pointer chase. No operation takes a lock.
//!
//! Trade-off, stated honestly: each push allocates one node and each pop
//! frees one, so the *uncontended* per-op cost can exceed the old
//! mutex+`VecDeque` (which amortized allocation away). What the lock-free
//! queue buys is the contended case — no lock convoy when several placers
//! hit the same core's AQ while its owner fetches, which is precisely the
//! §5.3 interference scenario. `repro bench-overhead --compare` measures
//! both regimes rather than asserting either.
//!
//! One transient state exists by design: between a producer's `swap` and
//! its link store, the chain is momentarily broken and `pop` reports the
//! queue empty even though later pushes may have completed. The worker
//! loop simply re-polls, and the park/unpark protocol in
//! `coordinator::worker` orders every wake-up *after* the link store, so a
//! sleeping worker can never miss an insertion.
//!
//! The mutex-guarded baseline this replaced lives on in
//! [`super::mutex_queues`] for the `bench-overhead` comparison.

use std::cell::UnsafeCell;
use std::fmt;
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

struct Node<T> {
    next: AtomicPtr<Node<T>>,
    /// `None` only for the stub node the consumer currently parks on.
    value: Option<T>,
}

/// Lock-free MPSC FIFO queue. `push` from any thread; `pop` is
/// **owner-only** (exactly one consumer thread at a time — the engines
/// uphold this: only core `c` pops `aqs[c]`).
pub struct AssemblyQueue<T> {
    /// Producers `swap` here; points at the most recently pushed node.
    head: AtomicPtr<Node<T>>,
    /// Consumer-owned cursor; points at the current stub (the node whose
    /// value was already taken, or the initial dummy).
    tail: UnsafeCell<*mut Node<T>>,
    /// Item count (incremented before the push is linked, so it never
    /// under-reports a pop-visible item).
    count: AtomicUsize,
}

// Safety: `tail` is only touched by the single consumer (contract above);
// producers communicate exclusively through `head`/`next` atomics.
unsafe impl<T: Send> Send for AssemblyQueue<T> {}
unsafe impl<T: Send> Sync for AssemblyQueue<T> {}

impl<T> AssemblyQueue<T> {
    pub fn new() -> AssemblyQueue<T> {
        let stub = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: None,
        }));
        AssemblyQueue {
            head: AtomicPtr::new(stub),
            tail: UnsafeCell::new(stub),
            count: AtomicUsize::new(0),
        }
    }

    /// Insert at the tail (placement time). Any thread; wait-free.
    pub fn push(&self, item: T) {
        self.count.fetch_add(1, Ordering::AcqRel);
        let n = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: Some(item),
        }));
        let prev = self.head.swap(n, Ordering::AcqRel);
        // Link. Between the swap above and this store the chain is
        // unwalkable past `prev`; consumers transiently see "empty" and
        // re-poll (module docs).
        unsafe { (*prev).next.store(n, Ordering::Release) };
    }

    /// Fetch from the head (execution time). **Owner-only.**
    pub fn pop(&self) -> Option<T> {
        unsafe {
            let tail = *self.tail.get();
            let next = (*tail).next.load(Ordering::Acquire);
            if next.is_null() {
                return None;
            }
            let value = (*next).value.take();
            debug_assert!(value.is_some(), "non-stub node must carry a value");
            // `next` becomes the new stub; the old one is done for good.
            *self.tail.get() = next;
            drop(Box::from_raw(tail));
            self.count.fetch_sub(1, Ordering::AcqRel);
            value
        }
    }

    /// Approximate length (counts completed and in-flight pushes).
    pub fn len(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Default for AssemblyQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> fmt::Debug for AssemblyQueue<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AssemblyQueue").field("len", &self.len()).finish()
    }
}

impl<T> Drop for AssemblyQueue<T> {
    fn drop(&mut self) {
        // Exclusive access: walk the chain from the stub, freeing every
        // node (remaining values drop with their `Option`).
        unsafe {
            let mut p = *self.tail.get();
            while !p.is_null() {
                let boxed = Box::from_raw(p);
                p = boxed.next.load(Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_fifo() {
        let q = AssemblyQueue::new();
        q.push("a");
        q.push("b");
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn len_tracks_and_interleaves() {
        let q = AssemblyQueue::new();
        assert!(q.is_empty());
        q.push(1);
        q.push(2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        q.push(3);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn drops_unconsumed_values() {
        use std::sync::Arc;
        let marker = Arc::new(());
        {
            let q = AssemblyQueue::new();
            q.push(marker.clone());
            q.push(marker.clone());
            let _ = q.pop();
            // One value still queued when `q` drops.
        }
        assert_eq!(Arc::strong_count(&marker), 1, "queued Arc must be released");
    }

    #[test]
    fn concurrent_producers_single_consumer() {
        use std::sync::Arc;
        let q = Arc::new(AssemblyQueue::new());
        let producers = 4;
        let per = 500usize;
        std::thread::scope(|s| {
            for p in 0..producers {
                let q = &q;
                s.spawn(move || {
                    for i in 0..per {
                        q.push((p, i));
                    }
                });
            }
            // Single consumer drains from this thread.
            let mut next_seq = vec![0usize; producers];
            let mut got = 0;
            while got < producers * per {
                if let Some((p, i)) = q.pop() {
                    assert_eq!(i, next_seq[p], "per-producer FIFO violated");
                    next_seq[p] += 1;
                    got += 1;
                } else {
                    std::hint::spin_loop();
                }
            }
        });
        assert!(q.is_empty());
    }
}
