//! The backend-agnostic scheduling core: one task-lifecycle state machine
//! shared by the virtual-time engine ([`crate::sim`]) and the real-thread
//! engine ([`super::worker`]).
//!
//! DESIGN.md's soundness argument is that every scheduling decision is the
//! *same code objects* in both backends. Before this module that was only
//! literally true for [`Policy::place`]; the surrounding lifecycle —
//! [`PlaceCtx`] construction, the §3.3 commit-and-wake-up with the
//! criticality hand-off rule, the leader-side PTT update, per-application
//! attribution and [`TraceRecord`] construction — existed twice and was
//! held in sync only by the conformance test suite. [`SchedCore`] is that
//! lifecycle, written once:
//!
//! - **Placement** ([`SchedCore::place`]): read the wake-time criticality
//!   flag, build the [`PlaceCtx`], dispatch [`Policy::place`], validate the
//!   partition.
//! - **Observation** ([`SchedCore::record_leader_share`]): the leader-side
//!   PTT update (§3.2 — only the partition leader writes its PTT row, so
//!   the *caller* decides which thread invokes this; the real engine calls
//!   it from the leader's own share to avoid cache-line migration).
//! - **Commit-and-wake-up** ([`SchedCore::commit`]): construct the
//!   [`TraceRecord`], run the policy completion hook, hand the critical
//!   path to the `criticality − 1` child, release dependents exactly once
//!   and re-derive each released child's criticality (§3.3: a child is
//!   critical iff it sits on its application's critical path, seeded per
//!   app by [`TaoDag::cp_root_seeds`]).
//! - **Admission** ([`AdmissionSource`]): the one root-distribution rule
//!   (round-robin per admitted batch, §3.3's default policy) both stream
//!   engines consume.
//!
//! ## Concurrency contract
//!
//! Every method takes `&self` and all mutable state is atomic — per-task
//! dependency counters, criticality flags, critical-path membership, and
//! the completion counter. The real engine's workers therefore share one
//! `SchedCore` with **no locks and no new shared mutable state** beyond
//! the atomics the engine already used; the orderings are exactly the
//! pre-refactor ones (release counters `AcqRel`, criticality `Relaxed`
//! behind the counter's edge, critical-path membership `Acquire/Release`).
//! The sim engine drives the identical methods single-threaded: atomics
//! degenerate to plain loads/stores there, so the virtual-time backend's
//! bit-for-bit determinism is untouched (the sim's rng never enters this
//! module — jitter is applied by the substrate *before*
//! [`SchedCore::record_leader_share`]).
//!
//! What stays substrate-specific, by design: queues and work acquisition
//! (lock-free deques/MPSC vs `VecDeque`s), the notion of time (wall vs
//! virtual), execution itself (payloads vs the analytic rating model), and
//! where a committed record is stored (per-worker shard vs one `Vec`).

use super::dag::{TaoDag, TaskId};
use super::metrics::TraceRecord;
use super::ptt::Ptt;
use super::scheduler::{PlaceCtx, Policy};
use crate::platform::{CoreId, Partition, Topology};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// One placement decision, as returned by [`SchedCore::place`].
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    /// The partition chosen by the policy (already validated).
    pub partition: Partition,
    /// The §3.3 wake-time criticality the decision was made under — the
    /// substrate must carry it to [`SchedCore::commit`] so the trace
    /// records what the policy actually saw.
    pub critical: bool,
}

/// One finished TAO instance, as observed by the substrate.
///
/// The split between `t_start`/`t_end` (what the trace records) and `exec`
/// (what [`Policy::on_complete`] is told) preserves the engines' historical
/// semantics: in virtual time they coincide; on real threads the record
/// spans the leader share stretched to the commit instant, while the
/// policy hook sees the leader share alone.
#[derive(Debug, Clone, Copy)]
pub struct CommitInfo {
    pub task: TaskId,
    pub partition: Partition,
    /// Placement-time criticality (from [`Placement::critical`]).
    pub critical: bool,
    /// Recorded start of the instance.
    pub t_start: f64,
    /// Recorded end of the instance.
    pub t_end: f64,
    /// Execution time reported to [`Policy::on_complete`].
    pub exec: f64,
    /// Commit time (the policy hook's `now`).
    pub now: f64,
}

/// Result of one [`SchedCore::commit`].
#[derive(Debug, Clone, Copy)]
pub struct CommitOutcome {
    /// The trace record for this instance; the substrate owns where it is
    /// stored (per-worker shard, single `Vec`, …).
    pub record: TraceRecord,
    /// `true` exactly once per run: this commit completed the last task.
    pub done: bool,
}

/// The shared task-lifecycle state machine (see the module docs).
pub struct SchedCore<'a> {
    dag: &'a TaoDag,
    /// Task → application id; empty slice means "everything is app 0"
    /// (the single-DAG path pays no lookup cost for the app dimension).
    app_of: &'a [usize],
    topo: &'a Topology,
    policy: &'a dyn Policy,
    ptt: &'a Ptt,
    /// Per-task remaining-dependency counters; the committer whose
    /// `fetch_sub` hits 1 releases the child — exactly once.
    pending: Vec<AtomicUsize>,
    /// Criticality flags resolved at wake time (§3.3). Initial tasks stay
    /// `false`: they are *placed* as non-critical by definition.
    critical: Vec<AtomicBool>,
    /// Critical-path membership, seeded per application
    /// ([`TaoDag::cp_root_seeds`]) and propagated at commit time.
    on_cp: Vec<AtomicBool>,
    completed: AtomicUsize,
}

impl<'a> SchedCore<'a> {
    /// Build the lifecycle state for one run. `app_of` may be empty (all
    /// tasks belong to app 0) or cover every task.
    pub fn new(
        dag: &'a TaoDag,
        app_of: &'a [usize],
        topo: &'a Topology,
        policy: &'a dyn Policy,
        ptt: &'a Ptt,
    ) -> SchedCore<'a> {
        assert!(dag.is_finalized(), "finalize() the DAG before scheduling");
        assert!(
            app_of.is_empty() || app_of.len() == dag.len(),
            "app_of must be empty or cover every task"
        );
        SchedCore {
            dag,
            app_of,
            topo,
            policy,
            ptt,
            pending: dag.nodes.iter().map(|n| AtomicUsize::new(n.preds.len())).collect(),
            critical: dag.nodes.iter().map(|_| AtomicBool::new(false)).collect(),
            on_cp: dag.cp_root_seeds(app_of).into_iter().map(AtomicBool::new).collect(),
            completed: AtomicUsize::new(0),
        }
    }

    pub fn dag(&self) -> &'a TaoDag {
        self.dag
    }

    pub fn topo(&self) -> &'a Topology {
        self.topo
    }

    pub fn ptt(&self) -> &'a Ptt {
        self.ptt
    }

    /// Whether the active policy consumes PTT updates (substrates gate
    /// their observation cost — e.g. the sim's jitter rng draw — on this).
    pub fn uses_ptt(&self) -> bool {
        self.policy.uses_ptt()
    }

    /// Application owning `task` (0 when the run is single-app).
    pub fn app_of(&self, task: TaskId) -> usize {
        self.app_of.get(task).copied().unwrap_or(0)
    }

    /// Tasks committed so far.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Acquire)
    }

    /// Whether every task of the run has committed.
    pub fn is_done(&self) -> bool {
        self.completed() == self.dag.len()
    }

    /// Current wake-time criticality flag of `task` (diagnostics/tests;
    /// meaningful once the task has been released by its last parent).
    pub fn is_critical(&self, task: TaskId) -> bool {
        self.critical[task].load(Ordering::Relaxed)
    }

    /// Place one ready task from the perspective of `core` at time `now`:
    /// build the [`PlaceCtx`], dispatch the policy, validate the result.
    pub fn place(&self, core: CoreId, task: TaskId, now: f64) -> Placement {
        let node = &self.dag.nodes[task];
        let critical = self.critical[task].load(Ordering::Relaxed);
        let ctx = PlaceCtx {
            core,
            type_id: node.type_id,
            critical,
            app_id: self.app_of(task),
            ptt: self.ptt,
            topo: self.topo,
            now,
        };
        let partition = self.policy.place(&ctx);
        debug_assert!(self.topo.is_valid_partition(partition), "{partition:?}");
        Placement { partition, critical }
    }

    /// The leader-side PTT update (§3.2): record the leader share's
    /// observed execution time. No-op for PTT-unaware policies.
    ///
    /// The caller chooses the invoking thread: the real engine calls this
    /// from the leader's own share (the paper's rule for avoiding PTT
    /// cache-line migration); the single-threaded sim calls it at
    /// completion, after applying its timer-jitter model.
    ///
    /// One observation feeds the PTT's *entire* v2 state — the long-run
    /// average, the recent window and the per-core change detector
    /// ([`Ptt::update`]) — so both engines share the change-detection
    /// logic by construction, exactly like the rest of the lifecycle.
    pub fn record_leader_share(&self, task: TaskId, partition: Partition, observed_exec: f64) {
        if self.policy.uses_ptt() {
            self.ptt.update(
                self.dag.nodes[task].type_id,
                partition.leader,
                partition.width,
                observed_exec,
            );
        }
    }

    /// Commit-and-wake-up (§3.3), shared verbatim by both engines:
    ///
    /// 1. construct the [`TraceRecord`] (returned — storage is the
    ///    substrate's concern);
    /// 2. run [`Policy::on_complete`];
    /// 3. hand the critical path to the `criticality − 1` child
    ///    ([`TaoDag::finalize`]'s `cp_child`) *before* any wake-up can
    ///    read the membership flag;
    /// 4. decrement each successor's dependency counter; the committer
    ///    that drops one to zero re-derives the child's criticality and
    ///    invokes `wake(child)` — exactly once per child across all
    ///    concurrent committers. The substrate enqueues the child wherever
    ///    its ready tasks live (the committer's deque on real threads, the
    ///    leader's queue in virtual time).
    ///
    /// Returns the record plus `done == true` on the run's final commit.
    pub fn commit(&self, info: &CommitInfo, mut wake: impl FnMut(TaskId)) -> CommitOutcome {
        let node = &self.dag.nodes[info.task];
        let record = TraceRecord {
            task: info.task,
            app_id: self.app_of(info.task),
            class: node.class,
            type_id: node.type_id,
            critical: info.critical,
            partition: info.partition,
            t_start: info.t_start,
            t_end: info.t_end,
        };
        self.policy.on_complete(info.partition.leader, info.partition.width, info.exec, info.now);
        // Critical-path hand-off: a task on the path marks the one child
        // whose criticality is exactly one less (§2: critical tasks are
        // the tasks *of the critical path*; the diff-by-1 check alone
        // would flood layered DAGs where every edge decrements
        // criticality).
        if self.on_cp[info.task].load(Ordering::Acquire) {
            if let Some(c) = node.cp_child {
                self.on_cp[c].store(true, Ordering::Release);
            }
        }
        for &child in &node.succs {
            if self.pending[child].fetch_sub(1, Ordering::AcqRel) == 1 {
                let crit = self.on_cp[child].load(Ordering::Acquire);
                self.critical[child].store(crit, Ordering::Relaxed);
                wake(child);
            }
        }
        let done = self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.dag.len();
        CommitOutcome { record, done }
    }
}

/// A workload stream's admission schedule, consumed identically by both
/// substrates: `(arrival, roots)` batches sorted by arrival, distributed
/// round-robin over the per-core lanes (§3.3's default root distribution,
/// restarting at lane 0 for every batch).
///
/// The cursor is atomic so the source can be shared by reference (the real
/// engine's bootstrap admits on the main thread, then hands the source to
/// the submitter thread), **not** to support concurrent admitters: at most
/// one thread may admit at a time.
pub struct AdmissionSource<'a> {
    batches: &'a [(f64, Vec<TaskId>)],
    next: AtomicUsize,
}

impl<'a> AdmissionSource<'a> {
    /// Validate the schedule against the DAG (see
    /// [`TaoDag::validate_admissions`]) and wrap it.
    pub fn new(
        dag: &TaoDag,
        app_of: &[usize],
        batches: &'a [(f64, Vec<TaskId>)],
    ) -> AdmissionSource<'a> {
        dag.validate_admissions(app_of, batches);
        AdmissionSource { batches, next: AtomicUsize::new(0) }
    }

    /// Arrival time of the next unadmitted batch, if any.
    pub fn next_arrival(&self) -> Option<f64> {
        self.batches.get(self.next.load(Ordering::Acquire)).map(|b| b.0)
    }

    /// Whether every batch has been admitted.
    pub fn is_exhausted(&self) -> bool {
        self.next.load(Ordering::Acquire) >= self.batches.len()
    }

    /// Admit every batch whose arrival is `<= now`, distributing each
    /// batch's roots round-robin over `n_lanes` via `push(lane, root)`.
    /// Returns the number of roots admitted (0 when nothing was due).
    pub fn admit_due(
        &self,
        now: f64,
        n_lanes: usize,
        mut push: impl FnMut(usize, TaskId),
    ) -> usize {
        let mut admitted = 0usize;
        loop {
            let i = self.next.load(Ordering::Acquire);
            let Some((arrival, roots)) = self.batches.get(i) else { break };
            if *arrival > now {
                break;
            }
            for (k, &root) in roots.iter().enumerate() {
                push(k % n_lanes, root);
                admitted += 1;
            }
            self.next.store(i + 1, Ordering::Release);
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dag::paper_figure1_dag;
    use crate::coordinator::scheduler::{HomogeneousWs, PerformanceBased};

    fn topo4() -> Topology {
        Topology::homogeneous(4)
    }

    #[test]
    fn place_builds_ctx_and_validates() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        let p = core.place(2, 0, 0.0);
        assert_eq!(p.partition, Partition { leader: 2, width: 1 });
        assert!(!p.critical, "roots are non-critical by definition");
    }

    #[test]
    fn commit_releases_children_and_derives_criticality() {
        // Figure 1: A(0) is the CP root; committing A must wake C(2) as
        // critical and E(3) as non-critical.
        let (dag, [a, _b, c, e, ..]) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &PerformanceBased, &ptt);
        let place = core.place(0, a, 0.0);
        let info = CommitInfo {
            task: a,
            partition: place.partition,
            critical: place.critical,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        let mut woken = Vec::new();
        let out = core.commit(&info, |child| woken.push(child));
        assert_eq!(woken, vec![c, e]);
        assert!(core.is_critical(c), "C continues the critical path");
        assert!(!core.is_critical(e), "E is off the path");
        assert!(!out.done);
        assert_eq!(out.record.task, a);
        assert_eq!(out.record.app_id, 0);
        assert!(!out.record.critical);
        assert_eq!(core.completed(), 1);
    }

    #[test]
    fn commit_reports_done_exactly_on_last_task() {
        let mut d = TaoDag::new();
        let x = d.add_task(crate::platform::KernelClass::MatMul, 0, 1.0);
        let y = d.add_task(crate::platform::KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, y);
        d.finalize().unwrap();
        let topo = topo4();
        let ptt = Ptt::new(d.n_types(), &topo);
        let core = SchedCore::new(&d, &[], &topo, &HomogeneousWs, &ptt);
        let mk = |task| CommitInfo {
            task,
            partition: Partition { leader: 0, width: 1 },
            critical: false,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        assert!(!core.commit(&mk(x), |_| {}).done);
        assert!(core.commit(&mk(y), |_| {}).done);
        assert!(core.is_done());
    }

    #[test]
    fn record_leader_share_is_gated_on_policy() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let blind = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        blind.record_leader_share(0, Partition { leader: 1, width: 1 }, 0.5);
        assert_eq!(ptt.read(dag.nodes[0].type_id, 1, 1), 0.0, "PTT-unaware policy: no update");
        let aware = SchedCore::new(&dag, &[], &topo, &PerformanceBased, &ptt);
        aware.record_leader_share(0, Partition { leader: 1, width: 1 }, 0.5);
        assert!(ptt.read(dag.nodes[0].type_id, 1, 1) > 0.0);
    }

    #[test]
    fn admission_source_distributes_round_robin_per_batch() {
        let mut d = TaoDag::new();
        for _ in 0..5 {
            d.add_task(crate::platform::KernelClass::Sort, 0, 1.0);
        }
        d.finalize().unwrap();
        let batches = vec![(0.0, vec![0usize, 1, 2]), (0.5, vec![3, 4])];
        let src = AdmissionSource::new(&d, &[], &batches);
        assert_eq!(src.next_arrival(), Some(0.0));
        let mut got = Vec::new();
        assert_eq!(src.admit_due(0.0, 2, |lane, root| got.push((lane, root))), 3);
        assert_eq!(got, vec![(0, 0), (1, 1), (0, 2)]);
        assert_eq!(src.next_arrival(), Some(0.5));
        assert_eq!(src.admit_due(0.4, 2, |_, _| panic!("nothing due")), 0);
        // Each batch restarts at lane 0 — the historical rule both
        // engines implemented independently.
        got.clear();
        assert_eq!(src.admit_due(0.5, 2, |lane, root| got.push((lane, root))), 2);
        assert_eq!(got, vec![(0, 3), (1, 4)]);
        assert!(src.is_exhausted());
        assert_eq!(src.next_arrival(), None);
    }

    #[test]
    fn admission_source_catches_up_over_multiple_due_batches() {
        let mut d = TaoDag::new();
        for _ in 0..4 {
            d.add_task(crate::platform::KernelClass::Copy, 0, 1.0);
        }
        d.finalize().unwrap();
        let batches = vec![(0.0, vec![0usize, 1]), (0.1, vec![2]), (0.2, vec![3])];
        let src = AdmissionSource::new(&d, &[], &batches);
        let mut got = Vec::new();
        // A late sweep admits everything due, batch by batch, in order.
        assert_eq!(src.admit_due(0.15, 4, |lane, root| got.push((lane, root))), 3);
        assert_eq!(got, vec![(0, 0), (1, 1), (0, 2)]);
        assert!(!src.is_exhausted());
    }
}
