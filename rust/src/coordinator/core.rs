//! The backend-agnostic scheduling core: one task-lifecycle state machine
//! shared by the virtual-time engine ([`crate::sim`]) and the real-thread
//! engine ([`super::worker`]).
//!
//! DESIGN.md's soundness argument is that every scheduling decision is the
//! *same code objects* in both backends. Before this module that was only
//! literally true for [`Policy::place`]; the surrounding lifecycle —
//! [`PlaceCtx`] construction, the §3.3 commit-and-wake-up with the
//! criticality hand-off rule, the leader-side PTT update, per-application
//! attribution and [`TraceRecord`] construction — existed twice and was
//! held in sync only by the conformance test suite. [`SchedCore`] is that
//! lifecycle, written once:
//!
//! - **Placement** ([`SchedCore::place`]): read the wake-time criticality
//!   flag, build the [`PlaceCtx`], dispatch [`Policy::place`], validate the
//!   partition.
//! - **Observation** ([`SchedCore::record_leader_share`]): the leader-side
//!   PTT update (§3.2 — only the partition leader writes its PTT row, so
//!   the *caller* decides which thread invokes this; the real engine calls
//!   it from the leader's own share to avoid cache-line migration).
//! - **Commit-and-wake-up** ([`SchedCore::commit`]): construct the
//!   [`TraceRecord`], run the policy completion hook, hand the critical
//!   path to the `criticality − 1` child, release dependents exactly once
//!   and re-derive each released child's criticality (§3.3: a child is
//!   critical iff it sits on its application's critical path, seeded per
//!   app by [`TaoDag::cp_root_seeds`]).
//! - **Admission** ([`AdmissionSource`]): the one root-distribution rule
//!   (round-robin per admitted batch, §3.3's default policy) both stream
//!   engines consume.
//!
//! ## Concurrency contract
//!
//! Every method takes `&self` and all mutable state is atomic — per-task
//! dependency counters, criticality flags, critical-path membership, and
//! the completion counter. The real engine's workers therefore share one
//! `SchedCore` with **no locks and no new shared mutable state** beyond
//! the atomics the engine already used; the orderings are exactly the
//! pre-refactor ones (release counters `AcqRel`, criticality `Relaxed`
//! behind the counter's edge, critical-path membership `Acquire/Release`).
//! The sim engine drives the identical methods single-threaded: atomics
//! degenerate to plain loads/stores there, so the virtual-time backend's
//! bit-for-bit determinism is untouched (the sim's rng never enters this
//! module — jitter is applied by the substrate *before*
//! [`SchedCore::record_leader_share`]).
//!
//! What stays substrate-specific, by design: queues and work acquisition
//! (lock-free deques/MPSC vs `VecDeque`s), the notion of time (wall vs
//! virtual), execution itself (payloads vs the analytic rating model), and
//! where a committed record is stored (per-worker shard vs one `Vec`).

use super::dag::{TaoDag, TaskId};
use super::metrics::{RunResult, TraceRecord};
use super::ptt::Ptt;
use super::scheduler::{EngineView, PlaceCtx, Policy, QosClass, TaskView};
use crate::platform::{CoreId, Partition, Topology};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// One placement decision, as returned by [`SchedCore::place`].
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    /// The partition chosen by the policy (already validated).
    pub partition: Partition,
    /// The §3.3 wake-time criticality the decision was made under — the
    /// substrate must carry it to [`SchedCore::commit`] so the trace
    /// records what the policy actually saw.
    pub critical: bool,
}

/// One finished TAO instance, as observed by the substrate.
///
/// The split between `t_start`/`t_end` (what the trace records) and `exec`
/// (what [`Policy::on_complete`] is told) preserves the engines' historical
/// semantics: in virtual time they coincide; on real threads the record
/// spans the leader share stretched to the commit instant, while the
/// policy hook sees the leader share alone.
#[derive(Debug, Clone, Copy)]
pub struct CommitInfo {
    pub task: TaskId,
    pub partition: Partition,
    /// Placement-time criticality (from [`Placement::critical`]).
    pub critical: bool,
    /// Recorded start of the instance.
    pub t_start: f64,
    /// Recorded end of the instance.
    pub t_end: f64,
    /// Execution time reported to [`Policy::on_complete`].
    pub exec: f64,
    /// Commit time (the policy hook's `now`).
    pub now: f64,
}

/// Result of one [`SchedCore::commit`].
#[derive(Debug, Clone, Copy)]
pub struct CommitOutcome {
    /// The trace record for this instance; the substrate owns where it is
    /// stored (per-worker shard, single `Vec`, …).
    pub record: TraceRecord,
    /// `true` exactly once per run: this commit completed the last task.
    pub done: bool,
}

/// The shared task-lifecycle state machine (see the module docs).
pub struct SchedCore<'a> {
    dag: &'a TaoDag,
    /// Task → application id; empty slice means "everything is app 0"
    /// (the single-DAG path pays no lookup cost for the app dimension).
    app_of: &'a [usize],
    topo: &'a Topology,
    policy: &'a dyn Policy,
    ptt: &'a Ptt,
    /// Per-task remaining-dependency counters; the committer whose
    /// `fetch_sub` hits 1 releases the child — exactly once.
    pending: Vec<AtomicUsize>,
    /// Criticality flags resolved at wake time (§3.3). Initial tasks stay
    /// `false`: they are *placed* as non-critical by definition.
    critical: Vec<AtomicBool>,
    /// Critical-path membership, seeded per application
    /// ([`TaoDag::cp_root_seeds`]) and propagated at commit time.
    on_cp: Vec<AtomicBool>,
    /// Per-task commit latch: the CAS that makes commits idempotent. Work
    /// reclamation may re-admit a task whose first execution already
    /// landed (the failure raced the commit); the latch turns the second
    /// commit into a counted no-op instead of double-releasing children.
    committed: Vec<AtomicBool>,
    completed: AtomicUsize,
    /// Commits refused by the latch (must stay 0 in a correct run — the
    /// chaos harness asserts it; a reclamation bug shows up here instead
    /// of as corrupted dependency counters).
    duplicates: AtomicUsize,
    /// Tasks whose payload panicked (caught by the real engine's
    /// `catch_unwind`); they still commit — a failed task is a *terminal*
    /// state, not a lost one — but the count is surfaced.
    failed: AtomicUsize,
    /// Per-application QoS class (empty ⇒ every app is
    /// [`QosClass::default`]); set by [`SchedCore::with_app_qos`].
    qos_of: Vec<QosClass>,
    /// Per-application committed-task counters (rolling-fairness input for
    /// the serving layer; one relaxed add per commit).
    app_done: Vec<AtomicUsize>,
    /// Per-core monopolisation streaks: the app whose tasks this core led
    /// most recently, and how many of its commits ran uninterrupted there.
    /// Relaxed heuristic state for [`SchedCore::monopolists`].
    core_last_app: Vec<AtomicUsize>,
    core_streak: Vec<AtomicUsize>,
}

impl<'a> SchedCore<'a> {
    /// Build the lifecycle state for one run. `app_of` may be empty (all
    /// tasks belong to app 0) or cover every task.
    pub fn new(
        dag: &'a TaoDag,
        app_of: &'a [usize],
        topo: &'a Topology,
        policy: &'a dyn Policy,
        ptt: &'a Ptt,
    ) -> SchedCore<'a> {
        assert!(dag.is_finalized(), "finalize() the DAG before scheduling");
        assert!(
            app_of.is_empty() || app_of.len() == dag.len(),
            "app_of must be empty or cover every task"
        );
        let n_apps = app_of.iter().copied().max().map_or(1, |m| m + 1);
        let n_cores = topo.n_cores();
        SchedCore {
            dag,
            app_of,
            topo,
            policy,
            ptt,
            pending: dag.nodes.iter().map(|n| AtomicUsize::new(n.preds.len())).collect(),
            critical: dag.nodes.iter().map(|_| AtomicBool::new(false)).collect(),
            on_cp: dag.cp_root_seeds(app_of).into_iter().map(AtomicBool::new).collect(),
            committed: dag.nodes.iter().map(|_| AtomicBool::new(false)).collect(),
            completed: AtomicUsize::new(0),
            duplicates: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            qos_of: Vec::new(),
            app_done: (0..n_apps).map(|_| AtomicUsize::new(0)).collect(),
            core_last_app: (0..n_cores).map(|_| AtomicUsize::new(usize::MAX)).collect(),
            core_streak: (0..n_cores).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Attach per-application QoS classes (serving mode). `qos` must be
    /// empty or cover every app id appearing in `app_of`.
    pub fn with_app_qos(mut self, qos: Vec<QosClass>) -> SchedCore<'a> {
        assert!(
            qos.is_empty() || qos.len() >= self.app_done.len(),
            "qos must cover every app ({} < {})",
            qos.len(),
            self.app_done.len()
        );
        self.qos_of = qos;
        self
    }

    pub fn dag(&self) -> &'a TaoDag {
        self.dag
    }

    pub fn topo(&self) -> &'a Topology {
        self.topo
    }

    pub fn ptt(&self) -> &'a Ptt {
        self.ptt
    }

    /// Whether the active policy consumes PTT updates (substrates gate
    /// their observation cost — e.g. the sim's jitter rng draw — on this).
    pub fn uses_ptt(&self) -> bool {
        self.policy.uses_ptt()
    }

    /// Application owning `task` (0 when the run is single-app).
    pub fn app_of(&self, task: TaskId) -> usize {
        self.app_of.get(task).copied().unwrap_or(0)
    }

    /// QoS class of application `app` (default when none was attached).
    pub fn qos_of_app(&self, app: usize) -> QosClass {
        self.qos_of.get(app).copied().unwrap_or_default()
    }

    /// Number of applications in this run.
    pub fn n_apps(&self) -> usize {
        self.app_done.len()
    }

    /// Committed tasks of application `app` so far (rolling-fairness
    /// input; relaxed — a control heuristic, not an exactness contract).
    pub fn app_done(&self, app: usize) -> usize {
        self.app_done[app].load(Ordering::Relaxed)
    }

    /// Per-core monopolist snapshot: for each core, the app that led its
    /// last `min_streak`-or-more commits uninterrupted (`None` otherwise).
    /// Fed to [`Policy::on_fairness`] by the serving drivers.
    pub fn monopolists(&self, min_streak: usize) -> Vec<Option<usize>> {
        self.core_last_app
            .iter()
            .zip(&self.core_streak)
            .map(|(app, streak)| {
                let a = app.load(Ordering::Relaxed);
                (a != usize::MAX && streak.load(Ordering::Relaxed) >= min_streak).then_some(a)
            })
            .collect()
    }

    /// Cancel `n_tasks` tasks that will never be pushed to any queue (a
    /// shed admission: the app's roots were refused, so its whole subgraph
    /// is unreachable). Accounts them as completed so [`SchedCore::is_done`]
    /// still terminates the run; returns `true` when this cancellation
    /// completes the run (the caller must propagate the done signal the
    /// same way a final commit would).
    pub fn cancel_tasks(&self, n_tasks: usize) -> bool {
        self.completed.fetch_add(n_tasks, Ordering::AcqRel) + n_tasks == self.dag.len()
    }

    /// Tasks committed so far.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Acquire)
    }

    /// Commits refused by the idempotency latch (0 in a correct run).
    pub fn n_duplicates(&self) -> usize {
        self.duplicates.load(Ordering::Acquire)
    }

    /// Tasks whose payload panicked (caught and committed as failed).
    pub fn n_failed(&self) -> usize {
        self.failed.load(Ordering::Acquire)
    }

    /// Record one caught payload panic ([`SchedCore::n_failed`]).
    pub fn note_failed(&self, _task: TaskId) {
        self.failed.fetch_add(1, Ordering::AcqRel);
    }

    /// Has `task` already committed? Reclamation uses this to drop
    /// re-admitted work whose first execution landed after all.
    pub fn already_committed(&self, task: TaskId) -> bool {
        self.committed[task].load(Ordering::Acquire)
    }

    /// Mark `core` fail-stopped (or recovered). Delegates to the PTT's
    /// dead mask so both the placement searches and the final
    /// [`SchedCore::place`] remap read one source of truth.
    pub fn set_core_dead(&self, core: CoreId, dead: bool) {
        self.ptt.set_core_dead(core, dead);
    }

    /// Is `core` currently fail-stopped?
    pub fn is_core_dead(&self, core: CoreId) -> bool {
        self.ptt.core_dead(core)
    }

    /// Lowest-numbered live core, if any (queue-redirect target for work
    /// that would otherwise land on a dead core).
    pub fn first_live_core(&self) -> Option<CoreId> {
        (0..self.topo.n_cores()).find(|&c| !self.ptt.core_dead(c))
    }

    /// Whether every task of the run has committed.
    pub fn is_done(&self) -> bool {
        self.completed() == self.dag.len()
    }

    /// Current wake-time criticality flag of `task` (diagnostics/tests;
    /// meaningful once the task has been released by its last parent).
    pub fn is_critical(&self, task: TaskId) -> bool {
        self.critical[task].load(Ordering::Relaxed)
    }

    /// Place one ready task from the perspective of `core` at time `now`:
    /// build the [`PlaceCtx`], dispatch the policy, validate the result.
    pub fn place(&self, core: CoreId, task: TaskId, now: f64) -> Placement {
        let node = &self.dag.nodes[task];
        let critical = self.critical[task].load(Ordering::Relaxed);
        let app_id = self.app_of(task);
        let ctx = PlaceCtx::new(
            TaskView {
                task,
                type_id: node.type_id,
                critical,
                max_width: node.max_width,
                app_id,
                qos: self.qos_of_app(app_id),
            },
            EngineView { core, ptt: self.ptt, topo: self.topo, now },
        );
        let partition = self.policy.place(&ctx);
        debug_assert!(self.topo.is_valid_partition(partition), "{partition:?}");
        let partition = self.remap_off_dead_cores(partition, node.type_id);
        Placement { partition, critical }
    }

    /// Belt-and-braces fail-stop guard: whatever the policy chose, a
    /// partition touching a dead core is remapped to the best live
    /// partition before the substrate ever queues a share there. The
    /// adaptive policy already treats dead cores like flagged ones in its
    /// avoiding searches; this covers the PTT-blind baselines and replayed
    /// offline plans, whose decisions predate the failure.
    fn remap_off_dead_cores(&self, partition: Partition, type_id: usize) -> Partition {
        if !self.ptt.any_core_dead() || !partition.cores().any(|c| self.ptt.core_dead(c)) {
            return partition;
        }
        if let Some((p, _)) =
            self.ptt.best_global_avoiding(type_id, self.topo, |c| self.ptt.core_dead(c))
        {
            return p;
        }
        // Every partition touches a dead core but some single core is
        // still alive: degrade to width 1 there. With no live core at all
        // the original choice stands — the substrate reports the wedge
        // ([`crate::error::SchedError::AllCoresDead`]); placement cannot.
        match self.first_live_core() {
            Some(c) => Partition { leader: c, width: 1 },
            None => partition,
        }
    }

    /// The leader-side PTT update (§3.2): record the leader share's
    /// observed execution time. No-op for PTT-unaware policies.
    ///
    /// The caller chooses the invoking thread: the real engine calls this
    /// from the leader's own share (the paper's rule for avoiding PTT
    /// cache-line migration); the single-threaded sim calls it at
    /// completion, after applying its timer-jitter model.
    ///
    /// One observation feeds the PTT's *entire* v2 state — the long-run
    /// average, the recent window and the per-core change detector
    /// ([`Ptt::update`]) — so both engines share the change-detection
    /// logic by construction, exactly like the rest of the lifecycle.
    pub fn record_leader_share(&self, task: TaskId, partition: Partition, observed_exec: f64) {
        if self.policy.uses_ptt() {
            self.ptt.update(
                self.dag.nodes[task].type_id,
                partition.leader,
                partition.width,
                observed_exec,
            );
        }
    }

    /// Commit-and-wake-up (§3.3), shared verbatim by both engines:
    ///
    /// 1. construct the [`TraceRecord`] (returned — storage is the
    ///    substrate's concern);
    /// 2. run [`Policy::on_complete`];
    /// 3. hand the critical path to the `criticality − 1` child
    ///    ([`TaoDag::finalize`]'s `cp_child`) *before* any wake-up can
    ///    read the membership flag;
    /// 4. decrement each successor's dependency counter; the committer
    ///    that drops one to zero re-derives the child's criticality and
    ///    invokes `wake(child)` — exactly once per child across all
    ///    concurrent committers. The substrate enqueues the child wherever
    ///    its ready tasks live (the committer's deque on real threads, the
    ///    leader's queue in virtual time).
    ///
    /// Returns the record plus `done == true` on the run's final commit —
    /// or `None` when `task` already committed: the idempotency latch
    /// makes a duplicate commit (re-executed reclaimed work whose first
    /// run landed after all) a counted no-op instead of a
    /// double-release of children and a corrupted completion count.
    pub fn commit(&self, info: &CommitInfo, mut wake: impl FnMut(TaskId)) -> Option<CommitOutcome> {
        if self.committed[info.task].swap(true, Ordering::AcqRel) {
            self.duplicates.fetch_add(1, Ordering::AcqRel);
            return None;
        }
        let node = &self.dag.nodes[info.task];
        let app_id = self.app_of(info.task);
        let record = TraceRecord {
            task: info.task,
            app_id,
            class: node.class,
            type_id: node.type_id,
            critical: info.critical,
            partition: info.partition,
            t_start: info.t_start,
            t_end: info.t_end,
        };
        // Serving-feedback bookkeeping: per-app progress and the leader
        // core's monopolisation streak. Relaxed heuristic counters — racy
        // interleavings on one core merely shorten an observed streak.
        self.app_done[app_id].fetch_add(1, Ordering::Relaxed);
        let leader = info.partition.leader;
        if self.core_last_app[leader].load(Ordering::Relaxed) == app_id {
            self.core_streak[leader].fetch_add(1, Ordering::Relaxed);
        } else {
            self.core_last_app[leader].store(app_id, Ordering::Relaxed);
            self.core_streak[leader].store(1, Ordering::Relaxed);
        }
        self.policy.on_complete(info.partition, info.exec, info.now);
        // Critical-path hand-off: a task on the path marks the one child
        // whose criticality is exactly one less (§2: critical tasks are
        // the tasks *of the critical path*; the diff-by-1 check alone
        // would flood layered DAGs where every edge decrements
        // criticality).
        if self.on_cp[info.task].load(Ordering::Acquire) {
            if let Some(c) = node.cp_child {
                self.on_cp[c].store(true, Ordering::Release);
            }
        }
        for &child in &node.succs {
            if self.pending[child].fetch_sub(1, Ordering::AcqRel) == 1 {
                let crit = self.on_cp[child].load(Ordering::Acquire);
                self.critical[child].store(crit, Ordering::Relaxed);
                wake(child);
            }
        }
        let done = self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.dag.len();
        Some(CommitOutcome { record, done })
    }
}

/// A workload stream's admission schedule, consumed identically by both
/// substrates: `(arrival, roots)` batches sorted by arrival, distributed
/// round-robin over the per-core lanes (§3.3's default root distribution,
/// restarting at lane 0 for every batch).
///
/// The cursor is atomic so the source can be shared by reference (the real
/// engine's bootstrap admits on the main thread, then hands the source to
/// the submitter thread), **not** to support concurrent admitters: at most
/// one thread may admit at a time.
pub struct AdmissionSource<'a> {
    batches: &'a [(f64, Vec<TaskId>)],
    next: AtomicUsize,
}

impl<'a> AdmissionSource<'a> {
    /// Validate the schedule against the DAG (see
    /// [`TaoDag::validate_admissions`]) and wrap it.
    pub fn new(
        dag: &TaoDag,
        app_of: &[usize],
        batches: &'a [(f64, Vec<TaskId>)],
    ) -> AdmissionSource<'a> {
        dag.validate_admissions(app_of, batches);
        AdmissionSource { batches, next: AtomicUsize::new(0) }
    }

    /// Arrival time of the next unadmitted batch, if any.
    pub fn next_arrival(&self) -> Option<f64> {
        self.batches.get(self.next.load(Ordering::Acquire)).map(|b| b.0)
    }

    /// Whether every batch has been admitted.
    pub fn is_exhausted(&self) -> bool {
        self.next.load(Ordering::Acquire) >= self.batches.len()
    }

    /// Admit every batch whose arrival is `<= now`, distributing each
    /// batch's roots round-robin over `n_lanes` via `push(lane, root)`.
    /// Returns the number of roots admitted (0 when nothing was due).
    pub fn admit_due(
        &self,
        now: f64,
        n_lanes: usize,
        mut push: impl FnMut(usize, TaskId),
    ) -> usize {
        let mut admitted = 0usize;
        loop {
            let i = self.next.load(Ordering::Acquire);
            let Some((arrival, roots)) = self.batches.get(i) else { break };
            if *arrival > now {
                break;
            }
            for (k, &root) in roots.iter().enumerate() {
                push(k % n_lanes, root);
                admitted += 1;
            }
            self.next.store(i + 1, Ordering::Release);
        }
        admitted
    }
}

/// One application offered to the serving admission path.
#[derive(Debug, Clone)]
pub struct ServingApp {
    pub app_id: usize,
    /// Scheduled offer time (seconds; virtual in sim, wall in real mode).
    pub arrival: f64,
    pub qos: QosClass,
    /// The app's root tasks (pushed on admission).
    pub roots: Vec<TaskId>,
    /// Total task count (cancelled wholesale when the app is shed).
    pub n_tasks: usize,
}

/// Per-class admission accounting, indexed by [`QosClass::index`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServingCounters {
    /// Apps admitted (pushed into the lanes), per class.
    pub admitted: [usize; 3],
    /// Delay *events* per class (one app re-offered twice counts twice).
    pub delays: [usize; 3],
    /// Apps shed (refused outright, tasks cancelled), per class.
    pub sheds: [usize; 3],
}

/// The serving-mode admission path: [`AdmissionSource`]'s open-loop
/// schedule plus **backpressure**, consumed identically by both engines.
///
/// Admission is open-loop — apps are offered at their scheduled arrival
/// regardless of backlog — but each offer consults the target lanes'
/// depth. When any target lane sits at or above `max_lane_depth` the
/// offer is *pressured*, and the outcome is decided strictly by QoS class,
/// highest priority first (the ordering the soak tests pin):
///
/// - [`QosClass::Latency`] — admitted anyway (the SLO class is never the
///   one to pay for backlog);
/// - [`QosClass::Batch`] — **delayed**: re-offered `delay_step` seconds
///   later (repeatedly, if pressure persists);
/// - [`QosClass::BestEffort`] — **shed**: refused outright; the caller's
///   `shed` hook must cancel the app's tasks in the [`SchedCore`]
///   (they were never pushed) so the run still terminates.
///
/// Methods take `&mut self`: a single admitter owns the source (the sim
/// loop, or the real engine's submitter thread).
pub struct ServingSource {
    apps: Vec<ServingApp>,
    /// `(offer time, app index)`, sorted ascending by offer time.
    queue: VecDeque<(f64, usize)>,
    counters: ServingCounters,
    max_lane_depth: usize,
    delay_step: f64,
    draining: bool,
}

impl ServingSource {
    /// Wrap an admission schedule. `max_lane_depth` bounds per-lane inbox
    /// depth (the backpressure threshold); `delay_step` is the re-offer
    /// interval for delayed batch apps.
    pub fn new(apps: Vec<ServingApp>, max_lane_depth: usize, delay_step: f64) -> ServingSource {
        assert!(max_lane_depth > 0, "a zero-depth lane admits nothing");
        assert!(delay_step > 0.0, "delayed apps must be re-offered strictly later");
        let mut order: Vec<usize> = (0..apps.len()).collect();
        order.sort_by(|&a, &b| apps[a].arrival.total_cmp(&apps[b].arrival));
        let queue = order.into_iter().map(|i| (apps[i].arrival, i)).collect();
        ServingSource {
            apps,
            queue,
            counters: ServingCounters::default(),
            max_lane_depth,
            delay_step,
            draining: false,
        }
    }

    /// Offer time of the next pending app, if any.
    pub fn next_offer(&self) -> Option<f64> {
        self.queue.front().map(|&(t, _)| t)
    }

    /// Whether every app has been admitted or shed.
    pub fn is_exhausted(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn counters(&self) -> ServingCounters {
        self.counters
    }

    /// Enter quiesce: backpressure is ignored from here on, so every
    /// still-pending (including previously delayed) app admits at its
    /// offer time and the run drains cleanly.
    pub fn begin_drain(&mut self) {
        self.draining = true;
    }

    /// Offer every app due by `now`. Roots of admitted apps are
    /// distributed round-robin from lane 0 via `push(lane, root)`
    /// ([`AdmissionSource`]'s rule); `lane_depth(lane)` supplies the
    /// backpressure reading; `shed` is invoked for refused apps. Returns
    /// the number of roots pushed.
    pub fn admit_due(
        &mut self,
        now: f64,
        n_lanes: usize,
        lane_depth: impl Fn(usize) -> usize,
        mut push: impl FnMut(usize, TaskId),
        mut shed: impl FnMut(&ServingApp),
    ) -> usize {
        let mut pushed = 0usize;
        while let Some(&(offer, idx)) = self.queue.front() {
            if offer > now {
                break;
            }
            self.queue.pop_front();
            let app = &self.apps[idx];
            let pressured = !self.draining && {
                let targets = app.roots.len().min(n_lanes).max(1);
                (0..targets).any(|k| lane_depth(k) >= self.max_lane_depth)
            };
            if pressured {
                match app.qos {
                    QosClass::Latency => {} // falls through to admission
                    QosClass::Batch => {
                        self.counters.delays[app.qos.index()] += 1;
                        let retry = now + self.delay_step;
                        let pos = self.queue.partition_point(|&(t, _)| t <= retry);
                        self.queue.insert(pos, (retry, idx));
                        continue;
                    }
                    QosClass::BestEffort => {
                        self.counters.sheds[app.qos.index()] += 1;
                        shed(app);
                        continue;
                    }
                }
            }
            self.counters.admitted[app.qos.index()] += 1;
            for (k, &root) in app.roots.iter().enumerate() {
                push(k % n_lanes, root);
                pushed += 1;
            }
        }
        pushed
    }
}

/// Serving-mode knobs shared by both engines. Times are in the backend's
/// clock (virtual seconds in the sim, wall seconds on real threads), so
/// callers scale them to the workload.
#[derive(Debug, Clone)]
pub struct ServingOpts {
    /// Backpressure threshold: an offer is pressured when any target lane
    /// already holds this many undrained roots.
    pub max_lane_depth: usize,
    /// Re-offer interval for delayed [`QosClass::Batch`] apps.
    pub delay_step: f64,
    /// Stop serving at this time: backpressure is switched off
    /// ([`ServingSource::begin_drain`]) so the backlog quiesces cleanly.
    /// The default never drains — harnesses set it to the window horizon.
    pub drain_after: f64,
    /// Period of the fairness feedback loop
    /// ([`super::scheduler::Policy::on_fairness`]).
    pub fairness_period: f64,
    /// Minimum uninterrupted same-app commit streak for a core to count
    /// as monopolised ([`SchedCore::monopolists`]).
    pub min_streak: usize,
}

impl Default for ServingOpts {
    fn default() -> Self {
        ServingOpts {
            max_lane_depth: 64,
            delay_step: 0.002,
            drain_after: f64::INFINITY,
            fairness_period: 0.005,
            min_streak: 8,
        }
    }
}

/// Result of one serving-mode run, either backend: the ordinary run result
/// plus the admission accounting the serving harness reports.
#[derive(Debug)]
pub struct ServingRun {
    pub result: RunResult,
    /// Per-class admitted / delayed / shed counts.
    pub counters: ServingCounters,
    /// `app_id`s refused by backpressure (their tasks never ran; their
    /// trace records do not exist).
    pub shed_apps: Vec<usize>,
    /// Largest per-lane admission backlog observed: inbox high-water on
    /// the real backend, pending-lane high-water in the sim.
    pub lane_high_water: usize,
    /// Retired-but-unreclaimed WSQ buffers left at the end (real backend;
    /// 0 in the sim). Bounded, or the never-drains path leaks.
    pub wsq_retired: usize,
    /// Jain fairness samples `(t, index)` taken by the feedback loop.
    pub fairness: Vec<(f64, f64)>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dag::paper_figure1_dag;
    use crate::coordinator::scheduler::{HomogeneousWs, PerformanceBased};

    fn topo4() -> Topology {
        Topology::homogeneous(4)
    }

    #[test]
    fn place_builds_ctx_and_validates() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        let p = core.place(2, 0, 0.0);
        assert_eq!(p.partition, Partition { leader: 2, width: 1 });
        assert!(!p.critical, "roots are non-critical by definition");
    }

    #[test]
    fn commit_releases_children_and_derives_criticality() {
        // Figure 1: A(0) is the CP root; committing A must wake C(2) as
        // critical and E(3) as non-critical.
        let (dag, [a, _b, c, e, ..]) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &PerformanceBased, &ptt);
        let place = core.place(0, a, 0.0);
        let info = CommitInfo {
            task: a,
            partition: place.partition,
            critical: place.critical,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        let mut woken = Vec::new();
        let out = core.commit(&info, |child| woken.push(child)).expect("first commit");
        assert_eq!(woken, vec![c, e]);
        assert!(core.is_critical(c), "C continues the critical path");
        assert!(!core.is_critical(e), "E is off the path");
        assert!(!out.done);
        assert_eq!(out.record.task, a);
        assert_eq!(out.record.app_id, 0);
        assert!(!out.record.critical);
        assert_eq!(core.completed(), 1);
    }

    #[test]
    fn commit_reports_done_exactly_on_last_task() {
        let mut d = TaoDag::new();
        let x = d.add_task(crate::platform::KernelClass::MatMul, 0, 1.0);
        let y = d.add_task(crate::platform::KernelClass::MatMul, 0, 1.0);
        d.add_edge(x, y);
        d.finalize().unwrap();
        let topo = topo4();
        let ptt = Ptt::new(d.n_types(), &topo);
        let core = SchedCore::new(&d, &[], &topo, &HomogeneousWs, &ptt);
        let mk = |task| CommitInfo {
            task,
            partition: Partition { leader: 0, width: 1 },
            critical: false,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        assert!(!core.commit(&mk(x), |_| {}).expect("first commit").done);
        assert!(core.commit(&mk(y), |_| {}).expect("first commit").done);
        assert!(core.is_done());
    }

    #[test]
    fn duplicate_commit_is_a_counted_noop() {
        // The exactly-once latch: re-committing a task (reclaimed work
        // whose first execution landed) must not release children again,
        // must not advance the completion counter, and must be counted.
        let (dag, [a, ..]) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &PerformanceBased, &ptt);
        let place = core.place(0, a, 0.0);
        let info = CommitInfo {
            task: a,
            partition: place.partition,
            critical: place.critical,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        let mut woken = Vec::new();
        assert!(core.commit(&info, |c| woken.push(c)).is_some());
        assert!(core.already_committed(a));
        let first_wakes = woken.len();
        let completed = core.completed();
        assert!(core.commit(&info, |c| woken.push(c)).is_none(), "duplicate must refuse");
        assert_eq!(woken.len(), first_wakes, "no child released twice");
        assert_eq!(core.completed(), completed, "completion count unchanged");
        assert_eq!(core.n_duplicates(), 1);
    }

    #[test]
    fn dead_core_mask_remaps_placements_to_live_partitions() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        // HomogeneousWs places width-1 on the acquiring core; kill core 2
        // and place "from" it (a thief that stole core 2's work after the
        // failure would do exactly this).
        core.set_core_dead(2, true);
        assert!(core.is_core_dead(2));
        let p = core.place(2, 0, 0.0);
        assert!(
            !p.partition.cores().any(|c| core.is_core_dead(c)),
            "placement must avoid the dead core: {:?}",
            p.partition
        );
        // Recovery restores the core as a valid target.
        core.set_core_dead(2, false);
        assert_eq!(core.first_live_core(), Some(0));
        let p = core.place(2, 0, 0.0);
        assert_eq!(p.partition, Partition { leader: 2, width: 1 });
    }

    #[test]
    fn failed_task_accounting() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let core = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        assert_eq!(core.n_failed(), 0);
        core.note_failed(0);
        assert_eq!(core.n_failed(), 1);
    }

    #[test]
    fn record_leader_share_is_gated_on_policy() {
        let (dag, _) = paper_figure1_dag();
        let topo = topo4();
        let ptt = Ptt::new(dag.n_types(), &topo);
        let blind = SchedCore::new(&dag, &[], &topo, &HomogeneousWs, &ptt);
        blind.record_leader_share(0, Partition { leader: 1, width: 1 }, 0.5);
        assert_eq!(ptt.read(dag.nodes[0].type_id, 1, 1), 0.0, "PTT-unaware policy: no update");
        let aware = SchedCore::new(&dag, &[], &topo, &PerformanceBased, &ptt);
        aware.record_leader_share(0, Partition { leader: 1, width: 1 }, 0.5);
        assert!(ptt.read(dag.nodes[0].type_id, 1, 1) > 0.0);
    }

    #[test]
    fn admission_source_distributes_round_robin_per_batch() {
        let mut d = TaoDag::new();
        for _ in 0..5 {
            d.add_task(crate::platform::KernelClass::Sort, 0, 1.0);
        }
        d.finalize().unwrap();
        let batches = vec![(0.0, vec![0usize, 1, 2]), (0.5, vec![3, 4])];
        let src = AdmissionSource::new(&d, &[], &batches);
        assert_eq!(src.next_arrival(), Some(0.0));
        let mut got = Vec::new();
        assert_eq!(src.admit_due(0.0, 2, |lane, root| got.push((lane, root))), 3);
        assert_eq!(got, vec![(0, 0), (1, 1), (0, 2)]);
        assert_eq!(src.next_arrival(), Some(0.5));
        assert_eq!(src.admit_due(0.4, 2, |_, _| panic!("nothing due")), 0);
        // Each batch restarts at lane 0 — the historical rule both
        // engines implemented independently.
        got.clear();
        assert_eq!(src.admit_due(0.5, 2, |lane, root| got.push((lane, root))), 2);
        assert_eq!(got, vec![(0, 3), (1, 4)]);
        assert!(src.is_exhausted());
        assert_eq!(src.next_arrival(), None);
    }

    #[test]
    fn admission_source_catches_up_over_multiple_due_batches() {
        let mut d = TaoDag::new();
        for _ in 0..4 {
            d.add_task(crate::platform::KernelClass::Copy, 0, 1.0);
        }
        d.finalize().unwrap();
        let batches = vec![(0.0, vec![0usize, 1]), (0.1, vec![2]), (0.2, vec![3])];
        let src = AdmissionSource::new(&d, &[], &batches);
        let mut got = Vec::new();
        // A late sweep admits everything due, batch by batch, in order.
        assert_eq!(src.admit_due(0.15, 4, |lane, root| got.push((lane, root))), 3);
        assert_eq!(got, vec![(0, 0), (1, 1), (0, 2)]);
        assert!(!src.is_exhausted());
    }

    #[test]
    fn commit_tracks_app_progress_and_core_streaks() {
        let mut d = TaoDag::new();
        for _ in 0..4 {
            d.add_task(crate::platform::KernelClass::Copy, 0, 1.0);
        }
        d.finalize().unwrap();
        let app_of = vec![0usize, 0, 1, 1];
        let topo = topo4();
        let ptt = Ptt::new(d.n_types(), &topo);
        let core = SchedCore::new(&d, &app_of, &topo, &HomogeneousWs, &ptt)
            .with_app_qos(vec![QosClass::Latency, QosClass::BestEffort]);
        assert_eq!(core.n_apps(), 2);
        assert_eq!(core.qos_of_app(0), QosClass::Latency);
        assert_eq!(core.qos_of_app(1), QosClass::BestEffort);
        let mk = |task| CommitInfo {
            task,
            partition: Partition { leader: 2, width: 1 },
            critical: false,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        // App 0 commits twice on core 2: streak of 2, monopolist at
        // min_streak 2 but not 3.
        core.commit(&mk(0), |_| {});
        core.commit(&mk(1), |_| {});
        assert_eq!(core.app_done(0), 2);
        assert_eq!(core.app_done(1), 0);
        assert_eq!(core.monopolists(2)[2], Some(0));
        assert_eq!(core.monopolists(3)[2], None);
        assert_eq!(core.monopolists(1)[0], None, "idle core has no monopolist");
        // App 1 takes over core 2: the streak resets.
        core.commit(&mk(2), |_| {});
        assert_eq!(core.monopolists(2)[2], None);
        assert_eq!(core.monopolists(1)[2], Some(1));
    }

    #[test]
    fn cancel_tasks_completes_the_run_like_commits_do() {
        let mut d = TaoDag::new();
        for _ in 0..3 {
            d.add_task(crate::platform::KernelClass::Sort, 0, 1.0);
        }
        d.finalize().unwrap();
        let topo = topo4();
        let ptt = Ptt::new(d.n_types(), &topo);
        let core = SchedCore::new(&d, &[], &topo, &HomogeneousWs, &ptt);
        assert!(!core.cancel_tasks(1), "2 of 3 still outstanding");
        let info = CommitInfo {
            task: 0,
            partition: Partition { leader: 0, width: 1 },
            critical: false,
            t_start: 0.0,
            t_end: 1.0,
            exec: 1.0,
            now: 1.0,
        };
        assert!(!core.commit(&info, |_| {}).expect("first commit").done);
        assert!(core.cancel_tasks(1), "final cancellation reports done");
        assert!(core.is_done());
    }

    fn serving_app(app_id: usize, arrival: f64, qos: QosClass, root: TaskId) -> ServingApp {
        ServingApp { app_id, arrival, qos, roots: vec![root], n_tasks: 2 }
    }

    fn serving_apps() -> Vec<ServingApp> {
        vec![
            serving_app(0, 0.0, QosClass::Latency, 0),
            serving_app(1, 0.1, QosClass::Batch, 2),
            serving_app(2, 0.2, QosClass::BestEffort, 4),
        ]
    }

    #[test]
    fn serving_source_admits_everything_without_pressure() {
        let mut src = ServingSource::new(serving_apps(), 4, 0.05);
        let mut pushed = Vec::new();
        let n = src.admit_due(1.0, 2, |_| 0, |lane, root| pushed.push((lane, root)), |_| {
            panic!("nothing should shed")
        });
        assert_eq!(n, 3);
        assert_eq!(pushed, vec![(0, 0), (0, 2), (0, 4)]);
        assert!(src.is_exhausted());
        let c = src.counters();
        assert_eq!(c.admitted, [1, 1, 1]);
        assert_eq!(c.delays, [0, 0, 0]);
        assert_eq!(c.sheds, [0, 0, 0]);
    }

    #[test]
    fn serving_pressure_hits_lower_qos_classes_first() {
        // Full lanes: latency admits anyway, batch is delayed, besteffort
        // is shed — the class ordering the acceptance criteria pin.
        let mut src = ServingSource::new(serving_apps(), 2, 0.05);
        let mut pushed = Vec::new();
        let mut shed_apps = Vec::new();
        let n = src.admit_due(
            0.3,
            2,
            |_| 99,
            |lane, root| pushed.push((lane, root)),
            |app: &ServingApp| shed_apps.push(app.app_id),
        );
        assert_eq!(n, 1, "only the latency app got through");
        assert_eq!(pushed, vec![(0, 0)]);
        assert_eq!(shed_apps, vec![2]);
        let c = src.counters();
        assert_eq!(c.admitted, [1, 0, 0]);
        assert_eq!(c.delays, [0, 1, 0], "batch delayed, never latency");
        assert_eq!(c.sheds, [0, 0, 1], "besteffort shed, nothing above it");
        // The delayed batch app is re-offered later and admits once the
        // pressure clears.
        assert!(!src.is_exhausted());
        assert_eq!(src.next_offer(), Some(0.35));
        let n = src.admit_due(0.4, 2, |_| 0, |lane, root| pushed.push((lane, root)), |_| {
            panic!("no shed")
        });
        assert_eq!(n, 1);
        assert_eq!(src.counters().admitted, [1, 1, 0]);
        assert!(src.is_exhausted());
    }

    #[test]
    fn serving_drain_ignores_pressure_for_clean_quiesce() {
        let mut src = ServingSource::new(serving_apps(), 2, 0.05);
        src.begin_drain();
        let mut pushed = Vec::new();
        let n = src.admit_due(
            f64::INFINITY,
            2,
            |_| 99,
            |lane, root| pushed.push((lane, root)),
            |_| panic!("drain never sheds"),
        );
        assert_eq!(n, 3);
        assert!(src.is_exhausted());
        assert_eq!(src.counters().admitted, [1, 1, 1]);
    }

    #[test]
    fn serving_batch_delay_repeats_under_sustained_pressure() {
        let apps = vec![ServingApp {
            app_id: 0,
            arrival: 0.0,
            qos: QosClass::Batch,
            roots: vec![0],
            n_tasks: 1,
        }];
        let mut src = ServingSource::new(apps, 1, 0.1);
        for i in 1..=3 {
            let t = 0.1 * i as f64;
            assert_eq!(src.admit_due(t, 1, |_| 5, |_, _| {}, |_| panic!("batch never sheds")), 0);
            assert_eq!(src.counters().delays[QosClass::Batch.index()], i);
        }
        // Pressure clears: the app finally admits; total delays preserved.
        assert_eq!(src.admit_due(1.0, 1, |_| 0, |_, _| {}, |_| {}), 1);
        let c = src.counters();
        assert_eq!(c.admitted[QosClass::Batch.index()], 1);
        assert_eq!(c.delays[QosClass::Batch.index()], 3);
        assert!(src.is_exhausted());
    }
}
