//! The Performance Trace Table (§3.2) — the paper's core data structure.
//!
//! One table per TAO type; each table has one *row per core* and one column
//! per resource width. Entry `(c, w)` holds a weighted moving average of the
//! execution time observed when a TAO of this type ran on the partition
//! *led by* core `c` at width `w`:
//!
//! ```text
//! updated = (4 · old + new) / 5        // 80% history, 20% new sample
//! ```
//!
//! Entries start at **0**, which models "zero execution time": because the
//! schedulers minimise `time × width`, untrained entries win every search
//! and the configuration space is explored automatically ("this ensures
//! that all configuration pairs will eventually be visited and trained").
//!
//! Implementation notes mirrored from the paper:
//! - only the **leader core** of a partition writes its entry (fewer cache
//!   migrations, no write races);
//! - each core's row is cache-line padded so concurrent leaders never
//!   false-share;
//! - reads are racy by design (schedulers tolerate slightly stale values).
//!   Values are stored as bit-cast `f64` in `AtomicU64`s, so every read and
//!   write is individually atomic — stale is possible, torn is not.

use crate::platform::{CoreId, Partition, Topology};
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// History weight: `(WEIGHT·old + new) / (WEIGHT + 1)`.
pub const HISTORY_WEIGHT: f64 = 4.0;

/// One core's row: per-width moving averages, cache-line padded.
struct Row {
    /// Indexed by width *index* (position in `Ptt::widths`).
    cells: CachePadded<Vec<AtomicU64>>,
}

/// The PTT for a set of TAO types on a fixed topology.
pub struct Ptt {
    /// Sorted valid widths (union over clusters); the column axis.
    widths: Vec<usize>,
    n_cores: usize,
    n_types: usize,
    /// `rows[type * n_cores + core]`.
    rows: Vec<Row>,
    /// Tunable history weight (paper default 4.0 = 4:1). Stored bit-cast so
    /// the table stays `Sync` without locks.
    weight: AtomicU64,
}

impl Ptt {
    pub fn new(n_types: usize, topo: &Topology) -> Ptt {
        let widths = topo.all_widths();
        let n_cores = topo.n_cores();
        let rows = (0..n_types.max(1) * n_cores)
            .map(|_| Row {
                cells: CachePadded::new(
                    (0..widths.len()).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
                ),
            })
            .collect();
        Ptt {
            widths,
            n_cores,
            n_types: n_types.max(1),
            rows,
            weight: AtomicU64::new(HISTORY_WEIGHT.to_bits()),
        }
    }

    /// Override the history weight (ablation `ablation_ptt`).
    pub fn set_history_weight(&self, w: f64) {
        assert!(w >= 0.0);
        self.weight.store(w.to_bits(), Ordering::Relaxed);
    }

    pub fn history_weight(&self) -> f64 {
        f64::from_bits(self.weight.load(Ordering::Relaxed))
    }

    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn n_types(&self) -> usize {
        self.n_types
    }

    fn width_index(&self, width: usize) -> Option<usize> {
        self.widths.iter().position(|&w| w == width)
    }

    fn cell(&self, type_id: usize, core: CoreId, width: usize) -> &AtomicU64 {
        let wi = self
            .width_index(width)
            .unwrap_or_else(|| panic!("width {width} not in PTT axis {:?}", self.widths));
        assert!(type_id < self.n_types, "type {type_id} out of range {}", self.n_types);
        assert!(core < self.n_cores, "core {core} out of range {}", self.n_cores);
        &self.rows[type_id * self.n_cores + core].cells[wi]
    }

    /// Read the moving average for `(type, leader core, width)`; 0 = untrained.
    pub fn read(&self, type_id: usize, core: CoreId, width: usize) -> f64 {
        f64::from_bits(self.cell(type_id, core, width).load(Ordering::Relaxed))
    }

    /// Leader-side update with an observed execution time (seconds).
    ///
    /// First sample replaces the 0 initialiser outright (a 4:1 blend with a
    /// fictitious zero would underestimate fivefold and distort the first
    /// few searches).
    pub fn update(&self, type_id: usize, leader: CoreId, width: usize, exec_time: f64) {
        debug_assert!(exec_time >= 0.0 && exec_time.is_finite());
        let cell = self.cell(type_id, leader, width);
        let old = f64::from_bits(cell.load(Ordering::Relaxed));
        let w = self.history_weight();
        let new = if old == 0.0 { exec_time } else { (w * old + exec_time) / (w + 1.0) };
        cell.store(new.to_bits(), Ordering::Relaxed);
    }

    /// **Global search** (critical tasks, §3.3): over every valid partition
    /// `(leader, width)` of the machine, minimise `time × width` — the
    /// system's resource occupation. Untrained entries (0) naturally win,
    /// forcing exploration. Deterministic tie-break: first in
    /// `Topology::all_partitions` order.
    pub fn best_global(&self, type_id: usize, topo: &Topology) -> (Partition, f64) {
        let mut best: Option<(Partition, f64)> = None;
        for p in topo.all_partitions() {
            let t = self.read(type_id, p.leader, p.width);
            let cost = t * p.width as f64;
            match best {
                Some((_, c)) if c <= cost => {}
                _ => best = Some((p, cost)),
            }
        }
        best.expect("topology has at least one partition")
    }

    /// **Local width search** (non-critical tasks, §3.3): the task stays
    /// near `core`; only the width of the partition *containing* `core` is
    /// chosen, reading the leader's entries. Minimises `time × width`.
    pub fn best_width_for(&self, type_id: usize, core: CoreId, topo: &Topology) -> (Partition, f64) {
        let cluster = topo.cluster_of(core);
        let mut best: Option<(Partition, f64)> = None;
        for w in cluster.valid_widths() {
            let p = topo
                .enclosing_partition(core, w)
                .expect("cluster width must yield an enclosing partition");
            let t = self.read(type_id, p.leader, p.width);
            let cost = t * w as f64;
            match best {
                Some((_, c)) if c <= cost => {}
                _ => best = Some((p, cost)),
            }
        }
        best.expect("cluster has at least width 1")
    }

    /// Lowest observed width-1 time per cluster (used by the CATS-like
    /// baseline to rank clusters as "big" vs "LITTLE").
    pub fn cluster_width1_estimate(&self, type_id: usize, topo: &Topology, cluster: usize) -> f64 {
        let cl = &topo.clusters[cluster];
        let vals: Vec<f64> =
            cl.cores().map(|c| self.read(type_id, c, 1)).filter(|&v| v > 0.0).collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Fraction of entries still untrained (diagnostics / convergence bench).
    pub fn untrained_fraction(&self, topo: &Topology) -> f64 {
        let mut total = 0usize;
        let mut zero = 0usize;
        for ty in 0..self.n_types {
            for p in topo.all_partitions() {
                total += 1;
                if self.read(ty, p.leader, p.width) == 0.0 {
                    zero += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            zero as f64 / total as f64
        }
    }

    /// Dump one type's table as `(core, width, value)` triples (traces/CLI).
    pub fn dump(&self, type_id: usize, topo: &Topology) -> Vec<(CoreId, usize, f64)> {
        let mut out = Vec::new();
        for p in topo.all_partitions() {
            out.push((p.leader, p.width, self.read(type_id, p.leader, p.width)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Topology;

    fn tx2() -> Topology {
        Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)])
    }

    #[test]
    fn starts_untrained() {
        let topo = tx2();
        let ptt = Ptt::new(2, &topo);
        assert_eq!(ptt.read(0, 0, 1), 0.0);
        assert_eq!(ptt.untrained_fraction(&topo), 1.0);
    }

    #[test]
    fn first_update_replaces_zero() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 10.0);
        assert_eq!(ptt.read(0, 0, 1), 10.0);
    }

    #[test]
    fn weighted_update_is_4_to_1() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 10.0);
        ptt.update(0, 0, 1, 5.0);
        // (4*10 + 5) / 5 = 9
        assert!((ptt.read(0, 0, 1) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn update_converges_to_steady_input() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 2, 2, 100.0);
        for _ in 0..100 {
            ptt.update(0, 2, 2, 3.0);
        }
        // Error decays ×0.8 per sample: 97 × 0.8^100 ≈ 2e-8.
        assert!((ptt.read(0, 2, 2) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn global_search_explores_zeros_first() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 0.5);
        let (p, cost) = ptt.best_global(0, &topo);
        // Some untrained entry must win over the trained 0.5.
        assert_eq!(cost, 0.0);
        assert_ne!((p.leader, p.width), (0, 1));
    }

    #[test]
    fn global_search_minimises_time_times_width() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Train everything to something large...
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 10.0);
        }
        // ...then make (2, 4) clearly best even after the ×4 width factor.
        for _ in 0..50 {
            ptt.update(0, 2, 4, 0.4);
        }
        let (p, _) = ptt.best_global(0, &topo);
        assert_eq!((p.leader, p.width), (2, 4));
    }

    #[test]
    fn local_search_restricted_to_enclosing_partitions() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Core 3 (a57, offset 1): candidates are (3,1), (2,2), (2,4).
        for _ in 0..50 {
            ptt.update(0, 2, 2, 0.01);
        }
        let (p, _) = ptt.best_width_for(0, 3, &topo);
        assert_eq!((p.leader, p.width), (2, 2));
        assert!(p.contains(3));
    }

    #[test]
    fn local_search_never_leaves_cluster() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Make a denver entry look amazing; core 3 must not pick it.
        for _ in 0..50 {
            ptt.update(0, 0, 1, 1e-6);
        }
        let (p, _) = ptt.best_width_for(0, 3, &topo);
        assert!(topo.cluster_of(p.leader).id == 1);
    }

    #[test]
    fn per_type_isolation() {
        let topo = tx2();
        let ptt = Ptt::new(2, &topo);
        ptt.update(0, 0, 1, 7.0);
        assert_eq!(ptt.read(1, 0, 1), 0.0);
        assert_eq!(ptt.read(0, 0, 1), 7.0);
    }

    #[test]
    fn history_weight_override() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.set_history_weight(1.0); // 1:1 averaging
        ptt.update(0, 0, 1, 10.0);
        ptt.update(0, 0, 1, 20.0);
        assert!((ptt.read(0, 0, 1) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_estimate_ignores_untrained() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 0.0);
        ptt.update(0, 0, 1, 2.0);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 2.0);
        ptt.update(0, 1, 1, 4.0);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 3.0);
    }

    #[test]
    #[should_panic]
    fn invalid_width_panics() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.read(0, 0, 3);
    }

    #[test]
    fn untrained_fraction_decreases() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        let before = ptt.untrained_fraction(&topo);
        ptt.update(0, 0, 1, 1.0);
        assert!(ptt.untrained_fraction(&topo) < before);
    }
}
