//! The Performance Trace Table (§3.2) — the paper's core data structure.
//!
//! One table per TAO type; each table has one *row per core* and one column
//! per resource width. Entry `(c, w)` holds a weighted moving average of the
//! execution time observed when a TAO of this type ran on the partition
//! *led by* core `c` at width `w`:
//!
//! ```text
//! updated = (4 · old + new) / 5        // 80% history, 20% new sample
//! ```
//!
//! Entries start at **0**, which models "zero execution time": because the
//! schedulers minimise `time × width`, untrained entries win every search
//! and the configuration space is explored automatically ("this ensures
//! that all configuration pairs will eventually be visited and trained").
//!
//! Implementation notes mirrored from the paper:
//! - only the **leader core** of a partition writes its entry (fewer cache
//!   migrations, no write races);
//! - each core's row is cache-line padded so concurrent leaders never
//!   false-share;
//! - reads are racy by design (schedulers tolerate slightly stale values).
//!   Values are stored as bit-cast `f64` in `AtomicU64`s, so every read and
//!   write is individually atomic — stale is possible, torn is not.
//!
//! ## PTT v2 — change detection and fast re-learning
//!
//! A single 4:1 moving average is equally sluggish whether the platform is
//! steady or mid-episode. To adapt to *dynamic* heterogeneity (DVFS,
//! background interferers — §5.3) each cell now keeps **two** estimates:
//!
//! - the **long-run average** (the paper's 4:1 blend, what [`Ptt::read`]
//!   and every search returns), and
//! - a **recent-window estimate** (a 1:1 blend, ≈ two-sample memory).
//!
//! The **change detector** compares the pair on every leader write: when
//! the recent/long-run ratio of the freshly updated cell exceeds
//! [`FLAG_THRESHOLD`] that *cell* turns diverged — its effective behaviour
//! has shifted faster than the long-run average can track — and it
//! reconverges only once the ratio falls below [`UNFLAG_THRESHOLD`]
//! (per-cell hysteresis: a dead band between the thresholds, and a
//! sibling cell's evidence can never clear a bit it did not set). A core
//! is **flagged** while any of its cells is diverged; while flagged, all
//! its cells blend at the low [`FAST_WEIGHT`] (fast re-learn). Policies
//! read the flags through [`Ptt::core_flagged`] / [`Ptt::core_flags`] as
//! "this core's observed behaviour just changed" — the `ptt-adaptive`
//! policy steers critical tasks away from flagged cores while the fast
//! re-learn pulls the long-run rows back to reality.
//!
//! The v2 state follows the same concurrency discipline as v1: recent
//! cells are bit-cast `f64` in `AtomicU64`s written only by the leader;
//! the diverged bits and the per-core diverged-cell count have a single
//! writer each (the core itself — only core `c` leads partitions whose
//! PTT rows are `c`). Detection reads the values the update just wrote,
//! draws no randomness, and is therefore exactly as deterministic as the
//! update sequence itself — the virtual-time engine stays bit-for-bit
//! reproducible.

use crate::platform::{CoreId, Partition, Topology};
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// History weight: `(WEIGHT·old + new) / (WEIGHT + 1)`.
pub const HISTORY_WEIGHT: f64 = 4.0;

/// Recent-window weight: a 1:1 blend (≈ two-sample memory) that tracks the
/// platform's *current* behaviour fast enough to expose episode edges.
pub const RECENT_WEIGHT: f64 = 1.0;

/// History weight applied to a **flagged** core's long-run cells: while the
/// change detector says the core's behaviour shifted, the long-run average
/// re-learns at this low weight instead of [`HISTORY_WEIGHT`].
pub const FAST_WEIGHT: f64 = 1.0;

/// Flag a core when `max(recent, long) / min(recent, long)` of the freshly
/// updated cell exceeds this. Calibrated against both regimes: for an
/// abrupt step of factor `k` the ratio peaks at
/// `(0.75k + 0.25) / (0.36k + 0.64)` (1:1 vs 4:1 blends, second sample) —
/// ≈ 1.33 for the §5.3 interference factor k ≈ 2.2, crossing 1.25 on the
/// first or second post-edge sample — while bounded ±5% timer jitter can
/// push the ratio to at most ≈ 1.09 in steady state, so the detector
/// cannot false-fire on noise.
pub const FLAG_THRESHOLD: f64 = 1.25;

/// Unflag once the ratio falls back below this. Strictly below
/// [`FLAG_THRESHOLD`] so the flag has a dead band instead of chattering,
/// and above the ≈ 1.05 steady-jitter ratio so reconvergence is reachable.
pub const UNFLAG_THRESHOLD: f64 = 1.10;

/// One core's row: per-width moving averages, cache-line padded.
struct Row {
    /// Long-run averages, indexed by width *index* (position in
    /// `Ptt::widths`).
    cells: CachePadded<Vec<AtomicU64>>,
    /// Recent-window estimates, same indexing and bit-cast discipline.
    recent: CachePadded<Vec<AtomicU64>>,
    /// Per-cell diverged bits (the change detector's hysteresis state).
    /// Per-cell, not per-core: one stale sibling cell producing a
    /// ratio-1.0 sample must not clear the core's flag while another cell
    /// is still mid-shift.
    diverged: CachePadded<Vec<AtomicBool>>,
}

/// The PTT for a set of TAO types on a fixed topology.
pub struct Ptt {
    /// Sorted valid widths (union over clusters); the column axis.
    widths: Vec<usize>,
    n_cores: usize,
    n_types: usize,
    /// `rows[type * n_cores + core]`.
    rows: Vec<Row>,
    /// Per-core count of currently diverged cells (single writer: the core
    /// itself — only core `c` leads partitions whose rows are `c`). A core
    /// is *flagged* while any of its cells is diverged.
    n_diverged: Vec<CachePadded<AtomicUsize>>,
    /// Per-core fail-stop mask: a dead core reads as infinite latency —
    /// never chosen by any avoiding search, remapped away by
    /// [`crate::coordinator::SchedCore::place`]'s final guard. Written by
    /// the substrate at failure/recovery boundaries (sim) or by the dying
    /// worker itself (real engine).
    dead: Vec<CachePadded<AtomicBool>>,
    /// Number of set bits in `dead` (cheap `any_core_dead` for the hot
    /// placement path; maintained by `swap`, so concurrent idempotent
    /// writes cannot drift the count).
    n_dead: AtomicUsize,
    /// Tunable history weight (paper default 4.0 = 4:1). Stored bit-cast so
    /// the table stays `Sync` without locks.
    weight: AtomicU64,
}

impl Ptt {
    pub fn new(n_types: usize, topo: &Topology) -> Ptt {
        let widths = topo.all_widths();
        let n_cores = topo.n_cores();
        let rows = (0..n_types.max(1) * n_cores)
            .map(|_| Row {
                cells: CachePadded::new(
                    (0..widths.len()).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
                ),
                recent: CachePadded::new(
                    (0..widths.len()).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
                ),
                diverged: CachePadded::new(
                    (0..widths.len()).map(|_| AtomicBool::new(false)).collect(),
                ),
            })
            .collect();
        Ptt {
            widths,
            n_cores,
            n_types: n_types.max(1),
            rows,
            n_diverged: (0..n_cores).map(|_| CachePadded::new(AtomicUsize::new(0))).collect(),
            dead: (0..n_cores).map(|_| CachePadded::new(AtomicBool::new(false))).collect(),
            n_dead: AtomicUsize::new(0),
            weight: AtomicU64::new(HISTORY_WEIGHT.to_bits()),
        }
    }

    /// Override the history weight (ablation `ablation_ptt`).
    pub fn set_history_weight(&self, w: f64) {
        assert!(w >= 0.0);
        self.weight.store(w.to_bits(), Ordering::Relaxed);
    }

    pub fn history_weight(&self) -> f64 {
        f64::from_bits(self.weight.load(Ordering::Relaxed))
    }

    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn n_types(&self) -> usize {
        self.n_types
    }

    fn width_index(&self, width: usize) -> Option<usize> {
        self.widths.iter().position(|&w| w == width)
    }

    fn row(&self, type_id: usize, core: CoreId) -> &Row {
        assert!(type_id < self.n_types, "type {type_id} out of range {}", self.n_types);
        assert!(core < self.n_cores, "core {core} out of range {}", self.n_cores);
        &self.rows[type_id * self.n_cores + core]
    }

    fn width_index_or_panic(&self, width: usize) -> usize {
        self.width_index(width)
            .unwrap_or_else(|| panic!("width {width} not in PTT axis {:?}", self.widths))
    }

    fn cell(&self, type_id: usize, core: CoreId, width: usize) -> &AtomicU64 {
        let wi = self.width_index_or_panic(width);
        &self.row(type_id, core).cells[wi]
    }

    /// Read the long-run moving average for `(type, leader core, width)`;
    /// 0 = untrained. This is what every search minimises over.
    pub fn read(&self, type_id: usize, core: CoreId, width: usize) -> f64 {
        f64::from_bits(self.cell(type_id, core, width).load(Ordering::Relaxed))
    }

    /// Read the recent-window estimate for `(type, leader core, width)`;
    /// 0 = untrained. Diverges from [`Ptt::read`] exactly when the core's
    /// effective behaviour is shifting (the change detector's input).
    pub fn read_recent(&self, type_id: usize, core: CoreId, width: usize) -> f64 {
        let wi = self.width_index_or_panic(width);
        f64::from_bits(self.row(type_id, core).recent[wi].load(Ordering::Relaxed))
    }

    /// Whether the change detector currently flags `core` ("this core's
    /// observed behaviour just shifted — estimates are re-learning"): true
    /// while *any* of the core's cells is diverged.
    pub fn core_flagged(&self, core: CoreId) -> bool {
        self.n_diverged[core].load(Ordering::Relaxed) > 0
    }

    /// Snapshot of every core's change-detector flag, indexed by core id.
    pub fn core_flags(&self) -> Vec<bool> {
        self.n_diverged.iter().map(|n| n.load(Ordering::Relaxed) > 0).collect()
    }

    /// Number of currently flagged cores (diagnostics / bench summaries).
    pub fn n_flagged(&self) -> usize {
        self.n_diverged.iter().filter(|n| n.load(Ordering::Relaxed) > 0).count()
    }

    /// Mark `core` fail-stopped (`true`) or recovered (`false`). A dead
    /// core behaves like infinite latency: the avoiding searches treat it
    /// like a flagged core and the scheduling core's placement guard
    /// remaps any partition that still touches it. Idempotent — `swap`
    /// keeps the count exact under repeated writes.
    pub fn set_core_dead(&self, core: CoreId, dead: bool) {
        let was = self.dead[core].swap(dead, Ordering::AcqRel);
        if was != dead {
            if dead {
                self.n_dead.fetch_add(1, Ordering::AcqRel);
            } else {
                self.n_dead.fetch_sub(1, Ordering::AcqRel);
            }
        }
    }

    /// Is `core` currently fail-stopped?
    pub fn core_dead(&self, core: CoreId) -> bool {
        self.dead[core].load(Ordering::Acquire)
    }

    /// Is any core currently fail-stopped? (One load — the fault-free hot
    /// path pays nothing beyond it.)
    pub fn any_core_dead(&self) -> bool {
        self.n_dead.load(Ordering::Acquire) > 0
    }

    /// Leader-side update with an observed execution time (seconds).
    ///
    /// First sample replaces the 0 initialiser outright (a blend with a
    /// fictitious zero would underestimate and distort the first few
    /// searches). Feeds **both** estimates — the long-run average at
    /// [`HISTORY_WEIGHT`] (or [`FAST_WEIGHT`] while the core is flagged)
    /// and the recent window at [`RECENT_WEIGHT`] — then runs the per-core
    /// change detector on the freshly updated pair (see the module docs).
    pub fn update(&self, type_id: usize, leader: CoreId, width: usize, exec_time: f64) {
        debug_assert!(exec_time >= 0.0 && exec_time.is_finite());
        let wi = self.width_index_or_panic(width);
        let row = self.row(type_id, leader);
        // Recent window first: the detector below compares the long-run
        // value against what the platform looks like *now*.
        let rcell = &row.recent[wi];
        let r_old = f64::from_bits(rcell.load(Ordering::Relaxed));
        let r_new = if r_old == 0.0 {
            exec_time
        } else {
            (RECENT_WEIGHT * r_old + exec_time) / (RECENT_WEIGHT + 1.0)
        };
        rcell.store(r_new.to_bits(), Ordering::Relaxed);
        // Long-run average, at the fast weight while the core is flagged.
        let cell = &row.cells[wi];
        let old = f64::from_bits(cell.load(Ordering::Relaxed));
        let w = if self.core_flagged(leader) {
            self.history_weight().min(FAST_WEIGHT)
        } else {
            self.history_weight()
        };
        let new = if old == 0.0 { exec_time } else { (w * old + exec_time) / (w + 1.0) };
        cell.store(new.to_bits(), Ordering::Relaxed);
        // Change detector with per-cell hysteresis: a cell turns diverged
        // above FLAG_THRESHOLD, reconverges below UNFLAG_THRESHOLD, holds
        // in the dead band; the core's flag is "any cell diverged". The
        // state must be per-cell: one cell's ratio-1.0 sample (a stale
        // sibling updating in lockstep at the fast weight, or an untrained
        // cell's first observation) says nothing about the cell that
        // actually diverged, so it may only clear *its own* bit. A cell's
        // first sample carries no divergence evidence at all (both
        // estimates are set to the sample) and is skipped outright.
        if r_old > 0.0 && old > 0.0 {
            let ratio = if r_new > new { r_new / new } else { new / r_new };
            let dcell = &row.diverged[wi];
            let was = dcell.load(Ordering::Relaxed);
            let is = if ratio > FLAG_THRESHOLD {
                true
            } else if ratio < UNFLAG_THRESHOLD {
                false
            } else {
                was
            };
            if is != was {
                // swap, not store: the counter must track *actual* bit
                // transitions. Under the single-writer contract this is
                // equivalent; under contract-violating concurrent updates
                // to one cell (the determinism suite's hammer test does
                // this deliberately) two racing writers would otherwise
                // both count the same transition and corrupt the counter
                // permanently — with swap the loser observes `prev == is`
                // and backs off, so the count stays the number of set bits.
                let prev = dcell.swap(is, Ordering::Relaxed);
                if prev != is {
                    if is {
                        self.n_diverged[leader].fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.n_diverged[leader].fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }

    /// The one `time × width` argmin every search is built on: minimise
    /// over the candidate partitions, first-wins tie-break (`c <= cost`
    /// keeps the earliest candidate), `None` for an empty candidate set.
    /// Candidate *order* is part of each search's contract — callers pass
    /// deterministic sequences.
    fn best_over(
        &self,
        type_id: usize,
        candidates: impl IntoIterator<Item = Partition>,
    ) -> Option<(Partition, f64)> {
        let mut best: Option<(Partition, f64)> = None;
        for p in candidates {
            let t = self.read(type_id, p.leader, p.width);
            let cost = t * p.width as f64;
            match best {
                Some((_, c)) if c <= cost => {}
                _ => best = Some((p, cost)),
            }
        }
        best
    }

    /// **Global search** (critical tasks, §3.3): over every valid partition
    /// `(leader, width)` of the machine, minimise `time × width` — the
    /// system's resource occupation. Untrained entries (0) naturally win,
    /// forcing exploration. Deterministic tie-break: first in
    /// `Topology::all_partitions` order.
    pub fn best_global(&self, type_id: usize, topo: &Topology) -> (Partition, f64) {
        self.best_global_capped(type_id, topo, usize::MAX)
    }

    /// [`Ptt::best_global`] restricted to partitions no wider than
    /// `max_width` — the moldability cap of the task being placed
    /// ([`crate::coordinator::dag::TaoNode::max_width`]). Width 1 always
    /// survives the cap, so the search stays total.
    pub fn best_global_capped(
        &self,
        type_id: usize,
        topo: &Topology,
        max_width: usize,
    ) -> (Partition, f64) {
        self.best_over(
            type_id,
            topo.all_partitions().into_iter().filter(|p| p.width <= max_width),
        )
        .expect("topology has at least one width-1 partition")
    }

    /// **Local width search** (non-critical tasks, §3.3): the task stays
    /// near `core`; only the width of the partition *containing* `core` is
    /// chosen, reading the leader's entries. Minimises `time × width`.
    pub fn best_width_for(&self, type_id: usize, core: CoreId, topo: &Topology) -> (Partition, f64) {
        self.best_width_for_capped(type_id, core, topo, usize::MAX)
    }

    /// [`Ptt::best_width_for`] restricted to enclosing partitions no wider
    /// than `max_width` (the task's moldability cap).
    pub fn best_width_for_capped(
        &self,
        type_id: usize,
        core: CoreId,
        topo: &Topology,
        max_width: usize,
    ) -> (Partition, f64) {
        let cluster = topo.cluster_of(core);
        self.best_over(
            type_id,
            cluster.valid_widths().into_iter().filter(|&w| w <= max_width).map(|w| {
                topo.enclosing_partition(core, w)
                    .expect("cluster width must yield an enclosing partition")
            }),
        )
        .expect("cluster has at least width 1")
    }

    /// **Filtered global search**: like [`Ptt::best_global`], but skipping
    /// every partition that contains a core for which `avoid` returns true.
    /// Returns `None` when every partition touches an avoided core (the
    /// caller falls back to the unfiltered search — a fully flagged machine
    /// has no safe harbour and the plain `time × width` argmin is the best
    /// remaining answer).
    pub fn best_global_avoiding(
        &self,
        type_id: usize,
        topo: &Topology,
        avoid: impl Fn(CoreId) -> bool,
    ) -> Option<(Partition, f64)> {
        self.best_global_capped_avoiding(type_id, topo, usize::MAX, avoid)
    }

    /// [`Ptt::best_global_avoiding`] with a moldability cap on the width.
    pub fn best_global_capped_avoiding(
        &self,
        type_id: usize,
        topo: &Topology,
        max_width: usize,
        avoid: impl Fn(CoreId) -> bool,
    ) -> Option<(Partition, f64)> {
        self.best_over(
            type_id,
            topo.all_partitions()
                .into_iter()
                .filter(|p| p.width <= max_width && !p.cores().any(&avoid)),
        )
    }

    /// **Widened local search**: every partition of the cluster containing
    /// `core` (any leader, any width) — not just the partitions *enclosing*
    /// `core` as in [`Ptt::best_width_for`]. Partitions containing a core
    /// for which `avoid` returns true are skipped; returns `None` if the
    /// whole cluster is avoided. The `ptt-adaptive` policy uses this to let
    /// a non-critical task escape its own interfered core without paying
    /// the full global search.
    pub fn best_in_cluster_avoiding(
        &self,
        type_id: usize,
        core: CoreId,
        topo: &Topology,
        avoid: impl Fn(CoreId) -> bool,
    ) -> Option<(Partition, f64)> {
        self.best_in_cluster_capped_avoiding(type_id, core, topo, usize::MAX, avoid)
    }

    /// [`Ptt::best_in_cluster_avoiding`] with a moldability cap on the
    /// width.
    pub fn best_in_cluster_capped_avoiding(
        &self,
        type_id: usize,
        core: CoreId,
        topo: &Topology,
        max_width: usize,
        avoid: impl Fn(CoreId) -> bool,
    ) -> Option<(Partition, f64)> {
        let cluster = topo.cluster_of(core).id;
        self.best_over(
            type_id,
            topo.all_partitions().into_iter().filter(|p| {
                p.width <= max_width
                    && topo.cluster_of(p.leader).id == cluster
                    && !p.cores().any(&avoid)
            }),
        )
    }

    /// Lowest observed width-1 time per cluster (used by the CATS-like
    /// baseline to rank clusters as "big" vs "LITTLE").
    pub fn cluster_width1_estimate(&self, type_id: usize, topo: &Topology, cluster: usize) -> f64 {
        let cl = &topo.clusters[cluster];
        let vals: Vec<f64> =
            cl.cores().map(|c| self.read(type_id, c, 1)).filter(|&v| v > 0.0).collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Fraction of entries still untrained (diagnostics / convergence bench).
    pub fn untrained_fraction(&self, topo: &Topology) -> f64 {
        let mut total = 0usize;
        let mut zero = 0usize;
        for ty in 0..self.n_types {
            for p in topo.all_partitions() {
                total += 1;
                if self.read(ty, p.leader, p.width) == 0.0 {
                    zero += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            zero as f64 / total as f64
        }
    }

    /// Dump one type's table as `(core, width, value)` triples (traces/CLI).
    pub fn dump(&self, type_id: usize, topo: &Topology) -> Vec<(CoreId, usize, f64)> {
        let mut out = Vec::new();
        for p in topo.all_partitions() {
            out.push((p.leader, p.width, self.read(type_id, p.leader, p.width)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Topology;

    fn tx2() -> Topology {
        Topology::from_clusters("tx2", &[(2, "denver2", 2 << 20), (4, "a57", 2 << 20)])
    }

    #[test]
    fn dead_mask_tracks_transitions_idempotently() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        assert!(!ptt.any_core_dead());
        ptt.set_core_dead(2, true);
        ptt.set_core_dead(2, true); // repeat must not double-count
        assert!(ptt.core_dead(2));
        assert!(!ptt.core_dead(0));
        assert!(ptt.any_core_dead());
        ptt.set_core_dead(2, false);
        assert!(!ptt.core_dead(2));
        assert!(!ptt.any_core_dead(), "count must return to zero after recovery");
    }

    #[test]
    fn starts_untrained() {
        let topo = tx2();
        let ptt = Ptt::new(2, &topo);
        assert_eq!(ptt.read(0, 0, 1), 0.0);
        assert_eq!(ptt.untrained_fraction(&topo), 1.0);
    }

    #[test]
    fn first_update_replaces_zero() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 10.0);
        assert_eq!(ptt.read(0, 0, 1), 10.0);
    }

    #[test]
    fn weighted_update_is_4_to_1() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 10.0);
        ptt.update(0, 0, 1, 5.0);
        // (4*10 + 5) / 5 = 9
        assert!((ptt.read(0, 0, 1) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn update_converges_to_steady_input() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 2, 2, 100.0);
        for _ in 0..100 {
            ptt.update(0, 2, 2, 3.0);
        }
        // Error decays ×0.8 per sample: 97 × 0.8^100 ≈ 2e-8.
        assert!((ptt.read(0, 2, 2) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn global_search_explores_zeros_first() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.update(0, 0, 1, 0.5);
        let (p, cost) = ptt.best_global(0, &topo);
        // Some untrained entry must win over the trained 0.5.
        assert_eq!(cost, 0.0);
        assert_ne!((p.leader, p.width), (0, 1));
    }

    #[test]
    fn global_search_minimises_time_times_width() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Train everything to something large...
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 10.0);
        }
        // ...then make (2, 4) clearly best even after the ×4 width factor.
        for _ in 0..50 {
            ptt.update(0, 2, 4, 0.4);
        }
        let (p, _) = ptt.best_global(0, &topo);
        assert_eq!((p.leader, p.width), (2, 4));
    }

    #[test]
    fn local_search_restricted_to_enclosing_partitions() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Core 3 (a57, offset 1): candidates are (3,1), (2,2), (2,4).
        for _ in 0..50 {
            ptt.update(0, 2, 2, 0.01);
        }
        let (p, _) = ptt.best_width_for(0, 3, &topo);
        assert_eq!((p.leader, p.width), (2, 2));
        assert!(p.contains(3));
    }

    #[test]
    fn local_search_never_leaves_cluster() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Make a denver entry look amazing; core 3 must not pick it.
        for _ in 0..50 {
            ptt.update(0, 0, 1, 1e-6);
        }
        let (p, _) = ptt.best_width_for(0, 3, &topo);
        assert!(topo.cluster_of(p.leader).id == 1);
    }

    #[test]
    fn per_type_isolation() {
        let topo = tx2();
        let ptt = Ptt::new(2, &topo);
        ptt.update(0, 0, 1, 7.0);
        assert_eq!(ptt.read(1, 0, 1), 0.0);
        assert_eq!(ptt.read(0, 0, 1), 7.0);
    }

    #[test]
    fn history_weight_override() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.set_history_weight(1.0); // 1:1 averaging
        ptt.update(0, 0, 1, 10.0);
        ptt.update(0, 0, 1, 20.0);
        assert!((ptt.read(0, 0, 1) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_estimate_ignores_untrained() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 0.0);
        ptt.update(0, 0, 1, 2.0);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 2.0);
        ptt.update(0, 1, 1, 4.0);
        assert_eq!(ptt.cluster_width1_estimate(0, &topo, 0), 3.0);
    }

    #[test]
    #[should_panic]
    fn invalid_width_panics() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        ptt.read(0, 0, 3);
    }

    #[test]
    fn untrained_fraction_decreases() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        let before = ptt.untrained_fraction(&topo);
        ptt.update(0, 0, 1, 1.0);
        assert!(ptt.untrained_fraction(&topo) < before);
    }

    // ----- PTT v2: recent window, change detection, fast re-learn ---------

    /// Train a cell to a steady value (enough samples that both estimates
    /// converge and the flag, if any, clears).
    fn steady(ptt: &Ptt, core: CoreId, v: f64) {
        for _ in 0..20 {
            ptt.update(0, core, 1, v);
        }
    }

    #[test]
    fn recent_window_tracks_faster_than_long_run() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        steady(&ptt, 0, 1.0);
        assert!((ptt.read_recent(0, 0, 1) - 1.0).abs() < 1e-9);
        // One shifted sample: recent moves halfway, long run lags.
        ptt.update(0, 0, 1, 3.0);
        let recent = ptt.read_recent(0, 0, 1);
        let long = ptt.read(0, 0, 1);
        assert!((recent - 2.0).abs() < 1e-9, "recent {recent}");
        assert!(recent > long, "recent {recent} must lead long {long}");
    }

    #[test]
    fn steady_state_never_flags() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // ±5% jitter around 1.0 — the sim's timer-noise envelope.
        for i in 0..200 {
            let v = 1.0 + 0.05 * if i % 2 == 0 { 1.0 } else { -1.0 };
            ptt.update(0, 1, 1, v);
            assert!(!ptt.core_flagged(1), "steady jitter flagged at sample {i}");
        }
        assert_eq!(ptt.n_flagged(), 0);
        assert_eq!(ptt.core_flags(), vec![false; 6]);
    }

    #[test]
    fn abrupt_shift_flags_then_reconverges_and_unflags() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        steady(&ptt, 2, 1.0);
        assert!(!ptt.core_flagged(2));
        // A 2.2x interference-style inflation: the detector must flag
        // within a few samples.
        let mut flagged_at = None;
        for i in 0..10 {
            ptt.update(0, 2, 1, 2.2);
            if ptt.core_flagged(2) {
                flagged_at = Some(i);
                break;
            }
        }
        assert!(flagged_at.is_some(), "2.2x shift never flagged core 2");
        // Keep feeding the new reality: fast re-learn reconverges the
        // long-run average and the flag clears again. (40 samples: the
        // residual decays at 0.8/sample once the flag drops back to 4:1.)
        for _ in 0..40 {
            ptt.update(0, 2, 1, 2.2);
        }
        assert!(!ptt.core_flagged(2), "flag must clear after reconvergence");
        assert!((ptt.read(0, 2, 1) - 2.2).abs() < 1e-3);
        assert!((ptt.read_recent(0, 2, 1) - 2.2).abs() < 1e-6);
    }

    #[test]
    fn flagged_core_relearns_faster_than_unflagged() {
        let topo = tx2();
        // Two identical cores, same steady history, same shifted input —
        // but core 0 is flagged first (via the shift itself), so its
        // long-run average must close the gap faster than a hypothetical
        // 4:1-only table. Compare against the closed-form 4:1 trajectory.
        let ptt = Ptt::new(1, &topo);
        steady(&ptt, 0, 1.0);
        let mut pure41 = 1.0;
        for _ in 0..8 {
            ptt.update(0, 0, 1, 3.0);
            pure41 = (4.0 * pure41 + 3.0) / 5.0;
        }
        let v2 = ptt.read(0, 0, 1);
        assert!(
            3.0 - v2 < 3.0 - pure41,
            "fast re-learn must beat the 4:1 trajectory: v2 {v2}, 4:1 {pure41}"
        );
    }

    #[test]
    fn episode_end_reflags_for_fast_recovery() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        // Interfered steady state (trained at the inflated value)...
        steady(&ptt, 0, 2.2);
        assert!(!ptt.core_flagged(0));
        // ...then the episode ends: times drop back, detector re-flags.
        let mut flagged = false;
        for _ in 0..10 {
            ptt.update(0, 0, 1, 1.0);
            flagged |= ptt.core_flagged(0);
        }
        assert!(flagged, "downward shift (episode end) must also flag");
        for _ in 0..40 {
            ptt.update(0, 0, 1, 1.0);
        }
        assert!(!ptt.core_flagged(0));
        assert!((ptt.read(0, 0, 1) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn sibling_cell_lockstep_sample_cannot_clear_anothers_divergence() {
        // Core 0 leads two cells (widths 1 and 2). Cell w1 diverges and
        // flags the core; cell w2's first post-shift sample blends recent
        // and long in lockstep (fast weight) — ratio exactly 1 — which is
        // evidence about w2 only and must NOT clear the core flag while
        // w1 is still mid-shift.
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for _ in 0..20 {
            ptt.update(0, 0, 1, 1.0);
            ptt.update(0, 0, 2, 1.0);
        }
        assert!(!ptt.core_flagged(0));
        ptt.update(0, 0, 1, 2.2); // w1 diverges (ratio 1.29)
        assert!(ptt.core_flagged(0));
        ptt.update(0, 0, 2, 2.2); // w2 lockstep: recent == long, ratio 1.0
        assert!(
            ptt.core_flagged(0),
            "a sibling cell's ratio-1.0 sample cleared the core flag"
        );
        // Once w1 itself reconverges, the core unflags.
        for _ in 0..10 {
            ptt.update(0, 0, 1, 2.2);
        }
        assert!(!ptt.core_flagged(0));
    }

    #[test]
    fn flags_are_per_core() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        steady(&ptt, 0, 1.0);
        steady(&ptt, 3, 1.0);
        for _ in 0..2 {
            ptt.update(0, 3, 1, 4.0); // only core 3 shifts
        }
        assert!(ptt.core_flagged(3));
        assert!(!ptt.core_flagged(0));
        let flags = ptt.core_flags();
        assert!(flags[3] && !flags[0]);
        assert_eq!(ptt.n_flagged(), 1);
    }

    #[test]
    fn best_global_avoiding_skips_flagged_and_falls_back() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Make (0,1) the unconstrained argmin.
        for _ in 0..50 {
            ptt.update(0, 0, 1, 0.01);
        }
        assert_eq!(ptt.best_global(0, &topo).0, Partition { leader: 0, width: 1 });
        // Avoiding core 0 must pick a partition not touching it.
        let (p, _) = ptt.best_global_avoiding(0, &topo, |c| c == 0).unwrap();
        assert!(!p.contains(0), "{p:?}");
        // Avoiding everything: no candidate survives.
        assert!(ptt.best_global_avoiding(0, &topo, |_| true).is_none());
    }

    #[test]
    fn best_in_cluster_avoiding_widens_but_stays_in_cluster() {
        let topo = tx2();
        let ptt = Ptt::new(1, &topo);
        for p in topo.all_partitions() {
            ptt.update(0, p.leader, p.width, 1.0);
        }
        // Core 3 (a57): its enclosing-partition search can only lead from
        // {3, 2}; the widened search may pick any a57 leader, e.g. 4.
        for _ in 0..50 {
            ptt.update(0, 4, 1, 0.01);
        }
        let (p, _) = ptt.best_in_cluster_avoiding(0, 3, &topo, |_| false).unwrap();
        assert_eq!((p.leader, p.width), (4, 1));
        assert_eq!(topo.cluster_of(p.leader).id, 1);
        // Avoiding core 3 still yields a candidate elsewhere in the cluster.
        let (p, _) = ptt.best_in_cluster_avoiding(0, 3, &topo, |c| c == 3).unwrap();
        assert!(!p.contains(3));
        // Avoiding the whole cluster: none.
        assert!(
            ptt.best_in_cluster_avoiding(0, 3, &topo, |c| topo.cluster_of(c).id == 1)
                .is_none()
        );
    }
}
