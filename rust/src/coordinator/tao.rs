//! Task Assembly Objects (TAOs).
//!
//! In XiTAO (§3.1) a TAO bundles a concurrent computation, an internal
//! scheduler and a *resource width* — the number of cores that execute it.
//! Here the computation is a [`TaoPayload`]: an object whose `execute` is
//! called once per participating core with a distinct `rank` in
//! `0..width`. The payload performs its own internal work partitioning
//! (the "internal scheduler" of the paper — all our kernels use static
//! rank-sliced decomposition).
//!
//! The *resource width is decided by the runtime scheduler*, not the
//! payload; payloads must therefore handle any width ≥ 1.

use crate::platform::KernelClass;
use std::sync::Arc;

/// A TAO body: executed by `width` cooperating cores, each with a unique
/// rank. Implementations must be safe to call concurrently from the
/// participating worker threads.
pub trait TaoPayload: Send + Sync {
    /// Workload class (drives the simulator's performance model and, in
    /// real mode, documents the kernel's character).
    fn class(&self) -> KernelClass;

    /// Execute rank `rank` of `width`. Called exactly once per rank.
    fn execute(&self, rank: usize, width: usize);

    /// Human-readable kernel name for traces.
    fn name(&self) -> &'static str {
        self.class().name()
    }
}

/// A trivial payload that does nothing (DAG-structure tests, sim-only runs).
pub struct NopPayload(pub KernelClass);

impl TaoPayload for NopPayload {
    fn class(&self) -> KernelClass {
        self.0
    }

    fn execute(&self, _rank: usize, _width: usize) {}
}

/// A payload wrapping a closure; the closure receives `(rank, width)`.
pub struct FnPayload<F: Fn(usize, usize) + Send + Sync> {
    pub class: KernelClass,
    pub f: F,
}

impl<F: Fn(usize, usize) + Send + Sync> TaoPayload for FnPayload<F> {
    fn class(&self) -> KernelClass {
        self.class
    }

    fn execute(&self, rank: usize, width: usize) {
        (self.f)(rank, width)
    }
}

/// Convenience constructor for closure payloads.
pub fn payload_fn<F: Fn(usize, usize) + Send + Sync + 'static>(
    class: KernelClass,
    f: F,
) -> Arc<dyn TaoPayload> {
    Arc::new(FnPayload { class, f })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fn_payload_executes_with_rank() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let p = payload_fn(KernelClass::MatMul, move |rank, width| {
            assert!(rank < width);
            h.fetch_add(1 << rank, Ordering::SeqCst);
        });
        p.execute(0, 2);
        p.execute(1, 2);
        assert_eq!(hits.load(Ordering::SeqCst), 0b11);
        assert_eq!(p.class(), KernelClass::MatMul);
    }

    #[test]
    fn nop_payload_class() {
        let p = NopPayload(KernelClass::Sort);
        assert_eq!(p.class(), KernelClass::Sort);
        assert_eq!(p.name(), "sort");
        p.execute(0, 1);
    }
}
