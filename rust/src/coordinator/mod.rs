//! The paper's contribution: the XiTAO coordinator with the Performance
//! Trace Table.
//!
//! - [`tao`] — Task Assembly Objects (internally parallel tasks).
//! - [`dag`] — TAO-DAGs, bottom-up criticality, average parallelism (§2).
//! - [`core`] — the backend-agnostic task-lifecycle core ([`SchedCore`]):
//!   placement, §3.3 commit-and-wake-up, admission — shared verbatim by
//!   the real-thread engine and [`crate::sim`].
//! - [`ptt`] — the Performance Trace Table (§3.2).
//! - [`wsq`] / [`aq`] — lock-free per-core work-stealing (Chase–Lev) and
//!   assembly (MPSC) queues (§3.1); [`inbox`] — lock-free admission
//!   handoff into live workers; [`mutex_queues`] — the mutex baselines,
//!   kept only for the `bench-overhead` comparison.
//! - [`arena`] — per-run bump arena of task frames: word-sized
//!   [`FrameId`] handles through the queues instead of `Arc` churn.
//! - [`scheduler`] — the performance-based policy and the baselines (§3.3, §6).
//! - [`list_sched`] — offline plan-ahead schedulers (HEFT/PEFT/DLS and a
//!   portfolio meta-policy) replayed through the same [`Policy`] seam.
//! - [`worker`] — the real-thread execution engine.
//! - [`metrics`] — traces and derived run metrics, plus
//!   [`metrics::lower_bound`] (critical-path/area makespan bounds).
//!
//! The simulated engine that drives the paper-figure reproductions lives in
//! [`crate::sim`] and reuses `core`, `dag`, `ptt`, `scheduler` and
//! `metrics` verbatim — the scheduling logic under test is the same code
//! objects in both engines, so sim/real conformance holds by construction.

pub mod aq;
pub mod arena;
pub mod core;
pub mod dag;
pub mod episodes_rt;
pub mod inbox;
pub mod list_sched;
pub mod metrics;
pub mod mutex_queues;
pub mod ptt;
pub mod scheduler;
pub mod tao;
pub mod worker;
pub mod wsq;

pub use self::core::{
    AdmissionSource, CommitInfo, CommitOutcome, Placement, SchedCore, ServingApp,
    ServingCounters, ServingOpts, ServingRun, ServingSource,
};
pub use arena::{Frame, FrameArena, FrameId};
pub use dag::{TaoDag, TaoNode, TaskId};
pub use episodes_rt::EpisodeDriver;
pub use list_sched::{PLANNER_NAMES, Plan, PlannedPolicy, plan_dag, planned_policy};
pub use metrics::lower_bound::{
    MakespanBound, model_bound, observed_app_bound, observed_bound, observed_cp_bound,
};
pub use metrics::{
    AppMetrics, RunResult, Trace, TraceRecord, jain_fairness_index, jain_fairness_total,
    per_app_metrics, sort_by_commit,
};
pub use ptt::Ptt;
pub use scheduler::{
    CatsLike, DheftLike, EnergyMinimizing, EngineView, FAIRNESS_SETPOINT, HomogeneousWs,
    POLICIES, PerformanceBased, PlaceCtx, Policy, PolicyInfo, PttAdaptive, PttElastic,
    PttServing, QosClass, TaskView, policy_by_name, policy_names,
};
pub use tao::{NopPayload, TaoPayload, payload_fn};
pub use worker::{RealEngineOpts, run_dag_real, run_serving_real, run_stream_real};
