//! Makespan lower bounds: how far is a schedule from provably optimal?
//!
//! Raw makespans only rank policies against each other; a *lower bound*
//! anchors them to the platform. Every bound here is the classical pair
//! from scheduling theory:
//!
//! - **critical-path bound** — no schedule can finish before the longest
//!   dependency chain, with every task charged its best-case cost;
//! - **area bound** — `n` cores can retire at most `n` core-seconds of
//!   work per second, so the makespan is at least the total work divided
//!   by the core count.
//!
//! Neither dominates the other (a serial chain is CP-bound, an
//! embarrassingly parallel bag is area-bound); [`MakespanBound::combined`]
//! takes the max. Reports express a run as `pct_of_bound` — 100% means
//! provably optimal, and the gap above 100% upper-bounds what *any*
//! scheduler could still recover.
//!
//! Two cost sources, with different validity envelopes:
//!
//! - [`model_bound`] charges each task its cheapest partition under the
//!   **episode-free, uncontended** analytic model
//!   ([`Platform::ideal_exec_time`] with the episode schedule stripped).
//!   Every dynamic effect the simulator models — episodes (DVFS,
//!   interference), cache/bandwidth/co-run contention — only *slows*
//!   execution (all factors ≤ 1), so this is a sound bound for the sim
//!   backend. It says nothing about wall-clock runs on a host machine.
//! - [`observed_bound`] / [`observed_cp_bound`] charge each task its
//!   **measured** execution time from the run's own trace. The CP part is
//!   sound on both backends: a child is released only at its parent's
//!   commit, so the records along any dependency path occupy disjoint
//!   sub-intervals of `[0, makespan]`. The area part additionally needs
//!   record intervals to represent busy cores, which holds exactly in the
//!   sim; real-engine records stretch to the last member's commit and may
//!   include queue-wait gaps, so wall-clock callers use the CP-only
//!   variant rather than risk an invalid "bound" above the makespan.
//!
//! The exec layer fills [`super::RunResult::bound`] with the appropriate
//! variant per backend; `tests/lower_bounds.rs` property-checks
//! `bound ≤ makespan` across random DAGs, every registered policy, every
//! scenario and both backends.

use super::TraceRecord;
use crate::coordinator::dag::TaoDag;
use crate::platform::{EpisodeSchedule, KernelClass, Platform};

/// The critical-path / area bound pair for one DAG (or one app's
/// component of a stream).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MakespanBound {
    /// Longest dependency chain at best-case per-task cost.
    pub cp: f64,
    /// Total best-case core-seconds divided by the core count; `0.0` when
    /// the area argument is not valid for the cost source (wall-clock
    /// observed costs).
    pub area: f64,
}

impl MakespanBound {
    /// The binding constraint: max of the two bounds.
    pub fn combined(&self) -> f64 {
        self.cp.max(self.area)
    }

    /// `makespan` as a percentage of the bound (`≥ 100` for a sound
    /// bound). `None` when the bound is degenerate (no costed tasks) or
    /// the makespan is not finite — reports print `n/a` rather than a
    /// fake ratio.
    pub fn pct_of(&self, makespan: f64) -> Option<f64> {
        let b = self.combined();
        if b > 0.0 && makespan.is_finite() {
            Some(100.0 * makespan / b)
        } else {
            None
        }
    }
}

/// Longest path through `dag` charging `cost[t]` per task: the standard
/// reverse-topological DP, `down[t] = cost[t] + max over successors`.
fn critical_path(dag: &TaoDag, cost: &[f64]) -> f64 {
    let order = dag.topo_order().expect("bounds need an acyclic DAG");
    let mut down = vec![0.0f64; dag.len()];
    let mut cp = 0.0f64;
    for &t in order.iter().rev() {
        let succ_max =
            dag.nodes[t].succs.iter().fold(0.0f64, |acc, &s| acc.max(down[s]));
        down[t] = cost[t] + succ_max;
        cp = cp.max(down[t]);
    }
    cp
}

/// Per-class best cost and best core-seconds over all partitions of the
/// episode-free platform, indexed by [`KernelClass::index`].
fn best_class_costs(plat: &Platform) -> ([f64; 4], [f64; 4]) {
    let clean = Platform {
        topo: plat.topo.clone(),
        dram_bw_gbps: plat.dram_bw_gbps,
        episodes: EpisodeSchedule::default(),
    };
    let mut best_cost = [f64::INFINITY; 4];
    let mut best_core_secs = [f64::INFINITY; 4];
    for p in clean.topo.all_partitions() {
        for class in KernelClass::ALL {
            let c = clean.ideal_exec_time(class, p);
            let i = class.index();
            best_cost[i] = best_cost[i].min(c);
            best_core_secs[i] = best_core_secs[i].min(c * p.width as f64);
        }
    }
    (best_cost, best_core_secs)
}

/// Analytic lower bound for running `dag` on `plat`'s *simulated*
/// performance model: per-task best-case cost is the cheapest partition
/// under the episode-free, uncontended model, scaled by `work_scale`.
///
/// The CP part charges best *time* per task; the area part charges best
/// *core-seconds* per task (a wide partition finishes sooner but occupies
/// more of the machine — the two minima can pick different partitions).
pub fn model_bound(dag: &TaoDag, plat: &Platform) -> MakespanBound {
    let (best_cost, best_core_secs) = best_class_costs(plat);
    let costs: Vec<f64> = dag
        .nodes
        .iter()
        .map(|n| best_cost[n.class.index()] * n.work_scale)
        .collect();
    let cp = critical_path(dag, &costs);
    let total_core_secs: f64 = dag
        .nodes
        .iter()
        .map(|n| best_core_secs[n.class.index()] * n.work_scale)
        .sum();
    MakespanBound { cp, area: total_core_secs / plat.topo.n_cores() as f64 }
}

/// Per-task measured execution times from a trace; tasks without a record
/// cost 0 (keeping every variant a sound *lower* bound on partial
/// traces).
fn observed_costs(dag: &TaoDag, records: &[TraceRecord]) -> Vec<f64> {
    let mut costs = vec![0.0f64; dag.len()];
    for r in records {
        if r.task < costs.len() {
            costs[r.task] = r.exec_time().max(0.0);
        }
    }
    costs
}

/// Observed bound from a *simulated* trace: CP over measured execution
/// times plus the area bound `Σ exec / n_cores`. Sim records are exact
/// busy intervals, so both parts are sound; for wall-clock traces use
/// [`observed_cp_bound`].
pub fn observed_bound(
    dag: &TaoDag,
    records: &[TraceRecord],
    n_cores: usize,
) -> MakespanBound {
    let costs = observed_costs(dag, records);
    let cp = critical_path(dag, &costs);
    let area = costs.iter().sum::<f64>() / n_cores as f64;
    MakespanBound { cp, area }
}

/// Observed bound from a *wall-clock* trace: CP only. Sound on the real
/// engine because a child's record starts at or after its parent's commit
/// (`t_end`), so path records occupy disjoint sub-intervals of the run.
/// The area argument is *not* sound there — a record spans leader start
/// to last-member commit, which can include queue-wait time on no core —
/// so `area` is reported as 0.
pub fn observed_cp_bound(dag: &TaoDag, records: &[TraceRecord]) -> MakespanBound {
    let costs = observed_costs(dag, records);
    MakespanBound { cp: critical_path(dag, &costs), area: 0.0 }
}

/// Observed lower bound on one application's makespan (completion −
/// arrival) within a multi-app trace: the app's own records, CP'd over
/// the shared DAG (apps are disjoint components, so other apps cost 0 and
/// contribute nothing to any path). `with_area` adds `Σ exec / n_cores`
/// — sound for sim traces only, same argument as [`observed_bound`].
/// `None` when the app has no records.
pub fn observed_app_bound(
    dag: &TaoDag,
    records: &[TraceRecord],
    app_id: usize,
    n_cores: usize,
    with_area: bool,
) -> Option<f64> {
    let mut costs = vec![0.0f64; dag.len()];
    let mut total = 0.0f64;
    let mut any = false;
    for r in records.iter().filter(|r| r.app_id == app_id) {
        if r.task < costs.len() {
            let e = r.exec_time().max(0.0);
            costs[r.task] = e;
            total += e;
            any = true;
        }
    }
    if !any {
        return None;
    }
    let cp = critical_path(dag, &costs);
    let area = if with_area { total / n_cores as f64 } else { 0.0 };
    Some(cp.max(area))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dag::paper_figure1_dag;
    use crate::dag_gen::fixtures::{chain_dag, independent_dag};
    use crate::platform::{Partition, scenarios};

    fn tx2() -> Platform {
        scenarios::by_name("tx2").expect("tx2 is registered")
    }

    /// The tx2 platform has no episode schedule, so the module's
    /// episode-free clone must agree with the platform's own
    /// `ideal_exec_time` — pinning that `model_bound` really is "best
    /// partition, nominal machine".
    #[test]
    fn figure1_model_bound_matches_per_class_minima() {
        let plat = tx2();
        let (dag, _) = paper_figure1_dag();
        let b = model_bound(&dag, &plat);
        let min_cost = |class: KernelClass| {
            plat.topo
                .all_partitions()
                .into_iter()
                .map(|p| plat.ideal_exec_time(class, p))
                .fold(f64::INFINITY, f64::min)
        };
        // Critical path of Figure 1 is A→C→G→D→F: 3 MatMul + 2 Copy.
        let expect_cp = 3.0 * min_cost(KernelClass::MatMul) + 2.0 * min_cost(KernelClass::Copy);
        assert!((b.cp - expect_cp).abs() < 1e-15, "cp {} vs {expect_cp}", b.cp);
        // Hand-computed pin (denver pair for MatMul, quad A57 for Copy):
        // 3 × 2.3636e-4 + 2 × 1.4679e-3 ≈ 3.6449e-3 virtual seconds.
        assert!((b.cp - 3.6449e-3).abs() < 1e-5, "cp drifted: {}", b.cp);
        assert!(b.area > 0.0 && b.area < b.cp, "figure 1 is CP-bound, got {b:?}");
        assert!((b.combined() - b.cp).abs() < 1e-18);
    }

    #[test]
    fn per_class_minima_cover_wide_partitions() {
        // Soundness under moldable (width > 1) placements: the bound
        // charges each class the minimum over *all* partitions, so it can
        // never exceed what a width-1-only bound would charge — and for
        // bandwidth-heavy classes the wide partition is strictly cheaper
        // (Copy's tx2 winner is the quad A57, pinned in the figure-1 test
        // above), so elastic schedules that go wide stay above the bound
        // by construction rather than by luck.
        let plat = tx2();
        let (best_cost, best_core_secs) = best_class_costs(&plat);
        let mut some_class_wins_wide = false;
        for class in KernelClass::ALL {
            let w1_best = plat
                .topo
                .all_partitions()
                .into_iter()
                .filter(|p| p.width == 1)
                .map(|p| plat.ideal_exec_time(class, p))
                .fold(f64::INFINITY, f64::min);
            let i = class.index();
            assert!(
                best_cost[i] <= w1_best + 1e-18,
                "{class:?}: all-width min {} above width-1 min {w1_best}",
                best_cost[i]
            );
            // Width-1 core-seconds equal width-1 time, so the area charge
            // is also no worse than a width-1-only bound's.
            assert!(best_core_secs[i] <= w1_best + 1e-18);
            if best_cost[i] < w1_best - 1e-15 {
                some_class_wins_wide = true;
            }
        }
        assert!(
            some_class_wins_wide,
            "no class prefers a wide partition on tx2 — the width>1 case is untested"
        );
    }

    #[test]
    fn chain_is_cp_bound_and_bag_is_area_bound() {
        let plat = tx2();
        let chain = chain_dag(8, KernelClass::MatMul);
        let cb = model_bound(&chain, &plat);
        assert!(cb.cp > cb.area, "serial chain must be CP-bound: {cb:?}");
        let bag = independent_dag(64, KernelClass::MatMul);
        let bb = model_bound(&bag, &plat);
        assert!(bb.area > bb.cp, "64 independent tasks on 6 cores must be area-bound: {bb:?}");
    }

    fn rec(task: usize, app_id: usize, t_start: f64, t_end: f64) -> TraceRecord {
        TraceRecord {
            task,
            app_id,
            class: KernelClass::MatMul,
            type_id: 0,
            critical: false,
            partition: Partition { leader: 0, width: 1 },
            t_start,
            t_end,
        }
    }

    #[test]
    fn observed_bounds_on_a_hand_built_trace() {
        let dag = chain_dag(3, KernelClass::MatMul);
        // Chain executed back-to-back with gaps: exec times 1, 2, 3.
        let records =
            vec![rec(0, 0, 0.0, 1.0), rec(1, 0, 1.5, 3.5), rec(2, 0, 4.0, 7.0)];
        let b = observed_bound(&dag, &records, 4);
        assert!((b.cp - 6.0).abs() < 1e-12, "cp {}", b.cp);
        assert!((b.area - 1.5).abs() < 1e-12, "area {}", b.area);
        let cp_only = observed_cp_bound(&dag, &records);
        assert_eq!(cp_only.area, 0.0);
        assert!((cp_only.cp - 6.0).abs() < 1e-12);
        // 7.5 wall seconds against a bound of 6: 125%.
        assert!((b.pct_of(7.5).unwrap() - 125.0).abs() < 1e-9);
    }

    #[test]
    fn empty_traces_yield_degenerate_bounds_not_fake_ratios() {
        let dag = chain_dag(3, KernelClass::MatMul);
        let b = observed_bound(&dag, &[], 4);
        assert_eq!(b.combined(), 0.0);
        assert_eq!(b.pct_of(1.0), None, "degenerate bound must not report a pct");
        assert_eq!(observed_app_bound(&dag, &[], 0, 4, true), None);
    }

    #[test]
    fn app_bound_ignores_other_apps_records() {
        let dag = chain_dag(4, KernelClass::MatMul);
        // Tasks 0-1 belong to app 0, tasks 2-3 to app 1 (edges 1→2 exist
        // in the fixture chain but costs of the other app are zeroed, so
        // each app's bound counts only its own work).
        let records = vec![
            rec(0, 0, 0.0, 1.0),
            rec(1, 0, 1.0, 2.0),
            rec(2, 1, 2.0, 5.0),
            rec(3, 1, 5.0, 9.0),
        ];
        let a0 = observed_app_bound(&dag, &records, 0, 2, true).unwrap();
        assert!((a0 - 2.0).abs() < 1e-12, "app0 cp 1+1, got {a0}");
        let a1 = observed_app_bound(&dag, &records, 1, 2, true).unwrap();
        assert!((a1 - 7.0).abs() < 1e-12, "app1 cp 3+4, got {a1}");
    }
}
