//! Per-core work-stealing queues (§3.1).
//!
//! The WSQ stores *ready* tasks. The owner pushes and pops at the back
//! (LIFO — freshly woken children run first, preserving locality); thieves
//! steal from the front (FIFO — the oldest, usually largest-subtree work
//! migrates). A mutex-guarded deque is sufficient here: the queues hold
//! task ids (copy types), critical sections are a few instructions, and
//! correctness/portability beat a lock-free Chase–Lev under this
//! repository's testing budget (measured in `sched_overhead`).

use std::collections::VecDeque;
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct WsQueue<T> {
    q: Mutex<VecDeque<T>>,
}

impl<T> WsQueue<T> {
    pub fn new() -> WsQueue<T> {
        WsQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Owner-side push (back).
    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
    }

    /// Owner-side pop (back, LIFO).
    pub fn pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop_back()
    }

    /// Thief-side steal (front, FIFO).
    pub fn steal(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn owner_lifo_thief_fifo() {
        let q = WsQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.steal(), Some(1)); // oldest
        assert_eq!(q.pop(), Some(3)); // newest
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.steal(), None);
    }

    #[test]
    fn concurrent_steals_lose_nothing() {
        let q = Arc::new(WsQueue::new());
        for i in 0..1000 {
            q.push(i);
        }
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.steal() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<i32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn len_tracks() {
        let q = WsQueue::new();
        assert!(q.is_empty());
        q.push(());
        q.push(());
        assert_eq!(q.len(), 2);
    }
}
