//! Per-core work-stealing queues (§3.1) — a lock-free Chase–Lev deque.
//!
//! The WSQ stores *ready* tasks. The owner pushes and pops at the bottom
//! (LIFO — freshly woken children run first, preserving locality); thieves
//! steal from the top (FIFO — the oldest, usually largest-subtree work
//! migrates). This is the dynamic circular work-stealing deque of Chase &
//! Lev (SPAA'05) with the weak-memory ordering discipline of Lê et al.
//! (PPoPP'13): owner pushes and non-racing pops are fence-free, a single
//! `SeqCst` fence orders the owner's `bottom` write against thief reads,
//! and thieves race each other (and the owner, on the last element) with
//! one CAS on `top`.
//!
//! An earlier revision guarded a `VecDeque` with a mutex and claimed the
//! lock was "sufficient" without a measurement. The measurement now exists:
//! `repro bench-overhead --compare` pits this deque against that mutex
//! baseline (kept in [`super::mutex_queues`]) on a steal-heavy workload and
//! records the ratio in `BENCH_sched_overhead.json`. On the paper's 20-core
//! Haswell scenario every push/pop/steal used to serialize through one lock
//! per core — the scheduler itself became the interference the PTT is
//! supposed to measure.
//!
//! ## Contract
//!
//! - `push`/`pop` are **owner-only**: at most one thread (the queue's core)
//!   uses the bottom end at a time. The engines uphold this by
//!   construction: a worker only touches its own queue, root bootstrap
//!   happens strictly before the workers spawn, and late admission goes
//!   through the per-core [`super::inbox::Inbox`] instead of a foreign
//!   push.
//! - `steal`/`len`/`is_empty` are safe from any thread, any number of
//!   thieves.
//! - `T: Copy` (and padding-free, at most word-sized — asserted in `new`):
//!   a thief may read a slot and then lose the `top` CAS, discarding the
//!   value, and a *stale* thief may even read a slot the owner is
//!   concurrently overwriting. Slots are therefore relaxed `AtomicU64`
//!   cells (values bit-cast through one word, exactly like Lê et al.'s
//!   atomic array accesses): the racing read is well-defined, merely
//!   possibly stale — and a stale value never survives the CAS. With
//!   `Copy` types the discarded duplicate is inert. (Task ids are `usize`,
//!   so the engines lose nothing.)
//!
//! Grown-out-of buffers are *retired*, not freed immediately: a stale
//! thief may still read them, and its CAS then fails harmlessly.
//! Retirement takes a lock, but only inside `grow` — never on the
//! push/pop/steal fast path.
//!
//! ## Retired-buffer reclamation
//!
//! An earlier revision kept retired buffers until `Drop` — fine for a
//! finite run, unbounded memory for a never-draining service where deques
//! resize under churn forever. Retired buffers are now freed at
//! **quiescent points** via a thief refcount (`thieves`): every `steal`
//! brackets its buffer access with a `SeqCst` increment/decrement, and the
//! owner frees the retired list only after observing `thieves == 0`.
//!
//! Soundness (sequential-consistency argument; every participating access
//! is `SeqCst`): a thief can only obtain a retired pointer `P` by loading
//! `buf` *before* the `grow` that replaced `P` in the SC total order, and
//! its `thieves` increment precedes that load. The owner's `thieves` read
//! follows the replacing store (same thread: `grow`/`maintain` are
//! owner-only). So if the owner reads 0, every thief that could hold `P`
//! has already decremented — i.e. finished its steal — and any *later*
//! thief's `buf` load follows the replacing `SeqCst` store in SC order and
//! must observe the new buffer. Freeing `P` is then safe. Reclamation runs
//! opportunistically inside `grow` and from [`WsQueue::maintain`], which
//! the real engine's workers call before parking — exactly when thieves
//! are likeliest to be quiescent.
//!
//! ## Batched steals (`steal_half`)
//!
//! A starving worker that resorts to the full victim sweep takes *half*
//! of the first non-empty victim's window in one call ([`steal_half`](
//! WsQueue::steal_half)), bounded by [`MAX_BATCH_STEAL`] — the classic
//! work-stealing result that migrating half the victim's backlog spreads
//! load in O(log n) rounds instead of one-task-per-probe trickles.
//!
//! **Why the batch is a bounded loop of single-item CAS claims and not one
//! wide `top: t → t+k` CAS.** The wide claim is *unsound* against this
//! deque's owner. A thief that reads `t`, `b`, copies slots `t..t+k` and
//! then CASes `top` from `t` to `t+k` has validated only that `top` never
//! moved — but the owner's non-racing pop path consumes index `b-1`
//! *without touching `top`* (it CASes only for the last element). Concrete
//! interleaving: `t = 0`, `b = 6`; a thief copies slots `0..3`; the owner
//! pops indices 5, 4, 3, 2 (each time `t < b-1` from its stale view, so
//! no CAS); the thief's CAS `0 → 3` still succeeds, and index 2 is
//! consumed twice. Repairing that by re-reading `bottom` *after* the wide
//! CAS and shrinking the claim fixes duplication but opens a lost-item
//! window instead: if the owner popped into the claimed range and then
//! *pushed* again, the new item sits at an index below the advanced `top`
//! and is never live — and un-publishing `top` backwards is unsound with
//! a second thief in flight. So each claimed item re-runs the full proven
//! single-steal protocol (`top` load, `SeqCst` fence, `bottom` load,
//! emptiness check, `SeqCst` buffer load, slot read, one CAS on `top`);
//! exactly-once and the stale-read argument hold per item by the
//! unchanged Lê et al. argument, and the retired-buffer discipline holds
//! because the *whole batch* sits inside a single `thieves`-refcount
//! bracket. What the batch amortizes is everything around the CAS: the
//! refcount bracket, the victim-selection probe, the call overhead, and —
//! decisively under contention — the cache-line transfer of `top`, which
//! a burst of back-to-back CAS claims keeps in the thief's cache instead
//! of re-acquiring it per probe round. A lost CAS mid-batch ends the
//! batch (another consumer owns the line now); the items already claimed
//! are kept.

use std::fmt;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::Mutex;
use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize, Ordering, fence};

/// Power-of-two circular buffer; indices wrap via the mask. Slots hold `T`
/// bit-cast into a `u64` word so every access is a (relaxed) atomic —
/// see the module docs for why the stale-thief race demands this.
struct Buffer<T> {
    mask: usize,
    slots: Box<[AtomicU64]>,
    _marker: PhantomData<T>,
}

impl<T: Copy> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        assert!(
            std::mem::size_of::<T>() <= 8,
            "WsQueue items must fit one machine word (got {} bytes)",
            std::mem::size_of::<T>()
        );
        let slots =
            (0..cap).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice();
        Box::into_raw(Box::new(Buffer { mask: cap - 1, slots, _marker: PhantomData }))
    }

    fn cap(&self) -> usize {
        self.mask + 1
    }

    /// Write slot `i` (owner-only). A stale thief may load this slot
    /// concurrently — defined behaviour (both sides are atomic), and the
    /// thief's value dies with its failed `top` CAS.
    fn put(&self, i: isize, v: T) {
        let mut bits = 0u64;
        // Safety: size checked in `alloc`; `v` is a valid T.
        unsafe {
            ptr::copy_nonoverlapping(
                &v as *const T as *const u8,
                &mut bits as *mut u64 as *mut u8,
                std::mem::size_of::<T>(),
            );
        }
        self.slots[i as usize & self.mask].store(bits, Ordering::Relaxed);
    }

    /// Read slot `i`. The value is only *used* by whoever wins the CAS on
    /// `top` (or by the owner when no race is possible), so slots that
    /// were written by `put` with a valid T are the only ones ever kept.
    ///
    /// Safety: the caller must only keep the value under the conditions
    /// above (index in the live `top..bottom` window at CAS time).
    unsafe fn get(&self, i: isize) -> T {
        let bits = self.slots[i as usize & self.mask].load(Ordering::Relaxed);
        let mut v = MaybeUninit::<T>::uninit();
        unsafe {
            ptr::copy_nonoverlapping(
                &bits as *const u64 as *const u8,
                v.as_mut_ptr() as *mut u8,
                std::mem::size_of::<T>(),
            );
            v.assume_init()
        }
    }
}

const INITIAL_CAP: usize = 64;

/// Upper bound on one [`WsQueue::steal_half`] batch. Keeps a single batch
/// from emptying a deep victim queue wholesale (other thieves deserve a
/// share, and the thief must not hoard more than it can start soon) while
/// still amortizing the per-steal overhead ~30x. The mutex reference in
/// [`super::mutex_queues`] uses the same cap so the lockstep conformance
/// tests can compare batch-for-batch.
pub const MAX_BATCH_STEAL: usize = 32;

/// Lock-free work-stealing deque. See the module docs for the ownership
/// contract (`push`/`pop` owner-only, `steal` from anywhere).
pub struct WsQueue<T> {
    /// Thief end; monotonically increasing (no ABA).
    top: AtomicIsize,
    /// Owner end.
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer<T>>,
    /// Buffers replaced by `grow`, kept alive while a stale thief may
    /// still read them; freed at quiescent points (see the module docs).
    retired: Mutex<Vec<*mut Buffer<T>>>,
    /// Number of thieves currently inside `steal` (the quiescence
    /// refcount guarding `retired`).
    thieves: AtomicUsize,
}

// Safety: the slots only ever transfer `T` by copy between threads, and all
// cross-thread index handoffs go through the atomics above.
unsafe impl<T: Copy + Send> Send for WsQueue<T> {}
unsafe impl<T: Copy + Send> Sync for WsQueue<T> {}

impl<T: Copy> WsQueue<T> {
    pub fn new() -> WsQueue<T> {
        WsQueue {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(INITIAL_CAP)),
            retired: Mutex::new(Vec::new()),
            thieves: AtomicUsize::new(0),
        }
    }

    /// Owner-side push (bottom).
    pub fn push(&self, item: T) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        if b - t >= unsafe { &*buf }.cap() as isize {
            buf = self.grow(t, b, buf);
        }
        unsafe { (*buf).put(b, item) };
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner-side pop (bottom, LIFO).
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // Order the `bottom` decrement against thief reads of `top`.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let item = unsafe { (*buf).get(b) };
            if t == b {
                // Last element: race the thieves for it.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                return won.then_some(item);
            }
            Some(item)
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief-side steal (top, FIFO). Retries internally when it loses a
    /// race; returns `None` only when the deque was observed empty.
    pub fn steal(&self) -> Option<T> {
        // Quiescence guard: while the count is non-zero the owner must not
        // free retired buffers (this thief may hold a stale pointer).
        self.thieves.fetch_add(1, Ordering::SeqCst);
        let item = self.steal_inner();
        self.thieves.fetch_sub(1, Ordering::SeqCst);
        item
    }

    fn steal_inner(&self) -> Option<T> {
        loop {
            let t = self.top.load(Ordering::Acquire);
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            // SeqCst (not just Acquire): the reclamation proof needs this
            // load totally ordered against `grow`'s buffer swap — see the
            // module docs.
            let buf = self.buf.load(Ordering::SeqCst);
            let item = unsafe { (*buf).get(t) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(item);
            }
            // Lost to the owner or another thief; re-read and retry.
        }
    }

    /// Batched thief-side steal: take up to half of the first observed
    /// window (rounded up, capped at [`MAX_BATCH_STEAL`]), passing each
    /// item to `sink` in FIFO (oldest-first) order. Returns the number of
    /// items taken; `0` only when the deque was observed empty (or another
    /// consumer won every race before we claimed anything).
    ///
    /// Each item is claimed by the full single-steal protocol — see the
    /// module docs ("Batched steals") for why a wide one-CAS claim is
    /// unsound here. A lost CAS after ≥ 1 item ends the batch early; the
    /// whole call sits inside one `thieves` quiescence bracket.
    pub fn steal_half(&self, mut sink: impl FnMut(T)) -> usize {
        self.thieves.fetch_add(1, Ordering::SeqCst);
        let taken = self.steal_batch_inner(MAX_BATCH_STEAL, &mut sink);
        self.thieves.fetch_sub(1, Ordering::SeqCst);
        taken
    }

    fn steal_batch_inner(&self, limit: usize, sink: &mut impl FnMut(T)) -> usize {
        let mut taken = 0usize;
        // Fixed after the first successful window observation: half of
        // what the victim had *then*, not a re-halving treadmill over the
        // shrinking remainder.
        let mut want = 0usize;
        loop {
            // Per-item protocol — identical to `steal_inner`, orderings
            // and all. The emptiness re-check each round is load-bearing:
            // claiming an index ≥ `bottom` would let an owner push land
            // below `top` and strand the item forever.
            let t = self.top.load(Ordering::Acquire);
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return taken;
            }
            if want == 0 {
                // Half the first observed window, rounded up (t < b here,
                // so the cast is lossless).
                want = ((b - t) as usize).div_ceil(2).clamp(1, limit);
            }
            let buf = self.buf.load(Ordering::SeqCst);
            let item = unsafe { (*buf).get(t) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                sink(item);
                taken += 1;
                if taken >= want {
                    return taken;
                }
            } else if taken > 0 {
                // Mid-batch contention: another consumer owns the `top`
                // line now — keep what we have instead of fighting for
                // the rest of the window.
                return taken;
            }
            // taken == 0: lost the race before claiming anything; retry
            // like `steal` does.
        }
    }

    /// Approximate length (exact when the queue is quiescent).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Double the buffer, copying the live range; the old buffer is
    /// retired (see the module docs) and freed once no thief can hold it.
    fn grow(&self, t: isize, b: isize, old: *mut Buffer<T>) -> *mut Buffer<T> {
        let new = Buffer::alloc(unsafe { &*old }.cap() * 2);
        for i in t..b {
            unsafe { (*new).put(i, (*old).get(i)) };
        }
        // SeqCst: totally ordered against thief buffer loads and the
        // owner's quiescence check (the reclamation proof's anchor).
        self.buf.store(new, Ordering::SeqCst);
        self.retired.lock().unwrap().push(old);
        self.reclaim_if_quiescent();
        new
    }

    /// Owner-side housekeeping: free retired buffers if no thief is
    /// mid-steal. **Owner-only**, like `push`/`pop` — the soundness
    /// argument needs the quiescence check ordered after this queue's own
    /// `grow` stores, which same-thread program order provides. The real
    /// engine's workers call this right before parking.
    pub fn maintain(&self) {
        self.reclaim_if_quiescent();
    }

    /// Number of retired (not yet reclaimed) buffers — observability for
    /// the long-churn bounded-memory tests.
    pub fn retired_len(&self) -> usize {
        self.retired.lock().unwrap().len()
    }

    fn reclaim_if_quiescent(&self) {
        let mut retired = match self.retired.try_lock() {
            Ok(r) => r,
            // Contended only by another reclaim attempt or Drop; skip.
            Err(_) => return,
        };
        if retired.is_empty() {
            return;
        }
        // Check *after* taking the lock: a thief that increments after
        // this load can no longer observe any pointer in `retired` (its
        // `buf` load is SC-after the store that retired it — module docs).
        if self.thieves.load(Ordering::SeqCst) != 0 {
            return;
        }
        for p in retired.drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

impl<T: Copy> Default for WsQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy> fmt::Debug for WsQueue<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WsQueue").field("len", &self.len()).finish()
    }
}

impl<T> Drop for WsQueue<T> {
    fn drop(&mut self) {
        // `T: Copy` for every constructible instance ⇒ no element
        // destructors to run; only the buffers need freeing.
        unsafe { drop(Box::from_raw(self.buf.load(Ordering::Relaxed))) };
        for p in self.retired.get_mut().unwrap().drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn owner_lifo_thief_fifo() {
        let q = WsQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.steal(), Some(1)); // oldest
        assert_eq!(q.pop(), Some(3)); // newest
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.steal(), None);
    }

    #[test]
    fn concurrent_steals_lose_nothing() {
        let q = Arc::new(WsQueue::new());
        for i in 0..1000 {
            q.push(i);
        }
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.steal() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<i32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn len_tracks() {
        let q = WsQueue::new();
        assert!(q.is_empty());
        q.push(());
        q.push(());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let q = WsQueue::new();
        let n = (super::INITIAL_CAP * 5) as i64;
        for i in 0..n {
            q.push(i);
        }
        assert_eq!(q.len(), n as usize);
        // LIFO pops return everything in reverse push order across the
        // grown buffer.
        for i in (0..n).rev() {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn steal_half_takes_half_rounded_up_fifo() {
        let q = WsQueue::new();
        for i in 0..7 {
            q.push(i);
        }
        let mut got = Vec::new();
        let n = q.steal_half(|v| got.push(v));
        // (7 + 1) / 2 = 4, oldest first.
        assert_eq!(n, 4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 3);
        // Owner still sees LIFO over the remainder.
        assert_eq!(q.pop(), Some(6));
        assert_eq!(q.pop(), Some(5));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn steal_half_caps_at_max_batch() {
        let q = WsQueue::new();
        let n = (MAX_BATCH_STEAL * 4) as i64;
        for i in 0..n {
            q.push(i);
        }
        let mut got = Vec::new();
        assert_eq!(q.steal_half(|v| got.push(v)), MAX_BATCH_STEAL);
        assert_eq!(got, (0..MAX_BATCH_STEAL as i64).collect::<Vec<_>>());
        assert_eq!(q.len(), (n as usize) - MAX_BATCH_STEAL);
    }

    #[test]
    fn steal_half_on_empty_and_singleton() {
        let q = WsQueue::new();
        assert_eq!(q.steal_half(|_: i32| panic!("empty deque yielded items")), 0);
        q.push(42);
        let mut got = Vec::new();
        assert_eq!(q.steal_half(|v| got.push(v)), 1);
        assert_eq!(got, vec![42]);
        assert_eq!(q.steal_half(|_| panic!("drained deque yielded items")), 0);
    }

    #[test]
    fn steal_half_leaves_queue_usable_for_mixed_ops() {
        let q = WsQueue::new();
        for i in 0..10 {
            q.push(i);
        }
        let mut got = Vec::new();
        assert_eq!(q.steal_half(|v| got.push(v)), 5);
        q.push(10);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.steal(), Some(5));
        let mut rest = Vec::new();
        q.steal_half(|v| rest.push(v));
        assert_eq!(rest, vec![6, 7]);
    }

    #[test]
    fn quiescent_grow_reclaims_retired_buffers() {
        // No thieves at all: every grow can free the buffer it retires,
        // so the retired list never exceeds the one entry `grow` pushes
        // before its own reclaim pass (which drains it).
        let q = WsQueue::new();
        for round in 0..20 {
            for i in 0..(super::INITIAL_CAP as i64 * (round + 2)) {
                q.push(i);
            }
            assert_eq!(q.retired_len(), 0, "round {round}");
            while q.pop().is_some() {}
        }
    }

    #[test]
    fn long_churn_with_thieves_keeps_retired_bounded() {
        // The never-draining-service scenario: the owner pushes/pops under
        // sustained stealing pressure for many grow cycles. The retired
        // list must stay bounded (reclaimed at quiescent points), not grow
        // monotonically as it did before reclamation existed.
        use std::sync::atomic::AtomicBool;
        let q = Arc::new(WsQueue::new());
        let stop = Arc::new(AtomicBool::new(false));
        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        if q.steal().is_some() {
                            n += 1;
                        }
                    }
                    n
                })
            })
            .collect();
        let mut max_retired = 0;
        for _ in 0..200 {
            for i in 0..(super::INITIAL_CAP as i64 * 8) {
                q.push(i);
            }
            while q.pop().is_some() {}
            q.maintain();
            max_retired = max_retired.max(q.retired_len());
        }
        stop.store(true, Ordering::Relaxed);
        for t in thieves {
            t.join().unwrap();
        }
        q.maintain();
        // A deque retires one buffer per grow, i.e. at most
        // log2(peak window / INITIAL_CAP) in total — the list must never
        // exceed that small bound while thieves are live, and must drain
        // to zero at the first thief-free maintain().
        assert!(max_retired <= 8, "retired list grew unbounded: {max_retired}");
        assert_eq!(q.retired_len(), 0, "final maintain() with no thieves must drain");
    }

    #[test]
    fn interleaved_push_pop_steal_preserves_order_semantics() {
        let q = WsQueue::new();
        q.push(10);
        q.push(11);
        assert_eq!(q.pop(), Some(11));
        q.push(12);
        assert_eq!(q.steal(), Some(10));
        assert_eq!(q.steal(), Some(12));
        assert_eq!(q.steal(), None);
        assert_eq!(q.pop(), None);
        // Reuse after empty.
        q.push(13);
        assert_eq!(q.pop(), Some(13));
    }
}
