//! Per-core work-stealing queues (§3.1) — a lock-free Chase–Lev deque.
//!
//! The WSQ stores *ready* tasks. The owner pushes and pops at the bottom
//! (LIFO — freshly woken children run first, preserving locality); thieves
//! steal from the top (FIFO — the oldest, usually largest-subtree work
//! migrates). This is the dynamic circular work-stealing deque of Chase &
//! Lev (SPAA'05) with the weak-memory ordering discipline of Lê et al.
//! (PPoPP'13): owner pushes and non-racing pops are fence-free, a single
//! `SeqCst` fence orders the owner's `bottom` write against thief reads,
//! and thieves race each other (and the owner, on the last element) with
//! one CAS on `top`.
//!
//! An earlier revision guarded a `VecDeque` with a mutex and claimed the
//! lock was "sufficient" without a measurement. The measurement now exists:
//! `repro bench-overhead --compare` pits this deque against that mutex
//! baseline (kept in [`super::mutex_queues`]) on a steal-heavy workload and
//! records the ratio in `BENCH_sched_overhead.json`. On the paper's 20-core
//! Haswell scenario every push/pop/steal used to serialize through one lock
//! per core — the scheduler itself became the interference the PTT is
//! supposed to measure.
//!
//! ## Contract
//!
//! - `push`/`pop` are **owner-only**: at most one thread (the queue's core)
//!   uses the bottom end at a time. The engines uphold this by
//!   construction: a worker only touches its own queue, root bootstrap
//!   happens strictly before the workers spawn, and late admission goes
//!   through the per-core [`super::inbox::Inbox`] instead of a foreign
//!   push.
//! - `steal`/`len`/`is_empty` are safe from any thread, any number of
//!   thieves.
//! - `T: Copy` (and padding-free, at most word-sized — asserted in `new`):
//!   a thief may read a slot and then lose the `top` CAS, discarding the
//!   value, and a *stale* thief may even read a slot the owner is
//!   concurrently overwriting. Slots are therefore relaxed `AtomicU64`
//!   cells (values bit-cast through one word, exactly like Lê et al.'s
//!   atomic array accesses): the racing read is well-defined, merely
//!   possibly stale — and a stale value never survives the CAS. With
//!   `Copy` types the discarded duplicate is inert. (Task ids are `usize`,
//!   so the engines lose nothing.)
//!
//! Grown-out-of buffers are *retired*, not freed: a stale thief may still
//! read them, and its CAS then fails harmlessly. Retirement takes a lock,
//! but only inside `grow` — never on the push/pop/steal fast path.

use std::fmt;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::Mutex;
use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicU64, Ordering, fence};

/// Power-of-two circular buffer; indices wrap via the mask. Slots hold `T`
/// bit-cast into a `u64` word so every access is a (relaxed) atomic —
/// see the module docs for why the stale-thief race demands this.
struct Buffer<T> {
    mask: usize,
    slots: Box<[AtomicU64]>,
    _marker: PhantomData<T>,
}

impl<T: Copy> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        assert!(
            std::mem::size_of::<T>() <= 8,
            "WsQueue items must fit one machine word (got {} bytes)",
            std::mem::size_of::<T>()
        );
        let slots =
            (0..cap).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice();
        Box::into_raw(Box::new(Buffer { mask: cap - 1, slots, _marker: PhantomData }))
    }

    fn cap(&self) -> usize {
        self.mask + 1
    }

    /// Write slot `i` (owner-only). A stale thief may load this slot
    /// concurrently — defined behaviour (both sides are atomic), and the
    /// thief's value dies with its failed `top` CAS.
    fn put(&self, i: isize, v: T) {
        let mut bits = 0u64;
        // Safety: size checked in `alloc`; `v` is a valid T.
        unsafe {
            ptr::copy_nonoverlapping(
                &v as *const T as *const u8,
                &mut bits as *mut u64 as *mut u8,
                std::mem::size_of::<T>(),
            );
        }
        self.slots[i as usize & self.mask].store(bits, Ordering::Relaxed);
    }

    /// Read slot `i`. The value is only *used* by whoever wins the CAS on
    /// `top` (or by the owner when no race is possible), so slots that
    /// were written by `put` with a valid T are the only ones ever kept.
    ///
    /// Safety: the caller must only keep the value under the conditions
    /// above (index in the live `top..bottom` window at CAS time).
    unsafe fn get(&self, i: isize) -> T {
        let bits = self.slots[i as usize & self.mask].load(Ordering::Relaxed);
        let mut v = MaybeUninit::<T>::uninit();
        unsafe {
            ptr::copy_nonoverlapping(
                &bits as *const u64 as *const u8,
                v.as_mut_ptr() as *mut u8,
                std::mem::size_of::<T>(),
            );
            v.assume_init()
        }
    }
}

const INITIAL_CAP: usize = 64;

/// Lock-free work-stealing deque. See the module docs for the ownership
/// contract (`push`/`pop` owner-only, `steal` from anywhere).
pub struct WsQueue<T> {
    /// Thief end; monotonically increasing (no ABA).
    top: AtomicIsize,
    /// Owner end.
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer<T>>,
    /// Buffers replaced by `grow`, kept alive until drop for stale thieves.
    retired: Mutex<Vec<*mut Buffer<T>>>,
}

// Safety: the slots only ever transfer `T` by copy between threads, and all
// cross-thread index handoffs go through the atomics above.
unsafe impl<T: Copy + Send> Send for WsQueue<T> {}
unsafe impl<T: Copy + Send> Sync for WsQueue<T> {}

impl<T: Copy> WsQueue<T> {
    pub fn new() -> WsQueue<T> {
        WsQueue {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(INITIAL_CAP)),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Owner-side push (bottom).
    pub fn push(&self, item: T) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        if b - t >= unsafe { &*buf }.cap() as isize {
            buf = self.grow(t, b, buf);
        }
        unsafe { (*buf).put(b, item) };
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner-side pop (bottom, LIFO).
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // Order the `bottom` decrement against thief reads of `top`.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let item = unsafe { (*buf).get(b) };
            if t == b {
                // Last element: race the thieves for it.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                return won.then_some(item);
            }
            Some(item)
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief-side steal (top, FIFO). Retries internally when it loses a
    /// race; returns `None` only when the deque was observed empty.
    pub fn steal(&self) -> Option<T> {
        loop {
            let t = self.top.load(Ordering::Acquire);
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            let buf = self.buf.load(Ordering::Acquire);
            let item = unsafe { (*buf).get(t) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(item);
            }
            // Lost to the owner or another thief; re-read and retry.
        }
    }

    /// Approximate length (exact when the queue is quiescent).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Double the buffer, copying the live range; the old buffer is
    /// retired (see the module docs), not freed.
    fn grow(&self, t: isize, b: isize, old: *mut Buffer<T>) -> *mut Buffer<T> {
        let new = Buffer::alloc(unsafe { &*old }.cap() * 2);
        for i in t..b {
            unsafe { (*new).put(i, (*old).get(i)) };
        }
        self.buf.store(new, Ordering::Release);
        self.retired.lock().unwrap().push(old);
        new
    }
}

impl<T: Copy> Default for WsQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy> fmt::Debug for WsQueue<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WsQueue").field("len", &self.len()).finish()
    }
}

impl<T> Drop for WsQueue<T> {
    fn drop(&mut self) {
        // `T: Copy` for every constructible instance ⇒ no element
        // destructors to run; only the buffers need freeing.
        unsafe { drop(Box::from_raw(self.buf.load(Ordering::Relaxed))) };
        for p in self.retired.get_mut().unwrap().drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn owner_lifo_thief_fifo() {
        let q = WsQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.steal(), Some(1)); // oldest
        assert_eq!(q.pop(), Some(3)); // newest
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.steal(), None);
    }

    #[test]
    fn concurrent_steals_lose_nothing() {
        let q = Arc::new(WsQueue::new());
        for i in 0..1000 {
            q.push(i);
        }
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.steal() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<i32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn len_tracks() {
        let q = WsQueue::new();
        assert!(q.is_empty());
        q.push(());
        q.push(());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let q = WsQueue::new();
        let n = (super::INITIAL_CAP * 5) as i64;
        for i in 0..n {
            q.push(i);
        }
        assert_eq!(q.len(), n as usize);
        // LIFO pops return everything in reverse push order across the
        // grown buffer.
        for i in (0..n).rev() {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop_steal_preserves_order_semantics() {
        let q = WsQueue::new();
        q.push(10);
        q.push(11);
        assert_eq!(q.pop(), Some(11));
        q.push(12);
        assert_eq!(q.steal(), Some(10));
        assert_eq!(q.steal(), Some(12));
        assert_eq!(q.steal(), None);
        assert_eq!(q.pop(), None);
        // Reuse after empty.
        q.push(13);
        assert_eq!(q.pop(), Some(13));
    }
}
