//! Per-core admission inboxes — lock-free MPSC handoff into a live worker.
//!
//! The Chase–Lev deque ([`super::wsq::WsQueue`]) makes `push` owner-only,
//! so the stream submitter thread (and any future external injector) can no
//! longer push late-arriving roots straight into a live worker's WSQ. The
//! inbox is the seam: producers push here (a Treiber stack — one CAS per
//! push, from any thread), and the owning worker drains the whole batch at
//! the top of its loop with a single `swap`, re-pushing the tasks into its
//! own deque. When the inbox is empty — the overwhelmingly common case —
//! the drain is a single relaxed load.
//!
//! `take_all` returns the items in FIFO push order (the detached LIFO chain
//! is reversed), so admission order is preserved end to end.

//!
//! For admission backpressure the inbox tracks its approximate `depth`
//! (pushes minus drains) plus a high-water mark: the serving layer bounds
//! per-core inbox depth by consulting `depth()` before admitting, and the
//! soak tests assert `high_water()` stays below the configured bound. Both
//! counters are relaxed — backpressure is a heuristic, not a hand-off.

use std::fmt;
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

struct Node<T> {
    next: *mut Node<T>,
    value: T,
}

/// Lock-free multi-producer inbox; see the module docs.
pub struct Inbox<T> {
    head: AtomicPtr<Node<T>>,
    /// Approximate number of undrained items (relaxed; see module docs).
    depth: AtomicUsize,
    /// Largest depth ever observed by a push (relaxed monotonic max).
    high_water: AtomicUsize,
}

// Safety: values cross threads only through the `head` atomic.
unsafe impl<T: Send> Send for Inbox<T> {}
unsafe impl<T: Send> Sync for Inbox<T> {}

impl<T> Inbox<T> {
    pub fn new() -> Inbox<T> {
        Inbox {
            head: AtomicPtr::new(ptr::null_mut()),
            depth: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
        }
    }

    /// Push from any thread (lock-free; one CAS on the uncontended path).
    pub fn push(&self, value: T) {
        // Count *before* the node becomes visible: a racing `take_all`
        // can then never subtract an item whose add is still pending
        // (depth transiently over-counts instead of underflowing).
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.high_water.fetch_max(d, Ordering::Relaxed);
        let n = Box::into_raw(Box::new(Node { next: ptr::null_mut(), value }));
        let mut cur = self.head.load(Ordering::Relaxed);
        loop {
            unsafe { (*n).next = cur };
            match self.head.compare_exchange_weak(cur, n, Ordering::Release, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Detach and return everything pushed so far, in FIFO push order.
    /// Safe from any thread (the swap is atomic), but intended for the
    /// owning worker. Costs one relaxed load when empty.
    pub fn take_all(&self) -> Vec<T> {
        if self.head.load(Ordering::Relaxed).is_null() {
            return Vec::new();
        }
        let mut p = self.head.swap(ptr::null_mut(), Ordering::Acquire);
        let mut out = Vec::new();
        while !p.is_null() {
            let boxed = unsafe { Box::from_raw(p) };
            p = boxed.next;
            out.push(boxed.value);
        }
        out.reverse();
        self.depth.fetch_sub(out.len(), Ordering::Relaxed);
        out
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Relaxed).is_null()
    }

    /// Approximate number of undrained items (backpressure input). May
    /// transiently over-count a concurrent drain or under-count a push in
    /// flight — fine for an admission heuristic.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Largest depth ever observed by a push (bounded-inbox assertions).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }
}

impl<T> Default for Inbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> fmt::Debug for Inbox<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Inbox").field("empty", &self.is_empty()).finish()
    }
}

impl<T> Drop for Inbox<T> {
    fn drop(&mut self) {
        let _ = self.take_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_single_producer() {
        let inbox = Inbox::new();
        for i in 0..10 {
            inbox.push(i);
        }
        assert_eq!(inbox.take_all(), (0..10).collect::<Vec<_>>());
        assert!(inbox.take_all().is_empty());
        assert!(inbox.is_empty());
    }

    #[test]
    fn batches_are_independent() {
        let inbox = Inbox::new();
        inbox.push('a');
        assert_eq!(inbox.take_all(), vec!['a']);
        inbox.push('b');
        inbox.push('c');
        assert_eq!(inbox.take_all(), vec!['b', 'c']);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let inbox = Inbox::new();
        let producers = 4;
        let per = 1000usize;
        let mut all = Vec::new();
        std::thread::scope(|s| {
            for p in 0..producers {
                let inbox = &inbox;
                s.spawn(move || {
                    for i in 0..per {
                        inbox.push(p * per + i);
                    }
                });
            }
            // Interleave drains with production.
            for _ in 0..100 {
                all.extend(inbox.take_all());
                std::thread::yield_now();
            }
        });
        all.extend(inbox.take_all());
        all.sort_unstable();
        assert_eq!(all, (0..producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn depth_and_high_water_track_pushes_and_drains() {
        let inbox = Inbox::new();
        assert_eq!(inbox.depth(), 0);
        assert_eq!(inbox.high_water(), 0);
        for i in 0..5 {
            inbox.push(i);
        }
        assert_eq!(inbox.depth(), 5);
        assert_eq!(inbox.high_water(), 5);
        assert_eq!(inbox.take_all().len(), 5);
        assert_eq!(inbox.depth(), 0);
        // High water is a lifetime max, not a current reading.
        assert_eq!(inbox.high_water(), 5);
        inbox.push(9);
        assert_eq!(inbox.depth(), 1);
        assert_eq!(inbox.high_water(), 5);
    }

    #[test]
    fn drop_releases_pending_values() {
        use std::sync::Arc;
        let marker = Arc::new(());
        {
            let inbox = Inbox::new();
            inbox.push(marker.clone());
            inbox.push(marker.clone());
        }
        assert_eq!(Arc::strong_count(&marker), 1);
    }
}
