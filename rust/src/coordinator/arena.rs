//! Per-run frame arena: allocation-free task frames for the real engine.
//!
//! PR 8 removed the locks from the hot path; this removes the allocator.
//! Every placement used to heap-allocate an `Arc<TaoInstance>` and every
//! member AQ push cloned it (refcount RMW), with the final member's
//! commit paying the deallocation — three allocator/refcount touches per
//! task on the execute/commit path. The arena replaces all of that with
//! one relaxed `fetch_add` per placement and a word-sized [`FrameId`]
//! flowing through the queues.
//!
//! # Design
//!
//! - **Chunked bump allocation.** The arena owns up to [`MAX_CHUNKS`]
//!   lazily-created chunks; chunk `k` holds `base << k` frames and starts
//!   at global index `base * (2^k - 1)`, so the chunk of id `i` is
//!   `log2(i / base + 1)` — a divide and a `leading_zeros`, no search.
//!   Frames are never moved: a `FrameId` handed out stays valid (at a
//!   stable address) until the arena is dropped, even while other threads
//!   trigger chunk growth. That per-chunk stability is what lets
//!   [`FrameArena::frame`] return a plain `&Frame` with no guard.
//! - **No reuse, no ABA, no reclamation protocol.** Ids are handed out by
//!   a monotone `fetch_add` and frames are freed only when the run's
//!   `Shared` is dropped (after every worker has joined). A stale
//!   `FrameId` rattling around a queue can therefore never alias a
//!   *different* task's frame, and the execute/commit path needs no
//!   epoch/hazard machinery — the quiescence argument is the thread
//!   scope's join, full stop.
//! - **Relaxed field stores, Release publication by the queue.** Frame
//!   fields are initialised with `Relaxed` stores because every handoff
//!   of a `FrameId` between threads already rides an Acquire/Release
//!   edge: the assembly queue's `push` publishes with a `Release` link
//!   store and `pop` reads it `Acquire` (see `aq.rs`), and the same holds
//!   for inbox and deque transfers. The arena itself only needs its
//!   `OnceLock` chunks' internal synchronisation.
//! - **All-atomic frames, wholly safe Rust.** Concurrent rank claims and
//!   completion countdowns were already atomic in the `Arc` era; keeping
//!   *every* field atomic means the arena contains no `unsafe` at all —
//!   Miri checks it for free alongside the lock-free queues.
//!
//! The honest trade-off: the Vyukov assembly queue still boxes one
//! intrusive node per push (documented in `aq.rs`). The arena removes the
//! frame allocation, the per-member refcount churn, and the commit-time
//! deallocation; the AQ node is the remaining allocator touch.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::dag::TaskId;
use crate::platform::Partition;

/// Index-based handle to a [`Frame`] in a [`FrameArena`]. Word-sized and
/// `Copy`, so it satisfies the lock-free queues' relaxed-slot contract
/// (`wsq.rs` bit-casts `T: Copy` word-sized values through `AtomicU64`
/// slots) as well as the boxed assembly-queue links.
pub type FrameId = usize;

/// Explicit "leader timing not yet published" sentinel for
/// [`Frame::leader_start`]/[`Frame::leader_end`]. `u64::MAX` is the bit
/// pattern of an f64 NaN, which no `Instant`-derived timestamp can
/// produce — unlike a `0` sentinel, which would be indistinguishable from
/// a legitimate `0.0`-second leader timestamp and could silently
/// misattribute a zero-duration leader share to the committer.
pub const LEADER_UNSET: u64 = u64::MAX;

/// Chunk count bound: with `base ≥ 64`, 32 doubling chunks exceed 2^37
/// frames — a run would exhaust memory long before the arena.
const MAX_CHUNKS: usize = 32;

/// Floor on the first chunk's capacity (frames); tiny DAGs still get a
/// chunk big enough that watchdog re-placements rarely grow.
const MIN_BASE: usize = 64;

/// One placed TAO: the per-run state shared by every member of its
/// partition. The all-atomic layout mirrors the retired
/// `Arc<TaoInstance>`: `task`/`leader`/`width`/`critical` are written
/// once at [`FrameArena::alloc`] and read-only afterwards; the rank
/// dispenser and completion countdown are genuinely concurrent.
#[derive(Debug)]
pub struct Frame {
    task: AtomicUsize,
    leader: AtomicUsize,
    width: AtomicUsize,
    critical: AtomicBool,
    /// Rank dispenser: arrival order claims ranks `0..width`.
    pub arrivals: AtomicUsize,
    /// Completion countdown; the rank that drops it to zero commits.
    pub remaining: AtomicUsize,
    /// Wall-clock start/end of the leader's share, f64 bits
    /// ([`LEADER_UNSET`] until the leader publishes them).
    pub leader_start: AtomicU64,
    pub leader_end: AtomicU64,
}

impl Frame {
    fn blank() -> Frame {
        Frame {
            task: AtomicUsize::new(0),
            leader: AtomicUsize::new(0),
            width: AtomicUsize::new(0),
            critical: AtomicBool::new(false),
            arrivals: AtomicUsize::new(0),
            remaining: AtomicUsize::new(0),
            leader_start: AtomicU64::new(LEADER_UNSET),
            leader_end: AtomicU64::new(LEADER_UNSET),
        }
    }

    pub fn task(&self) -> TaskId {
        self.task.load(Ordering::Relaxed)
    }

    pub fn partition(&self) -> Partition {
        Partition {
            leader: self.leader.load(Ordering::Relaxed),
            width: self.width.load(Ordering::Relaxed),
        }
    }

    pub fn critical(&self) -> bool {
        self.critical.load(Ordering::Relaxed)
    }
}

/// Chunked bump arena of [`Frame`]s. See the module docs for the
/// geometry, lifetime and memory-ordering arguments.
#[derive(Debug)]
pub struct FrameArena {
    base: usize,
    next: AtomicUsize,
    chunks: [OnceLock<Box<[Frame]>>; MAX_CHUNKS],
}

impl FrameArena {
    /// Arena sized for roughly `hint` placements before the first growth
    /// (callers pass the DAG's node count; watchdog re-placements and
    /// serving re-admissions may allocate past it, which just fills
    /// later chunks).
    pub fn with_capacity(hint: usize) -> FrameArena {
        FrameArena {
            base: hint.max(MIN_BASE),
            next: AtomicUsize::new(0),
            chunks: std::array::from_fn(|_| OnceLock::new()),
        }
    }

    /// `(chunk, slot)` of a global frame index. Chunk `k` spans
    /// `[base·(2^k − 1), base·(2^{k+1} − 1))`, so `id / base + 1` lies in
    /// `[2^k, 2^{k+1})` and its bit length recovers `k` for any base.
    fn locate(&self, id: FrameId) -> (usize, usize) {
        let q = id / self.base + 1;
        let k = (usize::BITS - 1 - q.leading_zeros()) as usize;
        (k, id - self.base * ((1 << k) - 1))
    }

    /// Allocate and initialise a fresh frame. The `Relaxed` stores are
    /// published to other threads by the queue edge that carries the
    /// returned id (module docs).
    pub fn alloc(&self, task: TaskId, partition: Partition, critical: bool) -> FrameId {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let (k, slot) = self.locate(id);
        assert!(k < MAX_CHUNKS, "frame arena exhausted ({id} frames)");
        let cap = self.base << k;
        let chunk = self.chunks[k]
            .get_or_init(|| (0..cap).map(|_| Frame::blank()).collect::<Vec<_>>().into());
        let f = &chunk[slot];
        f.task.store(task, Ordering::Relaxed);
        f.leader.store(partition.leader, Ordering::Relaxed);
        f.width.store(partition.width, Ordering::Relaxed);
        f.critical.store(critical, Ordering::Relaxed);
        f.arrivals.store(0, Ordering::Relaxed);
        f.remaining.store(partition.width, Ordering::Relaxed);
        f.leader_start.store(LEADER_UNSET, Ordering::Relaxed);
        f.leader_end.store(LEADER_UNSET, Ordering::Relaxed);
        id
    }

    /// The frame behind `id`. Panics on an id never handed out by
    /// [`FrameArena::alloc`] (an engine bug, not a recoverable state).
    pub fn frame(&self, id: FrameId) -> &Frame {
        let (k, slot) = self.locate(id);
        &self.chunks[k].get().expect("frame id from a foreign arena")[slot]
    }

    /// Frames allocated so far (monotone; nothing is ever freed early).
    pub fn allocated(&self) -> usize {
        self.next.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_maps_chunk_boundaries() {
        let a = FrameArena::with_capacity(64);
        // Chunk k spans [64·(2^k − 1), 64·(2^{k+1} − 1)).
        assert_eq!(a.locate(0), (0, 0));
        assert_eq!(a.locate(63), (0, 63));
        assert_eq!(a.locate(64), (1, 0));
        assert_eq!(a.locate(191), (1, 127));
        assert_eq!(a.locate(192), (2, 0));
        assert_eq!(a.locate(64 * 7), (3, 0));
        // Non-power-of-two base works the same way.
        let b = FrameArena::with_capacity(100);
        assert_eq!(b.locate(99), (0, 99));
        assert_eq!(b.locate(100), (1, 0));
        assert_eq!(b.locate(299), (1, 199));
        assert_eq!(b.locate(300), (2, 0));
    }

    #[test]
    fn alloc_survives_growth_with_stable_frames() {
        let a = FrameArena::with_capacity(1); // base clamps to MIN_BASE
        let n = MIN_BASE * 5; // forces several chunk growths
        let ids: Vec<FrameId> = (0..n)
            .map(|i| a.alloc(i, Partition { leader: i % 7, width: 1 + i % 3 }, i % 2 == 0))
            .collect();
        assert_eq!(a.allocated(), n);
        // Take addresses before more growth, re-check after: frames must
        // never move.
        let addrs: Vec<*const Frame> = ids.iter().map(|&id| a.frame(id) as *const _).collect();
        for i in n..n * 2 {
            a.alloc(i, Partition { leader: 0, width: 1 }, false);
        }
        for (i, &id) in ids.iter().enumerate() {
            let f = a.frame(id);
            assert_eq!(f as *const _, addrs[i]);
            assert_eq!(f.task(), i);
            assert_eq!(f.partition(), Partition { leader: i % 7, width: 1 + i % 3 });
            assert_eq!(f.critical(), i % 2 == 0);
            assert_eq!(f.remaining.load(Ordering::Relaxed), 1 + i % 3);
            assert_eq!(f.leader_end.load(Ordering::Relaxed), LEADER_UNSET);
        }
    }

    #[test]
    fn concurrent_allocs_get_distinct_live_ids() {
        let a = FrameArena::with_capacity(4);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let a = &a;
                    s.spawn(move || {
                        (0..64)
                            .map(|i| {
                                a.alloc(t * 1000 + i, Partition { leader: t, width: 1 }, false)
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let mut all: Vec<FrameId> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), 4 * 64, "duplicate frame ids under concurrent alloc");
            for &id in &all {
                let f = a.frame(id);
                assert_eq!(f.task() / 1000, f.partition().leader);
            }
        });
    }
}
