//! Serving-mode harness (`repro bench-serving`) — the continuous
//! multi-tenant analysis: what the scheduler sustains when the work never
//! drains.
//!
//! Each step of the **tenant ramp** builds an open-loop
//! [`ServingStream`] whose offered load grows with the tenant count
//! (fixed per-tenant arrival rate, QoS classes assigned round-robin over
//! latency/batch/besteffort), runs one bounded serving window through
//! [`run_serving_triple`] on the simulated backend with per-app isolated
//! baselines, and reports:
//!
//! - sustained **admissions/sec** vs the offered rate;
//! - **p99 slowdown** over the admitted apps;
//! - per-class **SLO attainment** ([`QosClass::slo_slowdown`]);
//! - the fairness loop's final **Jain index**;
//! - the backpressure counters (delays and sheds per class) and the lane
//!   high-water mark, so a ramp step that sheds is visible as such.
//!
//! The sim backend keeps the ramp deterministic for a fixed seed and
//! independent of host load; `tests/serving.rs` soaks the real engine.
//! `--json` writes `BENCH_serving.json` at the repository root.

use crate::coordinator::QosClass;
use crate::coordinator::core::ServingOpts;
use crate::dag_gen::DagParams;
use crate::exec::{RunOpts, ServingReport, run_serving_triple};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::{ServingStream, TenantSpec};

/// Harness options.
#[derive(Debug, Clone)]
pub struct ServingBenchOpts {
    /// CI smoke scale: shorter window, smaller ramp, fewer tasks per app.
    pub quick: bool,
    /// Write `BENCH_serving.json` at the repository root.
    pub json: bool,
    /// Platform scenario the serving window runs on.
    pub scenario: String,
    /// Scheduling policy under test.
    pub policy: String,
    /// Seed of the arrival process (tenant mix and instance DAGs derive
    /// their own sub-seeds from it).
    pub seed: u64,
}

impl Default for ServingBenchOpts {
    fn default() -> Self {
        ServingBenchOpts {
            quick: false,
            json: false,
            scenario: "hom4".to_string(),
            policy: "ptt-serving".to_string(),
            seed: 11,
        }
    }
}

/// Offered arrival rate per tenant (admissions/sec) — total offered load
/// of a ramp step is `RATE_PER_TENANT * tenants`.
pub const RATE_PER_TENANT: f64 = 15.0;

/// One measured step of the tenant ramp.
#[derive(Debug)]
pub struct ServingStep {
    /// Tenant count of this step.
    pub tenants: usize,
    /// Total offered arrival rate (admissions/sec).
    pub rate: f64,
    /// The full serving report (counters, per-app metrics, fairness).
    pub report: ServingReport,
}

/// Build the ramp step's tenant mix: QoS classes round-robin over
/// [`QosClass::ALL`], workload sizes staggered so tenants are not clones
/// of each other.
pub fn ramp_tenants(n: usize, quick: bool, seed: u64) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| {
            let qos = QosClass::ALL[i % QosClass::ALL.len()];
            let base = if quick { 8 } else { 14 };
            let n_tasks = base + 4 * (i % 3);
            let params = DagParams::mix(n_tasks, 2.0 + (i % 2) as f64, seed ^ (i as u64 + 1));
            TenantSpec::new(format!("tenant{i}"), params, qos)
        })
        .collect()
}

/// Run the tenant ramp. Panics on unknown scenario/policy names (the CLI
/// validates first; `run_serving_triple` reports them as errors).
pub fn run_serving_bench(opts: &ServingBenchOpts) -> Vec<ServingStep> {
    let ramp: &[usize] = if opts.quick { &[2, 4] } else { &[2, 4, 8] };
    let horizon = if opts.quick { 0.5 } else { 2.0 };
    let serving = ServingOpts::default();
    ramp.iter()
        .map(|&tenants| {
            let rate = RATE_PER_TENANT * tenants as f64;
            let stream =
                ServingStream::new(ramp_tenants(tenants, opts.quick, opts.seed), rate, opts.seed);
            let report = run_serving_triple(
                "sim",
                &opts.scenario,
                &opts.policy,
                &stream,
                horizon,
                &RunOpts { seed: opts.seed, trace: false, ..Default::default() },
                &serving,
                true,
            )
            .unwrap_or_else(|e| panic!("serving ramp step failed: {e}"));
            ServingStep { tenants, rate, report }
        })
        .collect()
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

fn counters_json(per_class: &[usize; 3]) -> Json {
    Json::obj(
        QosClass::ALL
            .iter()
            .map(|q| (q.name(), Json::Num(per_class[q.index()] as f64)))
            .collect(),
    )
}

fn step_json(s: &ServingStep) -> Json {
    let slo = s.report.slo_attainment();
    Json::obj(vec![
        ("tenants", Json::Num(s.tenants as f64)),
        ("rate", Json::Num(s.rate)),
        ("horizon", Json::Num(s.report.horizon)),
        ("offered", Json::Num(s.report.offered() as f64)),
        ("admissions_per_sec", Json::Num(s.report.admissions_per_sec())),
        ("p99_slowdown", opt_num(s.report.p99_slowdown())),
        (
            "slo_attainment",
            Json::obj(
                QosClass::ALL.iter().map(|q| (q.name(), opt_num(slo[q.index()]))).collect(),
            ),
        ),
        ("jain", opt_num(s.report.jain())),
        ("admitted", counters_json(&s.report.run.counters.admitted)),
        ("delays", counters_json(&s.report.run.counters.delays)),
        ("sheds", counters_json(&s.report.run.counters.sheds)),
        ("lane_high_water", Json::Num(s.report.run.lane_high_water as f64)),
        ("makespan", Json::Num(s.report.run.result.makespan)),
    ])
}

/// Assemble the machine-readable ramp result. Prints nothing — see
/// [`emit_serving`].
pub fn run_serving_json(opts: &ServingBenchOpts) -> Json {
    let steps = run_serving_bench(opts);
    Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("scenario", Json::Str(opts.scenario.clone())),
        ("policy", Json::Str(opts.policy.clone())),
        ("rate_per_tenant", Json::Num(RATE_PER_TENANT)),
        ("steps", Json::Arr(steps.iter().map(step_json).collect())),
    ])
}

/// Render the human-readable ramp table.
pub fn render_serving_table(result: &Json) -> Table {
    let mut t = Table::new(
        "Serving ramp: sustained admission, tail slowdown, SLO attainment, fairness",
        &[
            "tenants", "rate", "adm/s", "p99 slow", "slo lat", "slo batch", "slo be", "jain",
            "delays", "sheds", "lane hw",
        ],
    );
    if let Some(steps) = result.get("steps").and_then(Json::as_arr) {
        for s in steps {
            let num = |k: &str| s.get(k).and_then(Json::as_f64);
            let fmt = |v: Option<f64>| v.map_or("-".into(), |x| format!("{x:.3}"));
            let slo = |class: &str| {
                fmt(s.get("slo_attainment").and_then(|o| o.get(class)).and_then(Json::as_f64))
            };
            let class_sum = |k: &str| -> f64 {
                QosClass::ALL
                    .iter()
                    .filter_map(|q| s.get(k).and_then(|o| o.get(q.name())).and_then(Json::as_f64))
                    .sum()
            };
            t.row(vec![
                format!("{:.0}", num("tenants").unwrap_or(f64::NAN)),
                format!("{:.0}", num("rate").unwrap_or(f64::NAN)),
                format!("{:.1}", num("admissions_per_sec").unwrap_or(f64::NAN)),
                fmt(num("p99_slowdown")),
                slo("latency"),
                slo("batch"),
                slo("besteffort"),
                fmt(num("jain")),
                format!("{:.0}", class_sum("delays")),
                format!("{:.0}", class_sum("sheds")),
                format!("{:.0}", num("lane_high_water").unwrap_or(f64::NAN)),
            ]);
        }
    }
    t
}

/// CLI entry point: run, print, optionally write the JSON file.
pub fn emit_serving(opts: &ServingBenchOpts) -> Json {
    let result = run_serving_json(opts);
    println!("{}", render_serving_table(&result).render());
    if opts.json {
        let path = super::overhead::repo_root_file("BENCH_serving.json");
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramp_tenants_cycle_qos_and_stagger_seeds() {
        let ts = ramp_tenants(6, true, 3);
        assert_eq!(ts.len(), 6);
        for (i, t) in ts.iter().enumerate() {
            assert_eq!(t.qos, QosClass::ALL[i % 3]);
        }
        // Every class appears — the ramp exercises the whole QoS ladder.
        for q in QosClass::ALL {
            assert!(ts.iter().any(|t| t.qos == q));
        }
        assert_ne!(ts[0].params.seed, ts[1].params.seed);
    }

    #[test]
    fn quick_ramp_reports_every_step_and_serialises() {
        let opts = ServingBenchOpts { quick: true, ..Default::default() };
        let result = run_serving_json(&opts);
        let steps = result.get("steps").and_then(Json::as_arr).expect("steps array");
        assert_eq!(steps.len(), 2);
        for s in steps {
            let adm = s.get("admissions_per_sec").and_then(Json::as_f64).unwrap();
            assert!(adm > 0.0, "ramp step admitted nothing");
            // Ramp steps always admit apps (latency class cannot be shed),
            // so jain must be a number here; `null` is reserved for empty
            // windows.
            let jain = s.get("jain").and_then(Json::as_f64).expect("step admitted apps");
            assert!((0.0..=1.0 + 1e-9).contains(&jain));
            // Latency apps are never shed or delayed — the whole point of
            // the QoS ladder.
            for k in ["delays", "sheds"] {
                let v = s.get(k).and_then(|o| o.get("latency")).and_then(Json::as_f64);
                assert_eq!(v, Some(0.0));
            }
        }
        // The table renders without panicking on the real payload shape.
        let rendered = render_serving_table(&result).render();
        assert!(rendered.contains("tenants"));
    }
}
