//! The moldable-width ablation (`repro bench-elastic`): `ptt-elastic`
//! against a width-1-forced twin of the *same* DAG and seed.
//!
//! The question under test is the tentpole claim of the elastic seam: does
//! letting the policy choose partition widths (capped by each task's
//! moldability descriptor, narrowed under interference) actually buy
//! makespan over running every TAO at width 1? Each cell runs the same
//! generated DAG twice on the sim backend under the same policy — once as
//! generated (class-default moldability caps) and once through
//! [`crate::coordinator::TaoDag::with_max_width_cap`]`(1)`, which forces
//! every placement narrow without touching structure, seed or costs — so
//! the two runs differ *only* in the width freedom.
//!
//! Three scenario roles:
//! - **scaling** (`hom64`, `biglittle44`) — idle width-divisible machines
//!   where the elastic win should be largest (wide critical TAOs shorten
//!   the critical path);
//! - **interference** (`interference20`, `dvfs8`) — episode scenarios
//!   where elastic must *narrow* (flag-avoidance + width cap) and is
//!   accepted if it never loses more than ~5% to the width-1 twin;
//! - **commbound** (`commbound-tx2`) — the bandwidth-starved point, where
//!   wide partitions aggregate cache and dodge DRAM.
//!
//! Per row: both makespans, `speedup = width1 / elastic` (> 1 means
//! elastic wins) and the share of TAOs the elastic run placed wide.
//! `--json` writes `BENCH_elastic.json` at the repository root; CI runs
//! `repro bench-elastic --quick --json` and uploads it, and a
//! seed-estimate copy is committed for schema stability.

use crate::dag_gen::{DagParams, generate};
use crate::exec::{RunOpts, run_triple};
use crate::util::json::Json;
use crate::util::table::Table;

/// `(scenario, role)` cells — see the module docs for the roles.
pub const ELASTIC_CELLS: [(&str, &str); 5] = [
    ("hom64", "scaling"),
    ("biglittle44", "scaling"),
    ("interference20", "interference"),
    ("dvfs8", "interference"),
    ("commbound-tx2", "commbound"),
];

/// Harness options.
#[derive(Debug, Clone)]
pub struct ElasticOpts {
    /// CI smoke scale: 1 seed, ≤ 40-task DAGs.
    pub quick: bool,
    /// Write `BENCH_elastic.json` at the repository root.
    pub json: bool,
    /// Seeds per cell (each seed generates one DAG shared by both twins).
    pub seeds: usize,
    /// Tasks per generated DAG.
    pub tasks: usize,
    /// Average-parallelism knob of the DAG generator.
    pub parallelism: f64,
    /// Base seed; cell seeds are `seed + i`.
    pub seed: u64,
}

impl Default for ElasticOpts {
    fn default() -> Self {
        ElasticOpts {
            quick: false,
            json: false,
            seeds: 3,
            tasks: 120,
            parallelism: 4.0,
            seed: 0xE7,
        }
    }
}

/// Assemble the machine-readable ablation. Prints nothing — see
/// [`emit_elastic`]. Panics on registry inconsistencies (the scenario set
/// is compiled in).
pub fn run_elastic_json(opts: &ElasticOpts) -> Json {
    let seeds = if opts.quick { 1 } else { opts.seeds.max(1) };
    let tasks = if opts.quick { opts.tasks.min(40) } else { opts.tasks };
    let mut rows = Vec::new();
    for (scen, role) in ELASTIC_CELLS {
        for si in 0..seeds {
            let seed = opts.seed + si as u64;
            // One DAG per (cell, seed); the width-1 twin shares structure,
            // costs and seed — only the moldability caps differ.
            let (dag, _) = generate(&DagParams::mix(tasks, opts.parallelism, seed));
            let narrow = dag.with_max_width_cap(1);
            let run_opts = RunOpts { seed, ..Default::default() };
            let elastic = run_triple("sim", scen, "ptt-elastic", &dag, &run_opts)
                .unwrap_or_else(|e| panic!("elastic {scen}/{seed}: {e}"));
            let width1 = run_triple("sim", scen, "ptt-elastic", &narrow, &run_opts)
                .unwrap_or_else(|e| panic!("width1 {scen}/{seed}: {e}"));
            let (me, m1) = (elastic.result.makespan, width1.result.makespan);
            let wide_pct: f64 = elastic
                .result
                .width_percentages()
                .into_iter()
                .filter(|&(w, _)| w > 1)
                .map(|(_, pct)| pct)
                .sum();
            rows.push(Json::obj(vec![
                ("scenario", Json::Str(scen.to_string())),
                ("role", Json::Str(role.to_string())),
                ("seed", Json::Num(seed as f64)),
                ("tasks", Json::Num(dag.len() as f64)),
                ("makespan_elastic", Json::Num(me)),
                ("makespan_width1", Json::Num(m1)),
                ("speedup", Json::Num(m1 / me)),
                ("wide_pct", Json::Num(wide_pct)),
            ]));
        }
    }
    Json::obj(vec![
        ("bench", Json::Str("elastic".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("tasks", Json::Num(tasks as f64)),
        ("parallelism", Json::Num(opts.parallelism)),
        ("seeds", Json::Num(seeds as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// Render the human-readable ablation, averaging seeds per scenario (the
/// JSON keeps per-seed rows).
pub fn render_elastic_table(result: &Json) -> Table {
    let mut t = Table::new(
        "Elastic width ablation: ptt-elastic vs width-1-forced twin (same DAG/seed, sim)",
        &["scenario", "role", "elastic", "width-1", "speedup", "wide %"],
    );
    let key = |r: &Json, k: &str| -> String {
        r.get(k).and_then(Json::as_str).unwrap_or("").to_string()
    };
    if let Some(rows) = result.get("rows").and_then(Json::as_arr) {
        let mut i = 0;
        while i < rows.len() {
            let (sc, role) = (key(&rows[i], "scenario"), key(&rows[i], "role"));
            let mut group: Vec<&Json> = Vec::new();
            while i < rows.len() && key(&rows[i], "scenario") == sc {
                group.push(&rows[i]);
                i += 1;
            }
            let mean = |k: &str| -> Option<f64> {
                let vals: Vec<f64> =
                    group.iter().filter_map(|r| r.get(k).and_then(Json::as_f64)).collect();
                if vals.is_empty() {
                    None
                } else {
                    Some(vals.iter().sum::<f64>() / vals.len() as f64)
                }
            };
            let num = |k: &str, digits: usize| -> String {
                mean(k).map_or("-".to_string(), |v| format!("{v:.digits$}"))
            };
            t.row(vec![
                sc,
                role,
                num("makespan_elastic", 4),
                num("makespan_width1", 4),
                mean("speedup").map_or("-".to_string(), |s| format!("{s:.3}x")),
                mean("wide_pct").map_or("-".to_string(), |p| format!("{p:.1}%")),
            ]);
        }
    }
    t
}

/// CLI entry point: run, print, optionally write the JSON file.
pub fn emit_elastic(opts: &ElasticOpts) -> Json {
    let result = run_elastic_json(opts);
    println!("{}", render_elastic_table(&result).render());
    if opts.json {
        let path = super::overhead::repo_root_file("BENCH_elastic.json");
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_of(result: &Json) -> &[Json] {
        result.get("rows").and_then(Json::as_arr).expect("rows array")
    }

    #[test]
    fn elastic_beats_or_matches_its_width1_twin() {
        // The PR's acceptance criterion, run at smoke scale: elastic must
        // win outright on at least one scaling scenario and may never
        // lose more than 5% on the interference scenarios (where its job
        // is to narrow gracefully, not to win).
        let opts = ElasticOpts { quick: true, ..Default::default() };
        let result = run_elastic_json(&opts);
        let rows = rows_of(&result);
        assert_eq!(rows.len(), ELASTIC_CELLS.len(), "one row per cell at quick scale");
        let field = |r: &Json, k: &str| r.get(k).and_then(Json::as_f64).expect(k);
        let role = |r: &Json| r.get("role").and_then(Json::as_str).unwrap_or("").to_string();
        let mut scaling_win = false;
        for r in rows {
            let sc = r.get("scenario").and_then(Json::as_str).unwrap_or("?");
            let speedup = field(r, "speedup");
            assert!(speedup.is_finite() && speedup > 0.0, "{sc}: speedup {speedup}");
            match role(r).as_str() {
                "scaling" => {
                    if speedup > 1.0 {
                        scaling_win = true;
                    }
                    // Wide choices must actually happen where they pay.
                    assert!(field(r, "wide_pct") > 0.0, "{sc}: elastic never went wide");
                }
                "interference" => assert!(
                    speedup >= 0.95,
                    "{sc}: elastic loses {:.1}% to the width-1 twin",
                    100.0 * (1.0 - speedup)
                ),
                _ => {}
            }
        }
        assert!(scaling_win, "elastic beat the width-1 twin on no scaling scenario");
    }

    #[test]
    fn table_aggregates_seeds_per_scenario() {
        let row = |seed: f64, speedup: f64| {
            Json::obj(vec![
                ("scenario", Json::Str("hom64".into())),
                ("role", Json::Str("scaling".into())),
                ("seed", Json::Num(seed)),
                ("makespan_elastic", Json::Num(1.0)),
                ("makespan_width1", Json::Num(speedup)),
                ("speedup", Json::Num(speedup)),
                ("wide_pct", Json::Num(50.0)),
            ])
        };
        let result =
            Json::obj(vec![("rows", Json::Arr(vec![row(1.0, 1.2), row(2.0, 1.4)]))]);
        let rendered = render_elastic_table(&result).render();
        assert!(rendered.contains("1.300x"), "mean of 1.2 and 1.4:\n{rendered}");
        assert_eq!(rendered.matches("hom64").count(), 1, "one aggregated row:\n{rendered}");
    }
}
