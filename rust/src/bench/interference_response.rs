//! Interference-response harness (`repro bench-interference`) — the §5.3
//! analysis, end to end and in **both** execution backends.
//!
//! The paper's dynamic-heterogeneity claim is a *response shape*: when a
//! background process squeezes some cores mid-run, the scheduler's critical
//! tasks must leave those cores within a bounded window and return after
//! the episode ends. This harness reproduces that analysis as a per-interval
//! time series, for the plain `performance-based` policy and the PTT v2
//! `ptt-adaptive` policy side by side:
//!
//! - per-core **PTT width-1 values** (sampled every interval: virtual-time
//!   interval probe in the sim, a wall-clock sampler thread in the real
//!   engine — the table is shared, so reads are free);
//! - per-core **change-detector flag state** ([`Ptt::core_flags`]);
//! - **critical-task placement counts** on victim vs non-victim cores,
//!   bucketed from the trace.
//!
//! The victim set and episode window are derived from the scenario's own
//! [`EpisodeSchedule`] — no silently drifting copies. `--json` writes the
//! machine-readable series to `BENCH_interference_response.json` at the
//! repository root; `tests/interference_response.rs` asserts the *shape*
//! (adaptive cuts critical placements on victims during the episode and
//! recovers after, plain `ptt` lags), never exact values.

use crate::coordinator::metrics::RunResult;
use crate::coordinator::ptt::Ptt;
use crate::coordinator::scheduler::policy_by_name;
use crate::coordinator::worker::{RealEngineOpts, run_dag_real};
use crate::dag_gen::{DagParams, generate};
use crate::kernels::KernelSizes;
use crate::platform::{KernelClass, Platform, scenarios};
use crate::sim::{SimOpts, run_dag_sim};
use crate::util::json::Json;
use crate::util::table::Table;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Harness options.
#[derive(Debug, Clone)]
pub struct InterferenceOpts {
    /// CI smoke scale (smaller workload; the episode window still has to
    /// be spanned, so the floor is higher than other quick modes).
    pub quick: bool,
    /// Write `BENCH_interference_response.json` at the repository root.
    pub json: bool,
    /// `"sim"`, `"real"`, or `"both"`.
    pub backend: String,
    /// Platform scenario with a non-empty episode schedule.
    pub scenario: String,
    /// Seed for DAG generation and engine randomness.
    pub seed: u64,
}

impl Default for InterferenceOpts {
    fn default() -> Self {
        InterferenceOpts {
            quick: false,
            json: false,
            backend: "both".to_string(),
            scenario: "interference20".to_string(),
            seed: 7,
        }
    }
}

/// The two policies the response analysis compares.
pub const INTERFERENCE_POLICIES: [&str; 2] = ["performance-based", "ptt-adaptive"];

/// Sampling interval of the time series, seconds (virtual or wall).
pub const SAMPLE_INTERVAL: f64 = 0.01;

/// One interval of the response time series.
#[derive(Debug, Clone)]
pub struct IntervalPoint {
    /// End of the interval (seconds since run start).
    pub t: f64,
    /// Mean PTT width-1 long-run estimate over the victim cores.
    pub victim_w1: f64,
    /// Mean PTT width-1 long-run estimate over all other cores.
    pub other_w1: f64,
    /// Victim cores currently flagged by the change detector.
    pub victims_flagged: usize,
    /// Critical-task placements whose partition touches a victim core.
    pub crit_victims: usize,
    /// Critical-task placements entirely off the victim cores.
    pub crit_other: usize,
    /// All placements starting in this interval.
    pub tasks: usize,
}

/// Critical-placement accounting for one phase (pre/during/post episode).
#[derive(Debug, Clone, Copy)]
pub struct PhaseSummary {
    /// Critical placements in the phase.
    pub n_crit: usize,
    /// ...of which touch a victim core.
    pub on_victims: usize,
}

impl PhaseSummary {
    /// Fraction of the phase's critical placements touching victims
    /// (0 when the phase saw no critical tasks).
    pub fn share(&self) -> f64 {
        if self.n_crit == 0 { 0.0 } else { self.on_victims as f64 / self.n_crit as f64 }
    }
}

/// The full response series of one `(backend, policy)` run.
#[derive(Debug, Clone)]
pub struct ResponseRun {
    pub backend: String,
    pub policy: String,
    pub makespan: f64,
    pub n_tasks: usize,
    pub points: Vec<IntervalPoint>,
    pub pre: PhaseSummary,
    pub during: PhaseSummary,
    pub post: PhaseSummary,
    /// Max victim cores simultaneously flagged in any sampled interval.
    pub peak_victims_flagged: usize,
}

/// Derive the victim core set and the `[start, end)` envelope of a
/// scenario's episode schedule (union over episodes).
pub fn victims_and_window(plat: &Platform) -> (Vec<usize>, (f64, f64)) {
    let mut victims: Vec<usize> =
        plat.episodes.episodes.iter().flat_map(|e| e.cores.iter().copied()).collect();
    victims.sort_unstable();
    victims.dedup();
    let start =
        plat.episodes.episodes.iter().map(|e| e.t_start).fold(f64::INFINITY, f64::min);
    let end = plat.episodes.episodes.iter().map(|e| e.t_end).fold(0.0, f64::max);
    (victims, (start, end))
}

/// Assemble the per-interval series from a trace plus aligned PTT samples
/// (`samples[i]` ≈ state at the end of interval `i`).
fn assemble(
    backend: &str,
    policy: &str,
    result: &RunResult,
    samples: &[(Vec<f64>, Vec<bool>)],
    victims: &[usize],
    window: (f64, f64),
) -> ResponseRun {
    let iv = SAMPLE_INTERVAL;
    let n_intervals = ((result.makespan / iv).ceil() as usize).max(samples.len()).max(1);
    let touches_victims = |r: &crate::coordinator::metrics::TraceRecord| {
        r.partition.cores().any(|c| victims.contains(&c))
    };
    let mut points: Vec<IntervalPoint> = (0..n_intervals)
        .map(|i| {
            // The last interval of a run often has no sample of its own
            // (the final event lands between boundaries) — carry the last
            // known PTT state forward rather than emitting a spurious
            // all-zeros collapse at the end of the series.
            let (victim_w1, other_w1, victims_flagged) =
                match samples.get(i).or_else(|| samples.last()) {
                    Some((w1, flags)) => {
                        let vmean = mean_over(w1, |c| victims.contains(&c));
                        let omean = mean_over(w1, |c| !victims.contains(&c));
                        let nf =
                            victims.iter().filter(|&&v| flags.get(v) == Some(&true)).count();
                        (vmean, omean, nf)
                    }
                    None => (0.0, 0.0, 0),
                };
            IntervalPoint {
                t: (i + 1) as f64 * iv,
                victim_w1,
                other_w1,
                victims_flagged,
                crit_victims: 0,
                crit_other: 0,
                tasks: 0,
            }
        })
        .collect();
    let (mut pre, mut during, mut post) = (
        PhaseSummary { n_crit: 0, on_victims: 0 },
        PhaseSummary { n_crit: 0, on_victims: 0 },
        PhaseSummary { n_crit: 0, on_victims: 0 },
    );
    for r in &result.records {
        let idx = ((r.t_start / iv) as usize).min(n_intervals - 1);
        points[idx].tasks += 1;
        if r.critical {
            let on = touches_victims(r);
            if on {
                points[idx].crit_victims += 1;
            } else {
                points[idx].crit_other += 1;
            }
            let phase = if r.t_start < window.0 {
                &mut pre
            } else if r.t_start < window.1 {
                &mut during
            } else {
                &mut post
            };
            phase.n_crit += 1;
            if on {
                phase.on_victims += 1;
            }
        }
    }
    let peak = points.iter().map(|p| p.victims_flagged).max().unwrap_or(0);
    ResponseRun {
        backend: backend.to_string(),
        policy: policy.to_string(),
        makespan: result.makespan,
        n_tasks: result.records.len(),
        points,
        pre,
        during,
        post,
        peak_victims_flagged: peak,
    }
}

fn mean_over(w1: &[f64], keep: impl Fn(usize) -> bool) -> f64 {
    let vals: Vec<f64> =
        w1.iter().enumerate().filter(|(c, _)| keep(*c)).map(|(_, &v)| v).collect();
    if vals.is_empty() { 0.0 } else { vals.iter().sum::<f64>() / vals.len() as f64 }
}

/// Run one `(backend, policy)` response experiment on `scenario` and build
/// its time series. Panics on unknown names (the CLI validates first).
pub fn run_response(
    backend: &str,
    scenario: &str,
    policy_name: &str,
    opts: &InterferenceOpts,
) -> ResponseRun {
    let plat = scenarios::by_name(scenario)
        .unwrap_or_else(|| panic!("unknown platform scenario '{scenario}'"));
    assert!(
        !plat.episodes.is_empty(),
        "scenario '{scenario}' has no episodes — nothing to respond to"
    );
    let (victims, window) = victims_and_window(&plat);
    let policy = policy_by_name(policy_name, plat.topo.n_cores())
        .unwrap_or_else(|| panic!("unknown policy '{policy_name}'"));
    match backend {
        "sim" => {
            // Virtual time: the workload must span the episode window plus
            // a recovery tail. At ~17-21k MatMul tasks/s on the saturated
            // 20-core model, 10k tasks run ~0.5s of virtual time — about
            // 2x the interference20 window end.
            let n_tasks = if opts.quick { 10_000 } else { 20_000 };
            let (dag, _) =
                generate(&DagParams::single(KernelClass::MatMul, n_tasks, 16.0, opts.seed));
            let run = run_dag_sim(
                &dag,
                &plat,
                policy.as_ref(),
                None,
                &SimOpts {
                    seed: opts.seed,
                    ptt_probe: None,
                    probe_interval: Some(SAMPLE_INTERVAL),
                },
            )
            .unwrap();
            let samples: Vec<(Vec<f64>, Vec<bool>)> = run
                .interval_samples
                .into_iter()
                .map(|s| (s.w1, s.flags))
                .collect();
            assemble("sim", policy_name, &run.result, &samples, &victims, window)
        }
        "real" => {
            // Wall clock: size the workload so the run outlives the episode
            // window on this host — calibrate one payload, then target
            // ~2.2x the window end of busy time per online CPU.
            let sizes = KernelSizes { matmul_n: 64, ..KernelSizes::small() };
            let probe = sizes.instantiate(KernelClass::MatMul, opts.seed);
            let t = Instant::now();
            let reps = 16;
            for _ in 0..reps {
                probe.execute(0, 1);
            }
            let per_task = (t.elapsed().as_secs_f64() / reps as f64).max(1e-6);
            let online = crate::platform::detect::online_cpus();
            let target_wall = window.1 * 2.2;
            let n_tasks = ((target_wall * online as f64 / per_task) as usize)
                .clamp(2_000, if opts.quick { 24_000 } else { 96_000 });
            let (dag, _) = generate(
                &DagParams::single(KernelClass::MatMul, n_tasks, 16.0, opts.seed)
                    .with_payloads(sizes),
            );
            let ptt = Ptt::new(dag.n_types(), &plat.topo);
            let stop = AtomicBool::new(false);
            let mut samples: Vec<(Vec<f64>, Vec<bool>)> = Vec::new();
            let mut result: Option<RunResult> = None;
            std::thread::scope(|s| {
                let sampler = s.spawn(|| {
                    // Wall-clock sampler: the PTT is shared, reads are racy
                    // by design (never torn), so sampling costs the run
                    // nothing. If the thread is starved past several
                    // boundaries (oversubscribed CI host), the missed
                    // slots are filled by carrying the *previous* state
                    // forward — never by backfilling the current state
                    // into the past, which would skew flag-onset timing.
                    let t0 = Instant::now();
                    let mut out: Vec<(Vec<f64>, Vec<bool>)> = Vec::new();
                    while !stop.load(Ordering::Acquire) {
                        let next = (out.len() + 1) as f64 * SAMPLE_INTERVAL;
                        let behind = next - t0.elapsed().as_secs_f64();
                        if behind > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(behind.min(0.002)));
                            continue;
                        }
                        let obs: (Vec<f64>, Vec<bool>) = (
                            (0..plat.topo.n_cores()).map(|c| ptt.read(0, c, 1)).collect(),
                            ptt.core_flags(),
                        );
                        let reached =
                            (t0.elapsed().as_secs_f64() / SAMPLE_INTERVAL) as usize;
                        while out.len() + 1 < reached {
                            let fill = out.last().cloned().unwrap_or_else(|| obs.clone());
                            out.push(fill);
                        }
                        out.push(obs);
                    }
                    out
                });
                result = Some(
                    run_dag_real(
                        &dag,
                        &plat.topo,
                        policy.as_ref(),
                        Some(&ptt),
                        &RealEngineOpts {
                            seed: opts.seed,
                            episodes: plat.episodes.clone(),
                            ..Default::default()
                        },
                    )
                    .unwrap(),
                );
                stop.store(true, Ordering::Release);
                samples = sampler.join().expect("sampler thread");
            });
            let result = result.expect("run finished");
            assemble("real", policy_name, &result, &samples, &victims, window)
        }
        other => panic!("unknown backend '{other}' (sim|real)"),
    }
}

/// Run the configured backends × [`INTERFERENCE_POLICIES`] and assemble
/// the machine-readable result. Prints nothing — see [`emit_interference`].
pub fn run_interference(opts: &InterferenceOpts) -> Json {
    let plat = scenarios::by_name(&opts.scenario)
        .unwrap_or_else(|| panic!("unknown platform scenario '{}'", opts.scenario));
    let (victims, window) = victims_and_window(&plat);
    let backends: Vec<&str> = match opts.backend.as_str() {
        "both" => vec!["sim", "real"],
        "sim" => vec!["sim"],
        "real" => vec!["real"],
        other => panic!("unknown backend '{other}' (sim|real|both)"),
    };
    let mut runs = Vec::new();
    for be in backends {
        for policy in INTERFERENCE_POLICIES {
            let r = run_response(be, &opts.scenario, policy, opts);
            runs.push(response_to_json(&r));
        }
    }
    Json::obj(vec![
        ("bench", Json::Str("interference_response".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("scenario", Json::Str(opts.scenario.clone())),
        ("victims", Json::Arr(victims.iter().map(|&v| Json::Num(v as f64)).collect())),
        ("window", Json::Arr(vec![Json::Num(window.0), Json::Num(window.1)])),
        ("interval", Json::Num(SAMPLE_INTERVAL)),
        ("runs", Json::Arr(runs)),
    ])
}

fn phase_json(p: &PhaseSummary) -> Json {
    Json::obj(vec![
        ("n_crit", Json::Num(p.n_crit as f64)),
        ("on_victims", Json::Num(p.on_victims as f64)),
        ("share", Json::Num(p.share())),
    ])
}

fn response_to_json(r: &ResponseRun) -> Json {
    Json::obj(vec![
        ("backend", Json::Str(r.backend.clone())),
        ("policy", Json::Str(r.policy.clone())),
        ("makespan", Json::Num(r.makespan)),
        ("n_tasks", Json::Num(r.n_tasks as f64)),
        ("peak_victims_flagged", Json::Num(r.peak_victims_flagged as f64)),
        (
            "summary",
            Json::obj(vec![
                ("pre", phase_json(&r.pre)),
                ("during", phase_json(&r.during)),
                ("post", phase_json(&r.post)),
            ]),
        ),
        (
            "series",
            Json::Arr(
                r.points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("t", Json::Num(p.t)),
                            ("victim_w1", Json::Num(p.victim_w1)),
                            ("other_w1", Json::Num(p.other_w1)),
                            ("victims_flagged", Json::Num(p.victims_flagged as f64)),
                            ("crit_victims", Json::Num(p.crit_victims as f64)),
                            ("crit_other", Json::Num(p.crit_other as f64)),
                            ("tasks", Json::Num(p.tasks as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Render the human-readable summary table.
pub fn render_interference_tables(result: &Json) -> Vec<Table> {
    let mut t = Table::new(
        "Interference response: critical-task share on victim cores per phase",
        &["backend", "policy", "pre", "during", "post", "crit during", "peak flags", "makespan"],
    );
    if let Some(runs) = result.get("runs").and_then(Json::as_arr) {
        for r in runs {
            let share = |phase: &str| -> String {
                r.get("summary")
                    .and_then(|s| s.get(phase))
                    .and_then(|p| p.get("share"))
                    .and_then(Json::as_f64)
                    .map_or("-".into(), |v| format!("{v:.3}"))
            };
            let num = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            let crit_during = r
                .get("summary")
                .and_then(|s| s.get("during"))
                .and_then(|p| p.get("n_crit"))
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN);
            t.row(vec![
                r.get("backend").and_then(Json::as_str).unwrap_or("?").to_string(),
                r.get("policy").and_then(Json::as_str).unwrap_or("?").to_string(),
                share("pre"),
                share("during"),
                share("post"),
                format!("{crit_during:.0}"),
                format!("{:.0}", num("peak_victims_flagged")),
                format!("{:.3}s", num("makespan")),
            ]);
        }
    }
    vec![t]
}

/// CLI entry point: run, print, optionally write the JSON file.
pub fn emit_interference(opts: &InterferenceOpts) -> Json {
    let result = run_interference(opts);
    for t in render_interference_tables(&result) {
        println!("{}", t.render());
    }
    if opts.json {
        let path = super::overhead::repo_root_file("BENCH_interference_response.json");
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::TraceRecord;
    use crate::platform::{Episode, EpisodeSchedule, Partition};

    #[test]
    fn victims_and_window_derive_from_schedule() {
        let plat = scenarios::by_name("interference20").unwrap();
        let (victims, window) = victims_and_window(&plat);
        assert_eq!(victims, vec![0, 1]);
        assert!((window.0 - 0.05).abs() < 1e-12);
        assert!((window.1 - 0.25).abs() < 1e-12);
        // Multi-episode envelope.
        let p = Platform::homogeneous(4).with_episodes(EpisodeSchedule::new(vec![
            Episode::dvfs(vec![1], 0.1, 0.2, 0.5),
            Episode::interference(vec![2], 0.15, 0.4, 0.5, 0.0),
        ]));
        let (v, w) = victims_and_window(&p);
        assert_eq!(v, vec![1, 2]);
        assert_eq!(w, (0.1, 0.4));
    }

    fn rec(critical: bool, leader: usize, t_start: f64) -> TraceRecord {
        TraceRecord {
            task: 0,
            app_id: 0,
            class: KernelClass::MatMul,
            type_id: 0,
            critical,
            partition: Partition { leader, width: 1 },
            t_start,
            t_end: t_start + 0.001,
        }
    }

    #[test]
    fn assemble_buckets_and_phases() {
        let result = RunResult {
            policy: "x".into(),
            platform: "y".into(),
            makespan: 0.05,
            records: vec![
                rec(true, 0, 0.001),  // pre, on victim
                rec(true, 3, 0.005),  // pre, off
                rec(true, 1, 0.015),  // during, on victim
                rec(false, 0, 0.016), // during, non-critical
                rec(true, 2, 0.021),  // during, off
                rec(true, 0, 0.041),  // post, on victim
            ],
            bound: None,
        };
        let samples = vec![
            (vec![1.0, 1.0, 1.0, 1.0], vec![false, false, false, false]),
            (vec![2.0, 2.0, 1.0, 1.0], vec![true, true, false, false]),
        ];
        let r = assemble("sim", "ptt-adaptive", &result, &samples, &[0, 1], (0.01, 0.03));
        assert_eq!(r.points.len(), 5);
        assert_eq!(r.pre.n_crit, 2);
        assert_eq!(r.pre.on_victims, 1);
        assert_eq!(r.during.n_crit, 2);
        assert_eq!(r.during.on_victims, 1);
        assert_eq!(r.post.n_crit, 1);
        assert_eq!(r.post.on_victims, 1);
        assert!((r.pre.share() - 0.5).abs() < 1e-12);
        // Interval 0: two tasks; interval 1: flags on both victims.
        assert_eq!(r.points[0].tasks, 2);
        assert_eq!(r.points[1].victims_flagged, 2);
        assert!((r.points[1].victim_w1 - 2.0).abs() < 1e-12);
        assert!((r.points[1].other_w1 - 1.0).abs() < 1e-12);
        assert_eq!(r.peak_victims_flagged, 2);
        // JSON round-trips with the documented fields.
        let j = response_to_json(&r);
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), "ptt-adaptive");
        assert_eq!(j.get("series").unwrap().as_arr().unwrap().len(), 5);
        assert!(j.get("summary").unwrap().get("during").unwrap().get("share").is_some());
    }

    #[test]
    fn phase_share_handles_empty_phase() {
        let p = PhaseSummary { n_crit: 0, on_victims: 0 };
        assert_eq!(p.share(), 0.0);
    }
}
