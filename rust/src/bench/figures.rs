//! Regenerators for every figure in the paper's evaluation (§5).
//!
//! Each `figN` function reruns the corresponding experiment on the
//! modelled platform and returns the tables the paper plots; callers
//! print them and write CSVs (both the `repro` CLI and the `cargo bench`
//! harnesses go through here). Absolute numbers come from the analytic
//! platform model (DESIGN.md §Substitutions); the claims under test are
//! the *shapes*: who wins, by what factor, where the effect decays.

use crate::coordinator::dag::TaoDag;
use crate::coordinator::metrics::RunResult;
use crate::coordinator::scheduler::{HomogeneousWs, PerformanceBased, Policy, policy_by_name};
use crate::coordinator::ptt::Ptt;
use crate::dag_gen::{DagParams, generate};
use crate::exec::{ExecutionBackend, RunOpts, SimBackend, backend_by_name};
use crate::platform::{Episode, EpisodeSchedule, KernelClass, Platform};
use crate::util::stats;
use crate::util::table::{Table, f2, f3};
use crate::vgg::{VggConfig, build_dag as build_vgg_dag};

/// Shared experiment knobs.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Independent seeds averaged per cell.
    pub seeds: usize,
    /// Scale down task counts (CI smoke mode).
    pub quick: bool,
    /// Execution backend by registry name (`"sim"` reproduces the paper's
    /// modelled platforms; `"real"` measures the host).
    pub backend: String,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { seeds: 3, quick: false, backend: "sim".to_string() }
    }
}

impl BenchOpts {
    pub fn quick() -> BenchOpts {
        BenchOpts { seeds: 1, quick: true, backend: "sim".to_string() }
    }

    /// Resolve the configured execution backend.
    pub fn exec_backend(&self) -> Box<dyn ExecutionBackend> {
        backend_by_name(&self.backend)
            .unwrap_or_else(|| panic!("unknown backend '{}'", self.backend))
    }

    fn scale(&self, n: usize) -> usize {
        if self.quick { (n / 8).max(32) } else { n }
    }
}

/// Some figures are inherently virtual-time experiments and always run on
/// [`SimBackend`]; tell the user when their `--backend` choice is ignored.
fn warn_sim_pinned(opts: &BenchOpts, fig: &str, why: &str) {
    if opts.backend != "sim" {
        eprintln!("[{fig}] pinned to the simulated backend ({why}); ignoring backend '{}'", opts.backend);
    }
}

/// Run one random-DAG config under one policy, mean throughput over seeds.
fn mean_throughput(
    backend: &dyn ExecutionBackend,
    plat: &Platform,
    make_params: impl Fn(u64) -> DagParams,
    policy: &dyn Policy,
    seeds: usize,
) -> f64 {
    let tps: Vec<f64> = (0..seeds as u64)
        .map(|s| {
            let (dag, _) = generate(&make_params(1000 + s));
            let opts = RunOpts { seed: 42 + s, ..Default::default() };
            backend.run(&dag, plat, policy, None, &opts).unwrap().result.throughput()
        })
        .collect();
    stats::mean(&tps)
}

pub const FIG5_TASKS: [usize; 5] = [250, 500, 1000, 2000, 4000];
pub const PARALLELISMS: [usize; 5] = [1, 2, 4, 8, 16];

/// **Fig 5** — throughput heatmaps (tasks × parallelism) on the TX2 model
/// for the performance-based and homogeneous schedulers, plus the speedup
/// grid (the paper's headline "up to 3.25×" lives in this grid's max).
pub fn fig5(opts: &BenchOpts) -> Vec<Table> {
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let hdr: Vec<String> = std::iter::once("par\\tasks".to_string())
        .chain(FIG5_TASKS.iter().map(|t| t.to_string()))
        .collect();
    let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
    let mut t_perf = Table::new("Fig 5(a): performance-based scheduler, throughput [tasks/s]", &hdr_refs);
    let mut t_homo = Table::new("Fig 5(b): homogeneous scheduler, throughput [tasks/s]", &hdr_refs);
    let mut t_speed = Table::new("Fig 5 derived: speedup (perf / homo)", &hdr_refs);
    let mut max_speedup: f64 = 0.0;
    for &par in &PARALLELISMS {
        let mut row_p = vec![par.to_string()];
        let mut row_h = vec![par.to_string()];
        let mut row_s = vec![par.to_string()];
        for &tasks in &FIG5_TASKS {
            let tasks = opts.scale(tasks);
            let mk = |seed| DagParams::mix(tasks, par as f64, seed);
            let perf = mean_throughput(backend.as_ref(), &plat, mk, &PerformanceBased, opts.seeds);
            let homo = mean_throughput(backend.as_ref(), &plat, mk, &HomogeneousWs, opts.seeds);
            let sp = perf / homo;
            max_speedup = max_speedup.max(sp);
            row_p.push(f2(perf));
            row_h.push(f2(homo));
            row_s.push(f3(sp));
        }
        t_perf.row(row_p);
        t_homo.row(row_h);
        t_speed.row(row_s);
    }
    t_speed.title = format!("{} — max {:.2}× (paper: up to 3.25×)", t_speed.title, max_speedup);
    vec![t_perf, t_homo, t_speed]
}

/// Kernel mixes of Fig 6/7.
pub fn fig6_workloads() -> Vec<(&'static str, Option<KernelClass>)> {
    vec![
        ("matmul", Some(KernelClass::MatMul)),
        ("sort", Some(KernelClass::Sort)),
        ("copy", Some(KernelClass::Copy)),
        ("mix", None),
    ]
}

fn fig6_params(kind: Option<KernelClass>, tasks: usize, par: usize, seed: u64) -> DagParams {
    match kind {
        Some(class) => DagParams::single(class, tasks, par as f64, seed),
        None => DagParams::mix(tasks, par as f64, seed),
    }
}

/// **Fig 6** — throughput vs parallelism per kernel, both schedulers, on
/// the TX2 model with 4000 tasks.
pub fn fig6(opts: &BenchOpts) -> Vec<Table> {
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let tasks = opts.scale(4000);
    let mut out = Vec::new();
    for (name, kind) in fig6_workloads() {
        let mut t = Table::new(
            &format!("Fig 6: {name} — throughput [tasks/s] vs parallelism"),
            &["parallelism", "performance-based", "homogeneous"],
        );
        for &par in &PARALLELISMS {
            let mk = |seed| fig6_params(kind, tasks, par, seed);
            let perf = mean_throughput(backend.as_ref(), &plat, mk, &PerformanceBased, opts.seeds);
            let homo = mean_throughput(backend.as_ref(), &plat, mk, &HomogeneousWs, opts.seeds);
            t.row(vec![par.to_string(), f2(perf), f2(homo)]);
        }
        out.push(t);
    }
    out
}

/// **Fig 7** — speedup of the performance-based over the homogeneous
/// scheduler per kernel and parallelism (paper at par=1: matmul 3.3×,
/// sort 2.5×, copy 2.2×, mix 2.7×).
pub fn fig7(opts: &BenchOpts) -> Vec<Table> {
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let tasks = opts.scale(4000);
    let mut t = Table::new(
        "Fig 7: speedup perf-based / homogeneous",
        &["parallelism", "matmul", "sort", "copy", "mix"],
    );
    let mut rows: Vec<Vec<String>> = PARALLELISMS.iter().map(|p| vec![p.to_string()]).collect();
    for (_, kind) in fig6_workloads() {
        for (pi, &par) in PARALLELISMS.iter().enumerate() {
            let mk = |seed| fig6_params(kind, tasks, par, seed);
            let perf = mean_throughput(backend.as_ref(), &plat, mk, &PerformanceBased, opts.seeds);
            let homo = mean_throughput(backend.as_ref(), &plat, mk, &HomogeneousWs, opts.seeds);
            rows[pi].push(f3(perf / homo));
        }
    }
    for r in rows {
        t.row(r);
    }
    vec![t]
}

/// The interference scenario of §5.3 on the Haswell model: a background
/// process (the paper uses a chain of MatMul DAGs) time-shares cores 0–1
/// during a window in the middle of the run.
pub struct Fig8Scenario {
    pub platform: Platform,
    pub window: (f64, f64),
    pub victim_cores: Vec<usize>,
}

pub fn fig8_scenario() -> Fig8Scenario {
    let window = (0.05, 0.25);
    let victims = vec![0usize, 1];
    let platform = Platform::haswell20().with_episodes(EpisodeSchedule::new(vec![
        // Same-priority spinner per core → we keep ~45% of the core, and
        // the MatMul chain adds a little memory traffic.
        Episode::interference(victims.clone(), window.0, window.1, 0.45, 2.0),
    ]));
    Fig8Scenario { platform, window, victim_cores: victims }
}

/// One Fig-8 run: a high-parallelism mixed DAG, PTT probe on (matmul,
/// core 1, width 1) — the entry the paper plots.
pub fn fig8_run(with_interference: bool, seed: u64) -> (RunResult, Vec<(f64, f64)>) {
    let scen = fig8_scenario();
    let plat = if with_interference { scen.platform } else { Platform::haswell20() };
    let (dag, _) = generate(&DagParams::mix(4000, 16.0, seed));
    // Interference episodes exist only in virtual time, so this experiment
    // is pinned to the simulated backend.
    let opts = RunOpts {
        seed,
        ptt_probe: Some((KernelClass::MatMul.index(), 1, 1)),
        ..Default::default()
    };
    let run = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
    (run.result, run.ptt_samples)
}

/// **Fig 8** — the scheduler's response to interference: distribution of
/// critical-task leaders before/during/after the episode, the PTT(1,1)
/// probe trace, and the wall-time comparison with the clean run.
pub fn fig8(opts: &BenchOpts) -> Vec<Table> {
    warn_sim_pinned(opts, "fig8", "interference episodes and PTT probes are virtual-time only");
    let seed = if opts.quick { 7 } else { 11 };
    let scen = fig8_scenario();
    let (with_if, probe) = fig8_run(true, seed);
    let (without, _) = fig8_run(false, seed);

    let mut t = Table::new(
        "Fig 8: critical-task placements on victim cores (0-1), haswell20",
        &["phase", "window [s]", "crit TAOs total", "crit TAOs on victims", "share [%]"],
    );
    let end = with_if.makespan;
    let phases = [
        ("before", 0.0, scen.window.0),
        ("during", scen.window.0, scen.window.1.min(end)),
        ("after", scen.window.1.min(end), end),
    ];
    for (name, a, b) in phases {
        let crit: Vec<_> = with_if
            .records
            .iter()
            .filter(|r| r.critical && r.t_start >= a && r.t_start < b)
            .collect();
        let on_victims = crit
            .iter()
            .filter(|r| r.partition.cores().any(|c| scen.victim_cores.contains(&c)))
            .count();
        let share = if crit.is_empty() { 0.0 } else { 100.0 * on_victims as f64 / crit.len() as f64 };
        t.row(vec![
            name.to_string(),
            format!("{a:.2}-{b:.2}"),
            crit.len().to_string(),
            on_victims.to_string(),
            f2(share),
        ]);
    }

    let mut t2 = Table::new(
        "Fig 8: wall time with vs without interference (paper: marginal difference)",
        &["run", "makespan [s]", "throughput [tasks/s]"],
    );
    t2.row(vec!["interfered".into(), f3(with_if.makespan), f2(with_if.throughput())]);
    t2.row(vec!["clean".into(), f3(without.makespan), f2(without.throughput())]);
    t2.row(vec![
        "overhead".into(),
        f3(with_if.makespan - without.makespan),
        format!("{:.1}%", 100.0 * (with_if.makespan / without.makespan - 1.0)),
    ]);

    let mut t3 = Table::new(
        "Fig 8(a): PTT value probe at (matmul, core 1, width 1)",
        &["t [s]", "ptt value [s]"],
    );
    // Subsample the probe to ~40 rows.
    let step = (probe.len() / 40).max(1);
    for (ti, v) in probe.iter().step_by(step) {
        t3.row(vec![f3(*ti), format!("{v:.6}")]);
    }
    vec![t, t2, t3]
}

/// VGG DAG used by Fig 9/10 (block length 8 — the paper tunes the block
/// length at runtime; 8 channels per TAO gives every layer enough
/// TAO-level parallelism to feed 20 threads, §4.3).
pub fn fig9_dag(repeats: usize) -> TaoDag {
    build_vgg_dag(&VggConfig { input_hw: 224, block_len: 8, repeats }, None)
}

pub const FIG9_THREADS: [usize; 7] = [1, 2, 4, 8, 12, 16, 20];

/// One VGG scaling run at `n` simulated threads, measured with a *warm*
/// PTT: the paper's scalability study predicts repeatedly, so the table
/// has converged long before the measured steady state. A warm-up pass
/// trains the PTT, then the measured pass reuses it.
pub fn fig9_run(n_threads: usize, repeats: usize) -> RunResult {
    let plat = Platform::homogeneous(n_threads);
    let warm = fig9_dag(2);
    let dag = fig9_dag(repeats);
    let ptt = Ptt::new(dag.n_types(), &plat.topo);
    SimBackend.run(&warm, &plat, &PerformanceBased, Some(&ptt), &RunOpts::default()).unwrap();
    SimBackend.run(&dag, &plat, &PerformanceBased, Some(&ptt), &RunOpts::default()).unwrap().result
}

/// **Fig 9** — VGG-16 strong scaling (paper: ≈0.69 parallel efficiency,
/// near-linear speedup).
pub fn fig9(opts: &BenchOpts) -> Vec<Table> {
    warn_sim_pinned(opts, "fig9", "the strong-scaling sweep varies the modelled thread count");
    let repeats = if opts.quick { 1 } else { 3 };
    let mut t = Table::new(
        "Fig 9: VGG-16 strong scaling (haswell-class homogeneous model)",
        &["threads", "time [s]", "speedup", "efficiency"],
    );
    let t1 = fig9_run(1, repeats).makespan;
    for &n in &FIG9_THREADS {
        if opts.quick && n > 8 {
            break;
        }
        let tn = fig9_run(n, repeats).makespan;
        let sp = t1 / tn;
        t.row(vec![n.to_string(), f3(tn), f3(sp), f3(sp / n as f64)]);
    }
    vec![t]
}

/// **Fig 10** — percentage of TAOs scheduled at each width by the PTT
/// (paper at 8 threads: ~67% width 1, ~30% width 8).
pub fn fig10(opts: &BenchOpts) -> Vec<Table> {
    warn_sim_pinned(opts, "fig10", "the width histogram sweeps modelled thread counts");
    let repeats = if opts.quick { 1 } else { 3 };
    let threads = if opts.quick { vec![4usize, 8] } else { vec![2usize, 4, 8, 16] };
    let all_widths: Vec<usize> = vec![1, 2, 4, 8, 16];
    let hdr: Vec<String> = std::iter::once("threads".to_string())
        .chain(all_widths.iter().map(|w| format!("w={w} [%]")))
        .collect();
    let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 10: % of TAOs per scheduled width (VGG-16)", &hdr_refs);
    for &n in &threads {
        // Cold PTT: the paper's histogram covers the whole run including
        // the bootstrap phase, whose exploration is mostly width 1.
        let plat = Platform::homogeneous(n);
        let dag = fig9_dag(repeats);
        let res = SimBackend
            .run(&dag, &plat, &PerformanceBased, None, &RunOpts::default())
            .unwrap()
            .result;
        let pct = res.width_percentages();
        let mut row = vec![n.to_string()];
        for &w in &all_widths {
            row.push(f2(pct.get(&w).copied().unwrap_or(0.0)));
        }
        t.row(row);
    }
    vec![t]
}

/// **Ablation A1** — PTT history weight (§3.2's 4:1 choice) and the cost
/// of disabling the moving average entirely.
pub fn ablation_ptt(opts: &BenchOpts) -> Vec<Table> {
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let tasks = opts.scale(2000);
    let mut t = Table::new(
        "Ablation: PTT history weight (paper uses 4 = 80%/20%)",
        &["history weight", "makespan [s]", "throughput [tasks/s]", "untrained frac"],
    );
    for weight in [0.0, 1.0, 4.0, 9.0, 19.0] {
        let mks: Vec<f64> = (0..opts.seeds as u64)
            .map(|s| {
                let (dag, _) = generate(&DagParams::mix(tasks, 4.0, 500 + s));
                let ptt = Ptt::new(dag.n_types(), &plat.topo);
                ptt.set_history_weight(weight);
                let run = backend
                    .run(
                        &dag,
                        &plat,
                        &PerformanceBased,
                        Some(&ptt),
                        &RunOpts { seed: s, ..Default::default() },
                    )
                    .unwrap();
                run.result.makespan
            })
            .collect();
        let mk = stats::mean(&mks);
        t.row(vec![
            format!("{weight}"),
            f3(mk),
            f2(tasks as f64 / mk),
            "-".into(),
        ]);
    }
    vec![t]
}

/// **Ablation A2** — all four policies (§6 baselines) across parallelism.
pub fn ablation_baselines(opts: &BenchOpts) -> Vec<Table> {
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let tasks = opts.scale(2000);
    let names = ["performance", "homogeneous", "cats", "dheft"];
    let hdr: Vec<String> = std::iter::once("parallelism".to_string())
        .chain(names.iter().map(|s| s.to_string()))
        .collect();
    let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Ablation: throughput [tasks/s] by policy (mix, tx2)", &hdr_refs);
    for &par in &PARALLELISMS {
        let mut row = vec![par.to_string()];
        for name in names {
            let tp = stats::mean(
                &(0..opts.seeds as u64)
                    .map(|s| {
                        let (dag, _) = generate(&DagParams::mix(tasks, par as f64, 900 + s));
                        let policy = policy_by_name(name, plat.topo.n_cores()).unwrap();
                        backend
                            .run(
                                &dag,
                                &plat,
                                policy.as_ref(),
                                None,
                                &RunOpts { seed: s, ..Default::default() },
                            )
                            .unwrap()
                            .result
                            .throughput()
                    })
                    .collect::<Vec<_>>(),
            );
            row.push(f2(tp));
        }
        t.row(row);
    }
    vec![t]
}

/// **Ablation A3** — the §3.3 alternative objective: energy-minimizing vs
/// performance-based placement. Reports both throughput and modelled
/// energy per run (watt model in `platform::power`).
pub fn ablation_energy(opts: &BenchOpts) -> Vec<Table> {
    use crate::platform::run_energy;
    let plat = Platform::tx2();
    let backend = opts.exec_backend();
    let tasks = opts.scale(2000);
    let mut t = Table::new(
        "Ablation: performance vs energy objective (mix, tx2)",
        &["parallelism", "policy", "throughput [tasks/s]", "energy [J]", "J/task"],
    );
    for &par in &PARALLELISMS {
        for name in ["performance", "energy"] {
            let mut tps = Vec::new();
            let mut ens = Vec::new();
            for s in 0..opts.seeds as u64 {
                let (dag, _) = generate(&DagParams::mix(tasks, par as f64, 1300 + s));
                let policy = policy_by_name(name, plat.topo.n_cores()).unwrap();
                let run = backend
                    .run(
                        &dag,
                        &plat,
                        policy.as_ref(),
                        None,
                        &RunOpts { seed: s, ..Default::default() },
                    )
                    .unwrap()
                    .result;
                tps.push(run.throughput());
                ens.push(run_energy(&plat.topo, &run));
            }
            let tp = stats::mean(&tps);
            let en = stats::mean(&ens);
            t.row(vec![
                par.to_string(),
                name.to_string(),
                f2(tp),
                f2(en),
                format!("{:.4}", en / tasks as f64),
            ]);
        }
    }
    vec![t]
}

/// **Stream interference** — the §5.3 Haswell experiment grown to
/// multi-tenant form: two applications co-run on `bg-interferer-haswell20`
/// while a background process squeezes cores 0–1. For each policy we
/// report per-app slowdown against an isolated run and the Jain fairness
/// index, plus (for the PTT scheduler) the share of critical tasks placed
/// on the victim cores before/during/after the episode. The paper's shape
/// under test: the performance-based scheduler detects the interference
/// through the PTT alone and steers critical work off the victims, keeping
/// per-app slowdowns tighter than the PTT-blind baselines.
pub fn stream_interference(opts: &BenchOpts) -> Vec<Table> {
    use crate::exec::run_stream_triple;
    use crate::workload::scenarios::stream_by_name;
    warn_sim_pinned(opts, "stream-interference", "interference episodes are virtual-time only");
    let scen = stream_by_name("bg-interferer-haswell20").expect("registered stream");
    let victims = crate::platform::scenarios::BG_INTERFERER_VICTIMS;
    let (win_a, win_b) = crate::platform::scenarios::BG_INTERFERER_WINDOW;

    let mut t_fair = Table::new(
        "Stream interference: per-app slowdown and fairness, bg-interferer-haswell20",
        &["policy", "slowdown fg", "slowdown tenant", "worst", "Jain index"],
    );
    let mut t_victim = Table::new(
        "Stream interference: critical TAOs on victim cores 0-1 (performance-based)",
        &["phase", "window [s]", "crit TAOs", "on victims", "share [%]"],
    );
    for policy in ["performance", "homogeneous", "cats", "dheft"] {
        // Sized from the stream's actual app count, so editing the
        // registered scenario (more tenants, periodic copies) cannot
        // silently break the bench.
        let mut sd: Vec<Vec<f64>> = Vec::new();
        let mut jain = Vec::new();
        for s in 0..opts.seeds as u64 {
            let stream = scen.stream(17 + s, opts.quick);
            let run = run_stream_triple(
                "sim",
                scen.platform,
                policy,
                &stream,
                &RunOpts { seed: 17 + s, ..Default::default() },
                true,
            )
            .expect("registered triple");
            if sd.len() < run.apps.len() {
                sd.resize(run.apps.len(), Vec::new());
            }
            for (i, app) in run.apps.iter().enumerate() {
                sd[i].push(app.slowdown.expect("baseline attached"));
            }
            jain.push(run.jain_fairness().expect("stream admitted apps"));
            if policy == "performance" && s == 0 {
                // Phase table from the first seed's trace.
                let end = run.result.makespan;
                for (name, a, b) in [
                    ("before", 0.0, win_a),
                    ("during", win_a, win_b.min(end)),
                    ("after", win_b.min(end), end),
                ] {
                    let crit: Vec<_> = run
                        .result
                        .records
                        .iter()
                        .filter(|r| r.critical && r.t_start >= a && r.t_start < b)
                        .collect();
                    let on_victims = crit
                        .iter()
                        .filter(|r| r.partition.cores().any(|c| victims.contains(&c)))
                        .count();
                    let share = if crit.is_empty() {
                        0.0
                    } else {
                        100.0 * on_victims as f64 / crit.len() as f64
                    };
                    t_victim.row(vec![
                        name.to_string(),
                        format!("{a:.2}-{b:.2}"),
                        crit.len().to_string(),
                        on_victims.to_string(),
                        f2(share),
                    ]);
                }
            }
        }
        let means: Vec<f64> = sd.iter().map(|v| stats::mean(v)).collect();
        let m0 = means.first().copied().unwrap_or(f64::NAN);
        let m1 = means.get(1).copied().unwrap_or(m0);
        let worst = means.iter().copied().fold(f64::NAN, f64::max);
        t_fair.row(vec![
            policy.to_string(),
            f3(m0),
            f3(m1),
            f3(worst),
            f3(stats::mean(&jain)),
        ]);
    }
    vec![t_fair, t_victim]
}

/// Print tables and write CSVs under `bench_out/<prefix>_<i>.csv`.
pub fn emit(prefix: &str, tables: &[Table]) {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        let name = if tables.len() == 1 {
            prefix.to_string()
        } else {
            format!("{prefix}_{i}")
        };
        match t.write_csv(&name) {
            Ok(p) => println!("[csv] {p}\n"),
            Err(e) => eprintln!("[csv] write failed: {e}\n"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quick_produces_grid() {
        let tables = fig5(&BenchOpts::quick());
        assert_eq!(tables.len(), 3);
        assert_eq!(tables[0].rows.len(), PARALLELISMS.len());
        assert_eq!(tables[0].rows[0].len(), FIG5_TASKS.len() + 1);
    }

    #[test]
    fn fig7_speedup_positive() {
        let tables = fig7(&BenchOpts::quick());
        for row in &tables[0].rows {
            for cell in &row[1..] {
                let v: f64 = cell.parse().unwrap();
                assert!(v > 0.0);
            }
        }
    }

    #[test]
    fn fig8_interference_redirects_critical_tasks() {
        let tables = fig8(&BenchOpts::quick());
        // During the episode, the share of critical tasks on victim cores
        // must drop vs before.
        let share = |row: &Vec<String>| -> f64 { row[4].parse().unwrap() };
        let before = share(&tables[0].rows[0]);
        let during = share(&tables[0].rows[1]);
        assert!(
            during < before || before == 0.0,
            "during ({during}) should be below before ({before})"
        );
    }

    #[test]
    fn fig9_speedup_monotone() {
        let tables = fig9(&BenchOpts::quick());
        let speedups: Vec<f64> =
            tables[0].rows.iter().map(|r| r[2].parse().unwrap()).collect();
        for w in speedups.windows(2) {
            assert!(w[1] >= w[0] * 0.9, "speedup should not collapse: {speedups:?}");
        }
    }

    #[test]
    fn fig10_percentages_sum_to_100() {
        let tables = fig10(&BenchOpts::quick());
        for row in &tables[0].rows {
            let sum: f64 = row[1..].iter().map(|c| c.parse::<f64>().unwrap()).sum();
            assert!((sum - 100.0).abs() < 1.0, "row sums to {sum}");
        }
    }

    #[test]
    fn stream_interference_reports_all_policies_and_phases() {
        let tables = stream_interference(&BenchOpts::quick());
        assert_eq!(tables.len(), 2);
        // One fairness row per policy, each with a valid Jain index.
        assert_eq!(tables[0].rows.len(), 4);
        for row in &tables[0].rows {
            let jain: f64 = row[4].parse().unwrap();
            assert!(jain > 0.0 && jain <= 1.0 + 1e-9, "{row:?}");
            for cell in &row[1..4] {
                let sd: f64 = cell.parse().unwrap();
                assert!(sd > 0.0 && sd.is_finite(), "{row:?}");
            }
        }
        // before/during/after phase rows for the PTT scheduler.
        assert_eq!(tables[1].rows.len(), 3);
    }

    #[test]
    fn ablation_tables_well_formed() {
        let t1 = ablation_ptt(&BenchOpts::quick());
        assert_eq!(t1[0].rows.len(), 5);
        let t2 = ablation_baselines(&BenchOpts::quick());
        assert_eq!(t2[0].rows.len(), PARALLELISMS.len());
    }

    #[test]
    fn energy_policy_uses_less_energy_per_task() {
        let t = ablation_energy(&BenchOpts::quick());
        // At parallelism 1, the energy policy's J/task must not exceed the
        // performance policy's.
        let jt = |row: &Vec<String>| row[4].parse::<f64>().unwrap();
        let perf = jt(&t[0].rows[0]);
        let energy = jt(&t[0].rows[1]);
        assert!(energy <= perf * 1.05, "energy {energy} vs perf {perf}");
    }
}
