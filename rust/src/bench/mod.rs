//! Benchmark/figure harness: one regenerator per table and figure in the
//! paper's evaluation (§5), plus the design ablations called out in
//! DESIGN.md. Used by the `repro` CLI and the `cargo bench` targets.

pub mod figures;

pub use figures::{
    BenchOpts, ablation_baselines, ablation_energy, ablation_ptt, emit, fig5, fig6, fig7, fig8,
    fig9, fig10, stream_interference,
};
