//! Benchmark/figure harness: one regenerator per table and figure in the
//! paper's evaluation (§5), plus the design ablations called out in
//! DESIGN.md, the scheduler-overhead perf harness ([`overhead`]) and the
//! §5.3 interference-response harness ([`interference_response`]) and the
//! policy × scenario experiment matrix ([`experiment`]) and the
//! fault-injection chaos harness ([`faults`]) and the moldable-width
//! ablation ([`elastic`]).
//! Used by the `repro` CLI and the `cargo bench` targets.

pub mod elastic;
pub mod experiment;
pub mod faults;
pub mod figures;
pub mod interference_response;
pub mod overhead;
pub mod serving;

pub use elastic::{
    ELASTIC_CELLS, ElasticOpts, emit_elastic, render_elastic_table, run_elastic_json,
};
pub use experiment::{
    ExperimentOpts, emit_experiment, render_experiment_table, run_experiment_json,
};

pub use faults::{
    FAULT_POLICIES, FaultBenchOpts, emit_faults, fault_scenario_names, render_faults_table,
    run_faults_json,
};
pub use figures::{
    BenchOpts, ablation_baselines, ablation_energy, ablation_ptt, emit, fig5, fig6, fig7, fig8,
    fig9, fig10, stream_interference,
};
pub use interference_response::{
    INTERFERENCE_POLICIES, InterferenceOpts, ResponseRun, emit_interference, run_interference,
    run_response,
};
pub use overhead::{
    OverheadOpts, OverheadRun, emit_overhead, render_pressure_sweep, run_overhead,
};
pub use serving::{RATE_PER_TENANT, ServingBenchOpts, ServingStep, emit_serving, run_serving_bench};
