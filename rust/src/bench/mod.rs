//! Benchmark/figure harness: one regenerator per table and figure in the
//! paper's evaluation (§5), plus the design ablations called out in
//! DESIGN.md and the scheduler-overhead perf harness ([`overhead`]).
//! Used by the `repro` CLI and the `cargo bench` targets.

pub mod figures;
pub mod overhead;

pub use figures::{
    BenchOpts, ablation_baselines, ablation_energy, ablation_ptt, emit, fig5, fig6, fig7, fig8,
    fig9, fig10, stream_interference,
};
pub use overhead::{OverheadOpts, OverheadRun, emit_overhead, run_overhead};
