//! Scheduler-overhead harness (`repro bench-overhead`) — the first point
//! of this repository's recorded perf trajectory.
//!
//! Measures the hot path that PR 3 made lock-free:
//!
//! 1. **Steal-heavy queue benchmark**: one owner pushes/pops while
//!    thieves steal concurrently — the Chase–Lev [`WsQueue`] against the
//!    retired mutex baseline ([`MutexWsQueue`]), reporting throughput,
//!    mean steal latency and the lock-free/mutex speedup.
//! 2. **Single-thread queue micro-ops**: uncontended push+pop cost of the
//!    lock-free and mutex WSQ/AQ variants.
//! 3. **End-to-end engine overhead**: tasks/sec of the real-thread engine
//!    on nop payloads (pure runtime overhead, no kernel work) across the
//!    `hom4` / `hom20` / `biglittle44` / `hom64` scenarios (`hom128` too
//!    in full mode — 128 worker threads is too heavy for a CI smoke).
//! 4. **Simulator event rate**: simulated TAOs per wall second (tracks the
//!    O(n²)→O(n) bookkeeping fix in `sim::engine`).
//! 5. **Steal pressure**: the same steal-heavy workload under a thief
//!    *pack*, single-steal vs batched [`WsQueue::steal_half`] — the
//!    within-run speedup is this PR's trajectory point (`--pressure`
//!    additionally prints a thief-count sweep of the two modes).
//!
//! `--json` writes the machine-readable result to
//! `BENCH_sched_overhead.json` at the repository root; `--compare` prints
//! the focused mutex-vs-lockfree table **and**, when a committed
//! `BENCH_sched_overhead.json` exists, a current-vs-committed table of the
//! hot-path throughput metrics — flagging any metric that fell below
//! [`REGRESSION_FLOOR`] of a `"measured"`, same-`--quick`-scale baseline
//! (a seed-estimate or different-scale baseline is printed for context
//! but never flagged), and the CLI exits non-zero when anything is
//! flagged. Numbers are host-dependent; the *shape* under test is "the
//! lock-free path is no slower, and faster under steal contention".

use crate::coordinator::aq::AssemblyQueue;
use crate::coordinator::dag::TaoDag;
use crate::coordinator::mutex_queues::{MutexAssemblyQueue, MutexWsQueue};
use crate::coordinator::scheduler::policy_by_name;
use crate::coordinator::wsq::WsQueue;
use crate::coordinator::{NopPayload, RealEngineOpts, run_dag_real};
use crate::dag_gen::{DagParams, generate};
use crate::platform::{KernelClass, scenarios};
use crate::sim::{SimOpts, run_dag_sim};
use crate::util::json::Json;
use crate::util::table::Table;
use std::sync::Arc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Harness options (all off = print the lock-free numbers only).
#[derive(Debug, Clone, Default)]
pub struct OverheadOpts {
    /// CI smoke scale (small iteration counts).
    pub quick: bool,
    /// Run and print the mutex-vs-lockfree comparison.
    pub compare: bool,
    /// Write `BENCH_sched_overhead.json` at the repository root.
    pub json: bool,
    /// Print the steal-pressure sweep (single vs batched stealing across
    /// thief-pack sizes) on top of the always-measured fixed-pack point.
    pub pressure: bool,
}

/// Scenarios the end-to-end overhead is measured on at every scale.
pub const OVERHEAD_SCENARIOS: [&str; 4] = ["hom4", "hom20", "biglittle44", "hom64"];

/// Scenarios measured only in full (non-`--quick`) mode: spawning 128
/// worker threads dwarfs a CI smoke's budget and tells us nothing hom64
/// doesn't on a shared runner.
pub const OVERHEAD_SCENARIOS_FULL: [&str; 1] = ["hom128"];

/// The end-to-end scenario list for a given scale.
pub fn overhead_scenarios(quick: bool) -> Vec<&'static str> {
    let mut v: Vec<&'static str> = OVERHEAD_SCENARIOS.to_vec();
    if !quick {
        v.extend(OVERHEAD_SCENARIOS_FULL);
    }
    v
}

/// Resolve `name` at the repository root: the nearest ancestor of the
/// current directory whose `Cargo.toml` declares a `[workspace]` (this
/// repository's root manifest). Walking up and stopping at the *first*
/// workspace root means a checkout nested inside some other Cargo project
/// is never escaped. Falls back to the build-time manifest location for
/// artifacts executed outside any checkout. Shared by every committed
/// `BENCH_*.json` emitter.
pub fn repo_root_file(name: &str) -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    for dir in cwd.ancestors() {
        if let Ok(text) = std::fs::read_to_string(dir.join("Cargo.toml")) {
            if text.contains("[workspace]") {
                return dir.join(name);
            }
        }
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(name)
}

/// Where the scheduler-overhead JSON lands (see [`repo_root_file`]).
pub fn bench_json_path() -> std::path::PathBuf {
    repo_root_file("BENCH_sched_overhead.json")
}

/// Time `f` over `iters` iterations, returning ns/op. Shared with the
/// `sched_overhead` cargo-bench harness so the two measurement paths
/// cannot drift.
pub fn time_ns<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f(); // warmup
    }
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_nanos() as f64 / iters as f64
}

/// The queue surface both WSQ implementations expose; lets the steal
/// benchmark drive lock-free and mutex variants through one code path.
trait StealQueue<T>: Sync {
    fn push(&self, v: T);
    fn pop(&self) -> Option<T>;
    fn steal(&self) -> Option<T>;
    /// Batched steal (`steal_half` policy on both variants); returns the
    /// number of items passed to `sink`.
    fn steal_some(&self, sink: &mut dyn FnMut(T)) -> usize;
}

impl<T: Copy + Send> StealQueue<T> for WsQueue<T> {
    fn push(&self, v: T) {
        WsQueue::push(self, v)
    }
    fn pop(&self) -> Option<T> {
        WsQueue::pop(self)
    }
    fn steal(&self) -> Option<T> {
        WsQueue::steal(self)
    }
    fn steal_some(&self, sink: &mut dyn FnMut(T)) -> usize {
        WsQueue::steal_half(self, sink)
    }
}

impl<T: Send> StealQueue<T> for MutexWsQueue<T> {
    fn push(&self, v: T) {
        MutexWsQueue::push(self, v)
    }
    fn pop(&self) -> Option<T> {
        MutexWsQueue::pop(self)
    }
    fn steal(&self) -> Option<T> {
        MutexWsQueue::steal(self)
    }
    fn steal_some(&self, sink: &mut dyn FnMut(T)) -> usize {
        MutexWsQueue::steal_half(self, sink)
    }
}

#[derive(Debug, Clone, Copy)]
struct StealStats {
    ops_per_sec: f64,
    /// Mean latency of a *successful* steal, ns.
    steal_ns: f64,
    /// Items actually taken by thieves (vs the owner).
    stolen: usize,
}

/// Steal-heavy workload: the owner pushes `items` in DAG-commit-sized
/// batches and pops a quarter back (the LIFO half of the hot path) while
/// `n_thieves` thieves drain the rest — one [`StealQueue::steal`] per item
/// or, with `batched`, a [`StealQueue::steal_some`] half-queue grab per
/// visit. Every item is consumed exactly once — the consumed counter
/// doubles as a correctness check (the run would hang on a lost item).
fn run_steal_bench<Q: StealQueue<usize>>(
    q: &Q,
    items: usize,
    n_thieves: usize,
    batched: bool,
) -> StealStats {
    let consumed = AtomicUsize::new(0);
    let stolen = AtomicUsize::new(0);
    let steal_ns_total = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..n_thieves {
            let (consumed, stolen, steal_ns_total) = (&consumed, &stolen, &steal_ns_total);
            s.spawn(move || {
                let mut local_ns = 0u64;
                let mut local_stolen = 0usize;
                while consumed.load(Ordering::Relaxed) < items {
                    let t = Instant::now();
                    let got = if batched {
                        q.steal_some(&mut |v| {
                            std::hint::black_box(v);
                        })
                    } else {
                        usize::from(q.steal().is_some())
                    };
                    if got > 0 {
                        // Amortized per-item latency: a batch pays one
                        // visit for `got` items.
                        local_ns += t.elapsed().as_nanos() as u64;
                        local_stolen += got;
                        consumed.fetch_add(got, Ordering::Relaxed);
                    } else {
                        std::hint::spin_loop();
                    }
                }
                steal_ns_total.fetch_add(local_ns, Ordering::Relaxed);
                stolen.fetch_add(local_stolen, Ordering::Relaxed);
            });
        }
        // Owner (this thread): push batches, pop a share.
        let mut pushed = 0usize;
        while pushed < items {
            let batch = 64.min(items - pushed);
            for _ in 0..batch {
                q.push(pushed);
                pushed += 1;
            }
            for _ in 0..batch / 4 {
                if q.pop().is_some() {
                    consumed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        // Drain whatever the thieves leave behind.
        while consumed.load(Ordering::Relaxed) < items {
            if q.pop().is_some() {
                consumed.fetch_add(1, Ordering::Relaxed);
            } else {
                std::hint::spin_loop();
            }
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let n_stolen = stolen.load(Ordering::Relaxed);
    StealStats {
        ops_per_sec: items as f64 / secs.max(1e-9),
        steal_ns: if n_stolen == 0 {
            0.0
        } else {
            steal_ns_total.load(Ordering::Relaxed) as f64 / n_stolen as f64
        },
        stolen: n_stolen,
    }
}

/// An all-independent nop-payload DAG: every placement is a fresh
/// pop-or-steal + placement decision + AQ round trip — maximally
/// steal-heavy, zero kernel work, so elapsed time is pure scheduler
/// overhead.
fn nop_dag(n_tasks: usize) -> TaoDag {
    let mut dag = TaoDag::new();
    let payload: Arc<dyn crate::coordinator::TaoPayload> =
        Arc::new(NopPayload(KernelClass::MatMul));
    for _ in 0..n_tasks {
        dag.add_task_payload(KernelClass::MatMul, 0, 1.0, Some(payload.clone()));
    }
    dag.finalize().unwrap();
    dag
}

/// Run the full harness; returns the machine-readable result. Prints
/// nothing — see [`emit_overhead`] for the CLI entry point.
pub fn run_overhead(opts: &OverheadOpts) -> Json {
    let micro_iters = if opts.quick { 20_000 } else { 200_000 };
    let steal_items = if opts.quick { 50_000 } else { 400_000 };
    let engine_tasks = if opts.quick { 1_000 } else { 20_000 };
    let sim_tasks = if opts.quick { 2_000 } else { 20_000 };
    let host_cores = crate::platform::detect::online_cpus();
    let n_thieves = host_cores.saturating_sub(1).clamp(1, 3);
    let with_compare = opts.compare || opts.json;

    // --- 1. steal-heavy queue benchmark ---------------------------------
    let lf = {
        let q: WsQueue<usize> = WsQueue::new();
        run_steal_bench(&q, steal_items, n_thieves, false)
    };
    let mx = with_compare.then(|| {
        let q: MutexWsQueue<usize> = MutexWsQueue::new();
        run_steal_bench(&q, steal_items, n_thieves, false)
    });

    // --- 1b. steal pressure: single vs batched under a thief pack --------
    // Oversubscribed on small hosts by design — the contention on the
    // victim's `top` cache line is the thing being measured. The within-
    // run single→batched speedup is host-independent in *shape* and is
    // recorded as this PR's trajectory point.
    let pressure_thieves = if opts.quick { 4 } else { 8 };
    let ps_single = {
        let q: WsQueue<usize> = WsQueue::new();
        run_steal_bench(&q, steal_items, pressure_thieves, false)
    };
    let ps_batch = {
        let q: WsQueue<usize> = WsQueue::new();
        run_steal_bench(&q, steal_items, pressure_thieves, true)
    };

    // --- 2. uncontended micro-ops ----------------------------------------
    let wsq: WsQueue<usize> = WsQueue::new();
    let wsq_pp = time_ns(micro_iters, || {
        wsq.push(1);
        std::hint::black_box(wsq.pop());
    });
    let aq: AssemblyQueue<usize> = AssemblyQueue::new();
    let aq_pp = time_ns(micro_iters, || {
        aq.push(1);
        std::hint::black_box(aq.pop());
    });
    let (mwsq_pp, maq_pp) = if with_compare {
        let mwsq: MutexWsQueue<usize> = MutexWsQueue::new();
        let p1 = time_ns(micro_iters, || {
            mwsq.push(1);
            std::hint::black_box(mwsq.pop());
        });
        let maq: MutexAssemblyQueue<usize> = MutexAssemblyQueue::new();
        let p2 = time_ns(micro_iters, || {
            maq.push(1);
            std::hint::black_box(maq.pop());
        });
        (Some(p1), Some(p2))
    } else {
        (None, None)
    };

    // --- 3. end-to-end engine overhead per scenario ----------------------
    let dag = nop_dag(engine_tasks);
    let mut scen_objs: Vec<(&str, Json)> = Vec::new();
    for name in overhead_scenarios(opts.quick) {
        let plat = scenarios::by_name(name).expect("registered overhead scenario");
        let policy = policy_by_name("performance", plat.topo.n_cores()).expect("policy");
        let t = Instant::now();
        let res = run_dag_real(&dag, &plat.topo, policy.as_ref(), None, &RealEngineOpts::default())
            .unwrap();
        let secs = t.elapsed().as_secs_f64();
        let tps = res.n_tasks() as f64 / secs.max(1e-9);
        scen_objs.push((
            name,
            Json::obj(vec![
                ("workers", Json::Num(plat.topo.n_cores() as f64)),
                ("tasks", Json::Num(res.n_tasks() as f64)),
                ("tasks_per_sec", Json::Num(tps)),
                ("ns_per_tao", Json::Num(1e9 * secs / res.n_tasks() as f64)),
            ]),
        ));
    }

    // --- 4. simulator event rate -----------------------------------------
    let (sim_dag, _) = generate(&DagParams::mix(sim_tasks, 8.0, 3));
    let plat = scenarios::by_name("tx2").unwrap();
    let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
    let t = Instant::now();
    let run = run_dag_sim(&sim_dag, &plat, policy.as_ref(), None, &SimOpts::default()).unwrap();
    let sim_secs = t.elapsed().as_secs_f64();
    let sim_tps = run.result.n_tasks() as f64 / sim_secs.max(1e-9);

    // --- assemble ---------------------------------------------------------
    let mut steal_pairs = vec![
        ("threads", Json::Num((n_thieves + 1) as f64)),
        ("items", Json::Num(steal_items as f64)),
        ("lockfree_ops_per_sec", Json::Num(lf.ops_per_sec)),
        ("lockfree_steal_ns", Json::Num(lf.steal_ns)),
        ("lockfree_stolen", Json::Num(lf.stolen as f64)),
    ];
    if let Some(mx) = mx {
        steal_pairs.push(("mutex_ops_per_sec", Json::Num(mx.ops_per_sec)));
        steal_pairs.push(("mutex_steal_ns", Json::Num(mx.steal_ns)));
        steal_pairs.push((
            "speedup_lockfree_over_mutex",
            Json::Num(lf.ops_per_sec / mx.ops_per_sec.max(1e-9)),
        ));
    }
    let mut queue_pairs = vec![
        ("wsq_push_pop_ns", Json::Num(wsq_pp)),
        ("aq_push_pop_ns", Json::Num(aq_pp)),
    ];
    if let (Some(a), Some(b)) = (mwsq_pp, maq_pp) {
        queue_pairs.push(("mutex_wsq_push_pop_ns", Json::Num(a)));
        queue_pairs.push(("mutex_aq_push_pop_ns", Json::Num(b)));
    }
    let batch_speedup = ps_batch.ops_per_sec / ps_single.ops_per_sec.max(1e-9);
    Json::obj(vec![
        ("bench", Json::Str("sched_overhead".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("host_cores", Json::Num(host_cores as f64)),
        ("scenarios", Json::obj(scen_objs)),
        ("steal", Json::obj(steal_pairs)),
        (
            "steal_pressure",
            Json::obj(vec![
                ("thieves", Json::Num(pressure_thieves as f64)),
                ("items", Json::Num(steal_items as f64)),
                ("single_ops_per_sec", Json::Num(ps_single.ops_per_sec)),
                ("batch_ops_per_sec", Json::Num(ps_batch.ops_per_sec)),
                ("batch_speedup", Json::Num(batch_speedup)),
            ]),
        ),
        ("queues", Json::obj(queue_pairs)),
        (
            "sim",
            Json::obj(vec![
                ("tasks", Json::Num(sim_tasks as f64)),
                ("sim_tao_per_sec", Json::Num(sim_tps)),
            ]),
        ),
        // The recorded perf trajectory: both points measured in THIS run
        // (same host, same scale), so the speedup survives a CI `--json`
        // regeneration instead of comparing across machines.
        (
            "trajectory",
            Json::Arr(vec![
                Json::obj(vec![
                    ("point", Json::Str("pr3-single-steal".into())),
                    ("steal_ops_per_sec", Json::Num(ps_single.ops_per_sec)),
                ]),
                Json::obj(vec![
                    ("point", Json::Str("pr9-batched-steal".into())),
                    ("steal_ops_per_sec", Json::Num(ps_batch.ops_per_sec)),
                    ("speedup_over_single", Json::Num(batch_speedup)),
                ]),
            ]),
        ),
    ])
}

fn get_f64(j: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = j;
    for p in path {
        cur = cur.get(p)?;
    }
    cur.as_f64()
}

/// A current run must reach at least this fraction of a *measured*
/// committed baseline on every tracked hot-path metric, or `--compare`
/// flags it. Generous on purpose: CI runners are shared and noisy; the
/// floor catches "accidentally re-introduced a lock on the fast path"
/// (integer-factor slowdowns), not single-digit-percent drift.
pub const REGRESSION_FLOOR: f64 = 0.5;

/// Hot-path throughput metrics compared against the committed baseline:
/// `(json path, human label)`. Higher is better for all of them.
const TRACKED: [(&[&str], &str); 7] = [
    (&["scenarios", "hom4", "tasks_per_sec"], "hom4 tasks/s"),
    (&["scenarios", "hom20", "tasks_per_sec"], "hom20 tasks/s"),
    (&["scenarios", "biglittle44", "tasks_per_sec"], "biglittle44 tasks/s"),
    (&["scenarios", "hom64", "tasks_per_sec"], "hom64 tasks/s"),
    (&["steal", "lockfree_ops_per_sec"], "steal-heavy ops/s"),
    (&["steal_pressure", "batch_ops_per_sec"], "batched steal ops/s"),
    (&["sim", "sim_tao_per_sec"], "sim TAO/s"),
];

/// Outcome of one current-vs-committed baseline comparison.
pub struct BaselineComparison {
    /// The rendered metric table (always produced).
    pub table: Table,
    /// One line per flagged hot-path regression. Non-empty only when the
    /// baseline gates (measured provenance AND matching `quick` scale).
    pub regressions: Vec<String>,
    /// Informational caveats (non-measured provenance, scale mismatch).
    pub notes: Vec<String>,
}

/// Compare a fresh result against the committed baseline JSON. Regressions
/// are flagged only when the baseline is `provenance: "measured"` *and*
/// was produced at the same `quick` scale as the current run — a seed
/// estimate or a full-mode baseline under a quick run is context, not a
/// gate (the workload sizes differ, so ratios are not comparable).
pub fn compare_with_committed(current: &Json, baseline: &Json) -> BaselineComparison {
    let provenance = baseline.get("provenance").and_then(Json::as_str).unwrap_or("unknown");
    let measured = provenance == "measured";
    let same_scale = current.get("quick").and_then(Json::as_bool)
        == baseline.get("quick").and_then(Json::as_bool);
    let gating = measured && same_scale;
    let mut table = Table::new(
        "Current vs committed BENCH_sched_overhead.json (hot-path throughput)",
        &["metric", "committed", "current", "ratio"],
    );
    let mut regressions = Vec::new();
    for (path, label) in TRACKED {
        let base = get_f64(baseline, path);
        let cur = get_f64(current, path);
        let (Some(base), Some(cur)) = (base, cur) else {
            table.row(vec![label.into(), "-".into(), "-".into(), "-".into()]);
            continue;
        };
        let ratio = cur / base.max(1e-9);
        table.row(vec![
            label.into(),
            format!("{base:.0}"),
            format!("{cur:.0}"),
            format!("{ratio:.2}x"),
        ]);
        if gating && ratio < REGRESSION_FLOOR {
            regressions.push(format!(
                "REGRESSION: {label} at {ratio:.2}x of the committed measured baseline \
                 ({cur:.0} vs {base:.0}) — below the {REGRESSION_FLOOR} floor"
            ));
        }
    }
    let mut notes = Vec::new();
    if !measured {
        notes.push(format!(
            "note: committed baseline provenance is '{provenance}' (not 'measured') — \
             ratios above are context only, no regression gating"
        ));
    } else if !same_scale {
        notes.push(
            "note: committed baseline was recorded at a different --quick scale — \
             ratios above are context only, no regression gating"
                .to_string(),
        );
    }
    BaselineComparison { table, regressions, notes }
}

/// Render the result as tables (the CLI's human-readable half).
pub fn render_tables(result: &Json, opts: &OverheadOpts) -> Vec<Table> {
    let mut out = Vec::new();

    let mut t = Table::new(
        "Scheduler overhead: real engine, nop payloads (pure runtime cost)",
        &["scenario", "workers", "tasks/s", "ns/TAO"],
    );
    for name in overhead_scenarios(opts.quick) {
        let base = ["scenarios", name];
        let row = |field: &str| get_f64(result, &[base[0], base[1], field]).unwrap_or(f64::NAN);
        t.row(vec![
            name.to_string(),
            format!("{:.0}", row("workers")),
            format!("{:.0}", row("tasks_per_sec")),
            format!("{:.0}", row("ns_per_tao")),
        ]);
    }
    out.push(t);

    let mut t = Table::new(
        "Steal-heavy queue benchmark (1 owner + thieves, every item once)",
        &["impl", "ops/s", "steal ns", "stolen"],
    );
    t.row(vec![
        "chase-lev".into(),
        format!("{:.0}", get_f64(result, &["steal", "lockfree_ops_per_sec"]).unwrap_or(0.0)),
        format!("{:.1}", get_f64(result, &["steal", "lockfree_steal_ns"]).unwrap_or(0.0)),
        format!("{:.0}", get_f64(result, &["steal", "lockfree_stolen"]).unwrap_or(0.0)),
    ]);
    if let Some(mx_ops) = get_f64(result, &["steal", "mutex_ops_per_sec"]) {
        t.row(vec![
            "mutex".into(),
            format!("{mx_ops:.0}"),
            format!("{:.1}", get_f64(result, &["steal", "mutex_steal_ns"]).unwrap_or(0.0)),
            "-".into(),
        ]);
    }
    out.push(t);

    if opts.compare {
        if let Some(speedup) = get_f64(result, &["steal", "speedup_lockfree_over_mutex"]) {
            let mut t = Table::new(
                "Mutex vs lock-free (steal-heavy): speedup of the Chase-Lev path",
                &["metric", "lock-free", "mutex", "speedup"],
            );
            let lf_ops = get_f64(result, &["steal", "lockfree_ops_per_sec"]).unwrap_or(0.0);
            let mx_ops = get_f64(result, &["steal", "mutex_ops_per_sec"]).unwrap_or(0.0);
            t.row(vec![
                "queue ops/s".into(),
                format!("{lf_ops:.0}"),
                format!("{mx_ops:.0}"),
                format!("{speedup:.2}x"),
            ]);
            if let (Some(a), Some(b)) = (
                get_f64(result, &["queues", "wsq_push_pop_ns"]),
                get_f64(result, &["queues", "mutex_wsq_push_pop_ns"]),
            ) {
                t.row(vec![
                    "wsq push+pop ns".into(),
                    format!("{a:.1}"),
                    format!("{b:.1}"),
                    format!("{:.2}x", b / a.max(1e-9)),
                ]);
            }
            if let (Some(a), Some(b)) = (
                get_f64(result, &["queues", "aq_push_pop_ns"]),
                get_f64(result, &["queues", "mutex_aq_push_pop_ns"]),
            ) {
                t.row(vec![
                    "aq push+pop ns".into(),
                    format!("{a:.1}"),
                    format!("{b:.1}"),
                    format!("{:.2}x", b / a.max(1e-9)),
                ]);
            }
            out.push(t);
        }
    }

    let mut t = Table::new(
        "Steal pressure: single vs batched stealing (thief pack on one victim)",
        &["mode", "thieves", "ops/s", "speedup"],
    );
    let ps = |f: &str| get_f64(result, &["steal_pressure", f]).unwrap_or(f64::NAN);
    t.row(vec![
        "single-steal".into(),
        format!("{:.0}", ps("thieves")),
        format!("{:.0}", ps("single_ops_per_sec")),
        "1.00x".into(),
    ]);
    t.row(vec![
        "steal_half".into(),
        format!("{:.0}", ps("thieves")),
        format!("{:.0}", ps("batch_ops_per_sec")),
        format!("{:.2}x", ps("batch_speedup")),
    ]);
    out.push(t);

    let mut t = Table::new("Simulator event rate", &["metric", "value"]);
    t.row(vec![
        "simulated TAO/s (wall)".into(),
        format!("{:.0}", get_f64(result, &["sim", "sim_tao_per_sec"]).unwrap_or(0.0)),
    ]);
    out.push(t);
    out
}

/// `--pressure`: sweep the thief-pack size and pit single-steal against
/// batched [`WsQueue::steal_half`] at each point. Run on demand (it spawns
/// up to 17 threads), printed only — the fixed-pack point in the JSON is
/// the tracked metric; this sweep is for eyeballing where the crossover
/// sits on a given host.
pub fn render_pressure_sweep(opts: &OverheadOpts) -> Table {
    let items = if opts.quick { 30_000 } else { 200_000 };
    let mut t = Table::new(
        "Steal-pressure sweep: single vs batched stealing (WsQueue, 1 owner)",
        &["thieves", "single ops/s", "batched ops/s", "batch speedup"],
    );
    for nt in [1usize, 2, 4, 8, 16] {
        let single = {
            let q: WsQueue<usize> = WsQueue::new();
            run_steal_bench(&q, items, nt, false)
        };
        let batch = {
            let q: WsQueue<usize> = WsQueue::new();
            run_steal_bench(&q, items, nt, true)
        };
        t.row(vec![
            nt.to_string(),
            format!("{:.0}", single.ops_per_sec),
            format!("{:.0}", batch.ops_per_sec),
            format!("{:.2}x", batch.ops_per_sec / single.ops_per_sec.max(1e-9)),
        ]);
    }
    t
}

/// What [`emit_overhead`] produced: the machine-readable result plus the
/// number of baseline regressions flagged (0 when no committed baseline
/// gates the run). The CLI turns a non-zero count into a non-zero exit
/// code so the CI comparison step actually fails on a hot-path collapse.
pub struct OverheadRun {
    pub result: Json,
    pub regressions: usize,
}

/// CLI entry point: run, print tables, optionally write the JSON file.
/// Returns the result (and the flagged-regression count) so callers
/// (tests, benches, the CLI) can assert on it.
pub fn emit_overhead(opts: &OverheadOpts) -> OverheadRun {
    let result = run_overhead(opts);
    for t in render_tables(&result, opts) {
        println!("{}", t.render());
    }
    if opts.pressure {
        println!("{}", render_pressure_sweep(opts).render());
    }
    let mut regressions = 0usize;
    if opts.compare {
        // Compare against the committed record *before* --json overwrites
        // it, so a CI `--json --compare` run flags regressions vs the
        // checked-in numbers, not vs itself.
        let path = bench_json_path();
        match std::fs::read_to_string(&path)
            .map_err(|e| e.to_string())
            .and_then(|s| Json::parse(&s).map_err(|e| e.to_string()))
        {
            Ok(baseline) => {
                let cmp = compare_with_committed(&result, &baseline);
                println!("{}", cmp.table.render());
                for n in &cmp.notes {
                    println!("{n}");
                }
                for r in &cmp.regressions {
                    eprintln!("{r}");
                }
                regressions = cmp.regressions.len();
            }
            Err(e) => {
                println!("(no committed baseline to compare against: {e})");
            }
        }
    }
    if opts.json {
        let path = bench_json_path();
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    OverheadRun { result, regressions }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_overhead_run_is_well_formed() {
        let opts = OverheadOpts { quick: true, compare: true, ..Default::default() };
        let j = run_overhead(&opts);
        // Every quick-scale scenario (incl. hom64) has a positive tasks/sec.
        for name in OVERHEAD_SCENARIOS {
            let tps = get_f64(&j, &["scenarios", name, "tasks_per_sec"]).unwrap();
            assert!(tps > 0.0 && tps.is_finite(), "{name}: {tps}");
        }
        // The steal comparison is present and sane. The ≥1.5× win is only
        // expected on a multicore host under release optimizations, but a
        // *catastrophic inversion* (lock-free half the mutex throughput)
        // is a regression signal even in a noisy debug-mode test run — an
        // accidental contended RMW or lock on the fast path shows up far
        // below this floor.
        let sp = get_f64(&j, &["steal", "speedup_lockfree_over_mutex"]).unwrap();
        assert!(sp > 0.0 && sp.is_finite(), "speedup {sp}");
        let host_cores = get_f64(&j, &["host_cores"]).unwrap();
        if host_cores > 1.0 {
            assert!(sp >= 0.5, "lock-free path regressed to {sp:.2}x of the mutex baseline");
        }
        let lf = get_f64(&j, &["steal", "lockfree_ops_per_sec"]).unwrap();
        assert!(lf > 0.0);
        assert!(get_f64(&j, &["sim", "sim_tao_per_sec"]).unwrap() > 0.0);
        // Steal-pressure block: both modes measured, speedup consistent.
        let single = get_f64(&j, &["steal_pressure", "single_ops_per_sec"]).unwrap();
        let batch = get_f64(&j, &["steal_pressure", "batch_ops_per_sec"]).unwrap();
        let sp_batch = get_f64(&j, &["steal_pressure", "batch_speedup"]).unwrap();
        assert!(single > 0.0 && batch > 0.0);
        assert!((sp_batch - batch / single).abs() < 1e-6);
        // The trajectory records both points from THIS run.
        let traj = j.get("trajectory").and_then(Json::as_arr).unwrap();
        assert_eq!(traj.len(), 2);
        assert_eq!(traj[0].get("point").and_then(Json::as_str), Some("pr3-single-steal"));
        assert_eq!(traj[1].get("point").and_then(Json::as_str), Some("pr9-batched-steal"));
        assert!(traj[1].get("speedup_over_single").and_then(Json::as_f64).unwrap() > 0.0);
        // hom128 is full-mode only: a quick run must not have spawned it.
        assert!(j.get("scenarios").and_then(|s| s.get("hom128")).is_none());
        // Tables render without panicking.
        let tables = render_tables(&j, &opts);
        assert!(tables.len() >= 4);
        for t in tables {
            assert!(!t.render().is_empty());
        }
    }

    fn synthetic_result(scale: f64, provenance: &str, quick: bool) -> Json {
        let scen = |tps: f64| Json::obj(vec![("tasks_per_sec", Json::Num(tps * scale))]);
        Json::obj(vec![
            ("provenance", Json::Str(provenance.into())),
            ("quick", Json::Bool(quick)),
            (
                "scenarios",
                Json::obj(vec![
                    ("hom4", scen(300_000.0)),
                    ("hom20", scen(120_000.0)),
                    ("biglittle44", scen(200_000.0)),
                    ("hom64", scen(60_000.0)),
                ]),
            ),
            (
                "steal",
                Json::obj(vec![("lockfree_ops_per_sec", Json::Num(18e6 * scale))]),
            ),
            (
                "steal_pressure",
                Json::obj(vec![("batch_ops_per_sec", Json::Num(17e6 * scale))]),
            ),
            ("sim", Json::obj(vec![("sim_tao_per_sec", Json::Num(250_000.0 * scale))])),
        ])
    }

    #[test]
    fn baseline_comparison_flags_only_real_regressions_on_measured_baselines() {
        let baseline = synthetic_result(1.0, "measured", true);
        // Healthy run (noise-level wobble): table renders, nothing flagged.
        let cmp = compare_with_committed(&synthetic_result(0.9, "measured", true), &baseline);
        assert!(cmp.table.render().contains("hom4"));
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert!(cmp.notes.is_empty(), "{:?}", cmp.notes);
        // Collapsed hot path (below the floor on every metric): flagged.
        let cmp = compare_with_committed(&synthetic_result(0.3, "measured", true), &baseline);
        assert_eq!(cmp.regressions.len(), TRACKED.len(), "{:?}", cmp.regressions);
        assert!(cmp.regressions[0].contains("REGRESSION"));
    }

    #[test]
    fn baseline_comparison_never_gates_on_seed_estimates() {
        // The committed file starts life as a seed estimate (no toolchain
        // in the authoring container); it must inform, not gate.
        let baseline = synthetic_result(1.0, "seed-estimate (no local toolchain)", true);
        let cmp = compare_with_committed(&synthetic_result(0.1, "measured", true), &baseline);
        assert!(cmp.table.render().contains("sim TAO/s"));
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert_eq!(cmp.notes.len(), 1, "{:?}", cmp.notes);
        assert!(cmp.notes[0].contains("not 'measured'"), "{:?}", cmp.notes);
    }

    #[test]
    fn baseline_comparison_never_gates_across_quick_full_scales() {
        // A full-mode measured baseline under a --quick run (or vice
        // versa) measures a different workload size — context only.
        let baseline = synthetic_result(1.0, "measured", false);
        let cmp = compare_with_committed(&synthetic_result(0.1, "measured", true), &baseline);
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert_eq!(cmp.notes.len(), 1, "{:?}", cmp.notes);
        assert!(cmp.notes[0].contains("different --quick scale"), "{:?}", cmp.notes);
    }
}
