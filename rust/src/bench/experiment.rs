//! The experiment matrix (`repro experiment`): every registered policy ×
//! every platform scenario × stream seed, each cell anchored to its
//! makespan lower bound.
//!
//! This is the repo's one-stop comparison table. Individual figure
//! harnesses each sweep one axis; this harness sweeps the full cross
//! product so a policy's behaviour can be read *across* scenarios (does
//! the plan-ahead HEFT win on static heterogeneity but lose under
//! episodes?) and anchored in absolute terms: every row reports
//! `pct_of_bound` — the makespan as a percentage of the critical-path /
//! area lower bound ([`crate::coordinator::metrics::lower_bound`]), so
//! 100% is provably optimal and the slack above it upper-bounds what any
//! scheduler could still recover.
//!
//! Protocol (documented in EXPERIMENTS.md):
//! - per seed, *one* DAG (`DagParams::mix`) is shared by every
//!   (backend, scenario, policy) cell, so cells differ only in the thing
//!   under test; the real backend attaches small kernel payloads;
//! - sim rows carry the analytic model bound (sound for the simulator's
//!   performance model); real rows carry the trace-observed critical-path
//!   bound (sound for wall time) — see the lower-bound module docs for
//!   why the area argument is sim-only;
//! - the table aggregates seeds per cell; the JSON keeps every row.
//!
//! `--json` writes `BENCH_experiment.json` at the repository root; CI
//! runs `repro experiment --quick --json` and uploads it, and a
//! seed-estimate copy is committed for schema stability
//! (`tests/lower_bounds.rs` checks it).

use crate::coordinator::scheduler::policy_names;
use crate::dag_gen::{DagParams, generate};
use crate::exec::{RunOpts, run_triple};
use crate::kernels::KernelSizes;
use crate::platform::scenarios;
use crate::util::json::Json;
use crate::util::table::Table;

/// Harness options.
#[derive(Debug, Clone)]
pub struct ExperimentOpts {
    /// CI smoke scale: 1 seed, ≤ 40-task DAGs.
    pub quick: bool,
    /// Write `BENCH_experiment.json` at the repository root.
    pub json: bool,
    /// Execution backend(s): `sim`, `real` or `both`.
    pub backend: String,
    /// Stream seeds per cell (each seed generates one shared DAG).
    pub seeds: usize,
    /// Tasks per generated DAG.
    pub tasks: usize,
    /// Average-parallelism knob of the DAG generator.
    pub parallelism: f64,
    /// Base seed; cell seeds are `seed + i`.
    pub seed: u64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            quick: false,
            json: false,
            backend: "both".to_string(),
            seeds: 3,
            tasks: 120,
            parallelism: 4.0,
            seed: 0xE1,
        }
    }
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

/// Assemble the machine-readable experiment matrix. Prints nothing — see
/// [`emit_experiment`]. Panics on an unknown backend name (the CLI
/// validates first) and on registry inconsistencies.
pub fn run_experiment_json(opts: &ExperimentOpts) -> Json {
    let seeds = if opts.quick { 1 } else { opts.seeds.max(1) };
    let tasks = if opts.quick { opts.tasks.min(40) } else { opts.tasks };
    let backends: Vec<&str> = match opts.backend.as_str() {
        "both" => vec!["sim", "real"],
        "sim" => vec!["sim"],
        "real" => vec!["real"],
        other => panic!("unknown backend '{other}' (sim|real|both)"),
    };
    let mut rows = Vec::new();
    for be in &backends {
        for scen in scenarios::names() {
            let n_cores =
                scenarios::by_name(scen).expect("registered scenario").topo.n_cores();
            for pol in policy_names() {
                for si in 0..seeds {
                    let seed = opts.seed + si as u64;
                    // One DAG per seed, shared across every cell: cells
                    // differ only in (backend, scenario, policy).
                    let mut params = DagParams::mix(tasks, opts.parallelism, seed);
                    if *be == "real" {
                        params = params.with_payloads(KernelSizes::small());
                    }
                    let (dag, _) = generate(&params);
                    let run_opts = RunOpts { seed, ..Default::default() };
                    let run = run_triple(be, scen, pol, &dag, &run_opts)
                        .unwrap_or_else(|e| panic!("cell {be}/{scen}/{pol}: {e}"));
                    let r = &run.result;
                    let bound = r.bound.expect("triple drivers bound traced runs");
                    rows.push(Json::obj(vec![
                        ("backend", Json::Str(be.to_string())),
                        ("scenario", Json::Str(scen.to_string())),
                        ("policy", Json::Str(pol.to_string())),
                        ("seed", Json::Num(seed as f64)),
                        ("tasks", Json::Num(dag.len() as f64)),
                        ("makespan", Json::Num(r.makespan)),
                        ("bound_cp", Json::Num(bound.cp)),
                        ("bound_area", Json::Num(bound.area)),
                        ("bound", Json::Num(bound.combined())),
                        ("pct_of_bound", opt_num(bound.pct_of(r.makespan))),
                        ("throughput", Json::Num(r.throughput())),
                        ("utilisation", Json::Num(r.utilisation(n_cores))),
                    ]));
                }
            }
        }
    }
    Json::obj(vec![
        ("bench", Json::Str("experiment".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("tasks", Json::Num(tasks as f64)),
        ("parallelism", Json::Num(opts.parallelism)),
        ("seeds", Json::Num(seeds as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// Render the human-readable matrix, averaging seeds per cell (the JSON
/// keeps per-seed rows).
pub fn render_experiment_table(result: &Json) -> Table {
    let mut t = Table::new(
        "Experiment matrix: policy × scenario × backend vs makespan lower bound",
        &["backend", "scenario", "policy", "makespan", "bound", "% of bound", "tput", "util"],
    );
    let key = |r: &Json, k: &str| -> String {
        r.get(k).and_then(Json::as_str).unwrap_or("").to_string()
    };
    if let Some(rows) = result.get("rows").and_then(Json::as_arr) {
        let mut i = 0;
        while i < rows.len() {
            let (be, sc, po) =
                (key(&rows[i], "backend"), key(&rows[i], "scenario"), key(&rows[i], "policy"));
            let mut group: Vec<&Json> = Vec::new();
            while i < rows.len()
                && key(&rows[i], "backend") == be
                && key(&rows[i], "scenario") == sc
                && key(&rows[i], "policy") == po
            {
                group.push(&rows[i]);
                i += 1;
            }
            let mean = |k: &str| -> Option<f64> {
                let vals: Vec<f64> =
                    group.iter().filter_map(|r| r.get(k).and_then(Json::as_f64)).collect();
                if vals.is_empty() {
                    None
                } else {
                    Some(vals.iter().sum::<f64>() / vals.len() as f64)
                }
            };
            let num = |k: &str, digits: usize| -> String {
                mean(k).map_or("-".to_string(), |v| format!("{v:.digits$}"))
            };
            t.row(vec![
                be,
                sc,
                po,
                num("makespan", 4),
                num("bound", 4),
                mean("pct_of_bound").map_or("-".to_string(), |p| format!("{p:.1}%")),
                num("throughput", 0),
                num("utilisation", 2),
            ]);
        }
    }
    t
}

/// CLI entry point: run, print, optionally write the JSON file.
pub fn emit_experiment(opts: &ExperimentOpts) -> Json {
    let result = run_experiment_json(opts);
    println!("{}", render_experiment_table(&result).render());
    if opts.json {
        let path = super::overhead::repo_root_file("BENCH_experiment.json");
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sim_matrix_covers_every_cell_within_bounds() {
        let opts =
            ExperimentOpts { quick: true, backend: "sim".to_string(), ..Default::default() };
        let result = run_experiment_json(&opts);
        let rows = result.get("rows").and_then(Json::as_arr).expect("rows array");
        let n_cells = scenarios::names().len() * policy_names().len();
        assert_eq!(rows.len(), n_cells, "one row per (scenario × policy) cell");
        for r in rows {
            let cell = || {
                format!(
                    "{}/{}",
                    r.get("scenario").and_then(Json::as_str).unwrap_or("?"),
                    r.get("policy").and_then(Json::as_str).unwrap_or("?"),
                )
            };
            let bound = r.get("bound").and_then(Json::as_f64).expect("bound");
            assert!(bound > 0.0, "{}: degenerate bound", cell());
            // The acceptance criterion: no cell may beat its lower bound.
            let pct = r.get("pct_of_bound").and_then(Json::as_f64).expect("pct");
            assert!(pct >= 100.0 - 1e-6, "{}: {pct}% of bound", cell());
            let make = r.get("makespan").and_then(Json::as_f64).expect("makespan");
            assert!(make.is_finite() && make > 0.0, "{}: makespan {make}", cell());
        }
        let rendered = render_experiment_table(&result).render();
        assert!(rendered.contains("% of bound"));
        assert!(rendered.contains("portfolio"), "new planners appear in the table");
    }

    #[test]
    fn seeds_average_into_one_table_row_per_cell() {
        // Hand-built payload: two seeds of one cell must collapse to one
        // rendered row with the averaged pct.
        let row = |seed: f64, pct: f64| {
            Json::obj(vec![
                ("backend", Json::Str("sim".into())),
                ("scenario", Json::Str("tx2".into())),
                ("policy", Json::Str("heft".into())),
                ("seed", Json::Num(seed)),
                ("makespan", Json::Num(1.0)),
                ("bound", Json::Num(0.5)),
                ("pct_of_bound", Json::Num(pct)),
                ("throughput", Json::Num(10.0)),
                ("utilisation", Json::Num(0.5)),
            ])
        };
        let result = Json::obj(vec![(
            "rows",
            Json::Arr(vec![row(1.0, 110.0), row(2.0, 130.0)]),
        )]);
        let rendered = render_experiment_table(&result).render();
        assert!(rendered.contains("120.0%"), "mean of 110 and 130:\n{rendered}");
        assert_eq!(rendered.matches("tx2").count(), 1, "one aggregated row:\n{rendered}");
    }
}
