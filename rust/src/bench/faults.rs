//! The chaos harness (`repro bench-faults`): fault-injection sweep over
//! the registered fault scenarios × scheduling policy × execution
//! backend, with every faulted cell baselined against its *fault-free
//! twin* (same DAG, same seed, same platform with the fault episodes
//! stripped — [`crate::platform::EpisodeSchedule::without_faults`]).
//!
//! Per cell the harness reports:
//!
//! - **tasks lost** — admitted tasks minus committed trace records. The
//!   exactly-once reclamation guarantee says this is *always zero*: a
//!   fail-stopped core's queued and in-flight work is re-admitted
//!   elsewhere, and the shared core's commit latch absorbs any duplicate
//!   the re-admission could produce. The CLI exits non-zero if a cell
//!   loses (or duplicates) anything.
//! - **makespan inflation** — faulted makespan as a percentage of the
//!   fault-free twin's. The honest cost of the fault + recovery, not an
//!   abstract recovery count.
//! - **recovery latency** — for scenarios whose fail-stop episodes have a
//!   finite recovery boundary: the gap between the recovery instant and
//!   the first task that *starts* on a recovered core. Measures how fast
//!   the scheduler folds a returning core back in (placement unmasking +
//!   steal traffic), straight from the trace records.
//!
//! The DAG is a layered grid sized per scenario so the run provably
//! outlives the fault window (`span ≈ 1.5 × latest fault boundary`):
//! `n_cores` columns of equal ~2 ms tasks with a same-column and a
//! neighbour-column edge into the next layer, so commits keep waking
//! work across lanes while cores die and return. Real-backend tasks
//! carry a sleep payload of the same duration, making wall-clock spans
//! match virtual ones without burning CPU on oversubscribed hosts.
//!
//! `--json` writes `BENCH_fault_recovery.json` at the repository root;
//! CI runs `repro bench-faults --quick --json` and uploads it, and a
//! seed-estimate copy is committed for schema stability.

use crate::coordinator::metrics::RunResult;
use crate::coordinator::scheduler::policy_by_name;
use crate::coordinator::{RealEngineOpts, TaoDag, payload_fn, run_dag_real};
use crate::error::SchedError;
use crate::platform::{EpisodeKind, KernelClass, Partition, Platform, scenarios};
use crate::sim::{SimOpts, run_dag_sim};
use crate::util::json::Json;
use crate::util::table::Table;
use std::time::Duration;

/// Policies the chaos harness sweeps. The dynamic (reactive) policies
/// are the interesting axis — they are the ones that can *respond* to a
/// mid-run outage; the plan-ahead planners meet the fault scenarios in
/// the experiment matrix (`repro experiment`), where their stale plans
/// are remapped off dead cores by the shared core. Quick mode keeps the
/// first two.
pub const FAULT_POLICIES: [&str; 4] = ["performance", "homogeneous", "cats", "dheft"];

/// Harness options.
#[derive(Debug, Clone)]
pub struct FaultBenchOpts {
    /// CI smoke scale: 1 seed, 2 policies, coarser (4 ms) tasks.
    pub quick: bool,
    /// Write `BENCH_fault_recovery.json` at the repository root.
    pub json: bool,
    /// Execution backend(s): `sim`, `real` or `both`.
    pub backend: String,
    /// Engine seeds per cell (victim selection / PTT noise draws).
    pub seeds: usize,
    /// Base seed; cell seeds are `seed + i`.
    pub seed: u64,
}

impl Default for FaultBenchOpts {
    fn default() -> Self {
        FaultBenchOpts {
            quick: false,
            json: false,
            backend: "both".to_string(),
            seeds: 2,
            seed: 0xFA,
        }
    }
}

/// Names of every registered platform scenario that schedules at least
/// one fault episode — the sweep axis, derived from the registry so new
/// fault scenarios join the harness automatically.
pub fn fault_scenario_names() -> Vec<&'static str> {
    scenarios::scenarios()
        .iter()
        .filter(|s| s.platform().episodes.has_faults())
        .map(|s| s.name)
        .collect()
}

/// Latest fault boundary of the platform's schedule: the run must outlive
/// this to exercise the whole fault (and observe any recovery).
fn fault_horizon(plat: &Platform) -> f64 {
    let mut h: f64 = 0.0;
    for e in &plat.episodes.episodes {
        if e.is_fault() {
            h = h.max(e.t_start);
            if e.t_end.is_finite() {
                h = h.max(e.t_end);
            }
        }
    }
    h
}

/// Build the layered chaos DAG for `plat`: `layers × n_cores` tasks of
/// `task_exec` seconds each (virtual via `work_scale`, wall via a sleep
/// payload), each non-root depending on its own column and its left
/// neighbour's in the previous layer. Sized from the platform's fault
/// schedule so the run outlives every fault boundary; public because the
/// fault integration tests (`tests/faults.rs`) drive the engines with
/// the same workload directly.
pub fn chaos_dag(plat: &Platform, task_exec: f64) -> TaoDag {
    let n = plat.topo.n_cores();
    let span = (1.5 * fault_horizon(plat)).max(0.3);
    let layers = (span / task_exec).ceil() as usize;
    // work_scale calibrates the *simulated* duration to task_exec on the
    // scenario's core 0; the payload fixes the *wall* duration directly.
    let scale =
        task_exec / plat.ideal_exec_time(KernelClass::MatMul, Partition { leader: 0, width: 1 });
    let sleep = Duration::from_secs_f64(task_exec);
    let mut dag = TaoDag::new();
    let mut prev: Vec<usize> = Vec::new();
    for layer in 0..layers {
        let mut cur = Vec::with_capacity(n);
        for col in 0..n {
            let t = dag.add_task_payload(
                KernelClass::MatMul,
                0,
                scale,
                Some(payload_fn(KernelClass::MatMul, move |_, _| std::thread::sleep(sleep))),
            );
            if layer > 0 {
                dag.add_edge(prev[col], t);
                dag.add_edge(prev[(col + 1) % n], t);
            }
            cur.push(t);
        }
        prev = cur;
    }
    dag.finalize().expect("layered grid is acyclic");
    dag
}

/// Run one (backend, platform, policy) cell on the given DAG.
fn run_cell(
    be: &str,
    plat: &Platform,
    policy_name: &str,
    dag: &TaoDag,
    seed: u64,
) -> Result<RunResult, SchedError> {
    let policy = policy_by_name(policy_name, plat.topo.n_cores()).expect("registered policy");
    match be {
        "sim" => {
            run_dag_sim(dag, plat, policy.as_ref(), None, &SimOpts { seed, ..Default::default() })
                .map(|run| run.result)
        }
        "real" => {
            let opts = RealEngineOpts {
                seed,
                episodes: plat.episodes.clone(),
                ..Default::default()
            };
            run_dag_real(dag, &plat.topo, policy.as_ref(), None, &opts)
        }
        other => panic!("unknown backend '{other}' (sim|real|both)"),
    }
}

/// Tasks that committed more than once (must be 0: the commit latch
/// makes re-admitted duplicates no-ops).
fn duplicates(res: &RunResult) -> usize {
    let mut ids: Vec<usize> = res.records.iter().map(|r| r.task).collect();
    ids.sort_unstable();
    ids.dedup();
    res.records.len() - ids.len()
}

/// Recovery latency: for each fail-stop episode with a finite recovery
/// boundary, the gap from that boundary to the first record *starting*
/// on one of its cores; `None` if nothing ever recovers (or the run
/// drained before touching a recovered core).
fn recovery_latency(plat: &Platform, res: &RunResult) -> Option<f64> {
    let mut best: Option<f64> = None;
    for e in &plat.episodes.episodes {
        if !matches!(e.kind, EpisodeKind::FailStop { .. }) || !e.t_end.is_finite() {
            continue;
        }
        let first = res
            .records
            .iter()
            .filter(|r| {
                r.t_start >= e.t_end && r.partition.cores().any(|c| e.cores.contains(&c))
            })
            .map(|r| r.t_start - e.t_end)
            .fold(f64::INFINITY, f64::min);
        if first.is_finite() {
            best = Some(best.map_or(first, |b: f64| b.min(first)));
        }
    }
    best
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

/// Assemble the machine-readable fault-recovery matrix. Prints nothing —
/// see [`emit_faults`]. Panics on an unknown backend name (the CLI
/// validates first) and on a cell that errors out: every registered
/// fault scenario leaves live cores, so a `SchedError` here is a bug.
pub fn run_faults_json(opts: &FaultBenchOpts) -> Json {
    let seeds = if opts.quick { 1 } else { opts.seeds.max(1) };
    let task_exec = if opts.quick { 4e-3 } else { 2e-3 };
    let policies: &[&str] =
        if opts.quick { &FAULT_POLICIES[..2] } else { &FAULT_POLICIES };
    let backends: Vec<&str> = match opts.backend.as_str() {
        "both" => vec!["sim", "real"],
        "sim" => vec!["sim"],
        "real" => vec!["real"],
        other => panic!("unknown backend '{other}' (sim|real|both)"),
    };
    let mut rows = Vec::new();
    for scen in fault_scenario_names() {
        let plat = scenarios::by_name(scen).expect("registered scenario");
        let twin = Platform { episodes: plat.episodes.without_faults(), ..plat.clone() };
        // One DAG per scenario, shared by every cell: cells differ only
        // in (backend, policy, seed, faults on/off).
        let dag = chaos_dag(&plat, task_exec);
        for be in &backends {
            for pol in policies {
                for si in 0..seeds {
                    let seed = opts.seed + si as u64;
                    let cell = |p: &Platform| {
                        run_cell(be, p, pol, &dag, seed)
                            .unwrap_or_else(|e| panic!("cell {be}/{scen}/{pol}: {e}"))
                    };
                    let faulted = cell(&plat);
                    let free = cell(&twin);
                    let lost = dag.len() - {
                        let mut ids: Vec<usize> =
                            faulted.records.iter().map(|r| r.task).collect();
                        ids.sort_unstable();
                        ids.dedup();
                        ids.len()
                    };
                    rows.push(Json::obj(vec![
                        ("backend", Json::Str(be.to_string())),
                        ("scenario", Json::Str(scen.to_string())),
                        ("policy", Json::Str(pol.to_string())),
                        ("seed", Json::Num(seed as f64)),
                        ("tasks", Json::Num(dag.len() as f64)),
                        ("makespan", Json::Num(faulted.makespan)),
                        ("makespan_fault_free", Json::Num(free.makespan)),
                        (
                            "inflation_pct",
                            Json::Num(100.0 * faulted.makespan / free.makespan),
                        ),
                        ("recovery_latency", opt_num(recovery_latency(&plat, &faulted))),
                        ("tasks_lost", Json::Num(lost as f64)),
                        ("duplicates", Json::Num(duplicates(&faulted) as f64)),
                    ]));
                }
            }
        }
    }
    Json::obj(vec![
        ("bench", Json::Str("fault_recovery".into())),
        ("schema", Json::Num(1.0)),
        ("provenance", Json::Str("measured".into())),
        ("quick", Json::Bool(opts.quick)),
        ("task_exec", Json::Num(task_exec)),
        ("seeds", Json::Num(seeds as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// Render the human-readable fault matrix (one row per JSON row — the
/// sweep is small enough that per-seed rows read fine).
pub fn render_faults_table(result: &Json) -> Table {
    let mut t = Table::new(
        "Chaos harness: fault scenario × policy × backend vs fault-free twin",
        &["backend", "scenario", "policy", "makespan", "vs fault-free", "recovery", "lost", "dup"],
    );
    if let Some(rows) = result.get("rows").and_then(Json::as_arr) {
        for r in rows {
            let s = |k: &str| r.get(k).and_then(Json::as_str).unwrap_or("").to_string();
            let f = |k: &str| r.get(k).and_then(Json::as_f64);
            t.row(vec![
                s("backend"),
                s("scenario"),
                s("policy"),
                f("makespan").map_or("-".into(), |v| format!("{v:.4}")),
                f("inflation_pct").map_or("-".into(), |v| format!("{v:.1}%")),
                f("recovery_latency").map_or("-".into(), |v| format!("{:.1} ms", v * 1e3)),
                f("tasks_lost").map_or("-".into(), |v| format!("{v:.0}")),
                f("duplicates").map_or("-".into(), |v| format!("{v:.0}")),
            ]);
        }
    }
    t
}

/// CLI entry point: run, print, optionally write the JSON file.
pub fn emit_faults(opts: &FaultBenchOpts) -> Json {
    let result = run_faults_json(opts);
    println!("{}", render_faults_table(&result).render());
    if opts.json {
        let path = super::overhead::repo_root_file("BENCH_fault_recovery.json");
        match std::fs::write(&path, result.to_pretty()) {
            Ok(()) => println!("[json] {}", path.display()),
            Err(e) => eprintln!("[json] write failed ({}): {e}", path.display()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_exposes_the_three_fault_scenarios() {
        let names = fault_scenario_names();
        for expect in ["failstop20", "failstop-recover8", "failslow-biglittle44"] {
            assert!(names.contains(&expect), "{expect} missing from {names:?}");
        }
    }

    #[test]
    fn quick_sim_sweep_loses_nothing_and_degrades_gracefully() {
        let opts = FaultBenchOpts {
            quick: true,
            backend: "sim".to_string(),
            ..Default::default()
        };
        let result = run_faults_json(&opts);
        let rows = result.get("rows").and_then(Json::as_arr).expect("rows array");
        assert_eq!(
            rows.len(),
            fault_scenario_names().len() * 2,
            "one row per (fault scenario × quick policy)"
        );
        for r in rows {
            let cell = || {
                format!(
                    "{}/{}",
                    r.get("scenario").and_then(Json::as_str).unwrap_or("?"),
                    r.get("policy").and_then(Json::as_str).unwrap_or("?"),
                )
            };
            let f = |k: &str| r.get(k).and_then(Json::as_f64);
            // The exactly-once acceptance criterion.
            assert_eq!(f("tasks_lost"), Some(0.0), "{}: lost tasks", cell());
            assert_eq!(f("duplicates"), Some(0.0), "{}: duplicate commits", cell());
            let make = f("makespan").expect("makespan");
            assert!(make.is_finite() && make > 0.0, "{}: makespan {make}", cell());
            // Faults can only hurt (small tolerance for rng divergence
            // between the faulted run and its twin).
            let infl = f("inflation_pct").expect("inflation");
            assert!(infl >= 99.0, "{}: inflation {infl}% — fault sped the run up?", cell());
            // A recovered half-machine must be folded back in.
            if r.get("scenario").and_then(Json::as_str) == Some("failstop-recover8") {
                let lat = f("recovery_latency")
                    .unwrap_or_else(|| panic!("{}: no recovery observed", cell()));
                assert!(
                    (0.0..0.2).contains(&lat),
                    "{}: recovery latency {lat}s",
                    cell()
                );
            }
        }
        let rendered = render_faults_table(&result).render();
        assert!(rendered.contains("vs fault-free"));
        assert!(rendered.contains("failstop20"));
    }

    #[test]
    fn chaos_dag_outlives_the_fault_window() {
        let plat = scenarios::by_name("failstop20").unwrap();
        let dag = chaos_dag(&plat, 4e-3);
        // 20 columns, span ≥ 1.5 × 0.25 s at 4 ms per task.
        assert_eq!(dag.len() % 20, 0);
        assert!(dag.len() / 20 >= (0.375f64 / 4e-3) as usize);
        // Serial work per column alone already exceeds the horizon.
        assert!(dag.len() as f64 / 20.0 * 4e-3 > fault_horizon(&plat));
    }
}
