//! Unified execution backends: one seam over virtual-time simulation and
//! real-thread execution.
//!
//! The paper's core claim — PTT-guided scheduling adapts to both static
//! heterogeneity and dynamic interference — is only meaningful if the same
//! scheduling code runs identically in virtual time (`crate::sim`) and on
//! real threads (`crate::coordinator::worker`). This module is the seam
//! that enforces it: both engines are reachable through one trait,
//!
//! ```text
//! ExecutionBackend::run(dag, platform, policy, ptt, opts) -> BackendRun
//! ```
//!
//! with one [`RunOpts`] (seed, trace, PTT probe, pinning), so the CLI, the
//! figure harnesses and the conformance tests select a backend *by name*
//! instead of branching on `--real`. Combined with the platform scenario
//! registry ([`crate::platform::scenarios`]), any
//! `(backend × policy × platform)` triple is one call: [`run_triple`].
//!
//! Multi-application workload streams go through the same seam:
//! `ExecutionBackend::run_stream(stream, ...)` admits each app's DAG at
//! its arrival time and returns per-app metrics ([`StreamRun`]);
//! [`run_stream_triple`] is the by-name variant, optionally attaching
//! isolated-run baselines for slowdown/fairness. `run` is the one-app,
//! arrival-0 special case of `run_stream` — a parity the multi-app test
//! suite pins bit-for-bit on the sim backend.
//!
//! Semantics shared by both backends:
//! - the DAG must be finalized and non-empty;
//! - a fresh PTT is created when `ptt` is `None`; passing a warm table
//!   chains runs (the VGG scalability study relies on this);
//! - the returned trace has one record per executed TAO, with partitions
//!   valid on the given platform's topology. The sim backend sorts records
//!   by start time (its single-threaded completion order is already
//!   deterministic); the real backend imposes the deterministic
//!   `(t_end, task)` total order so the per-worker trace-shard layout can
//!   never leak into the result (`metrics::sort_by_commit`).
//!
//! Differences that remain by design: the simulated backend interprets the
//! platform's performance model and episode schedule in virtual time and
//! is bit-for-bit deterministic under a fixed seed; the real backend runs
//! `topo.n_cores()` worker threads on the host in wall time, so makespans
//! are host-dependent (and `ptt_probe` sampling is sim-only).
//!
//! Every entry point returns `Result<_, SchedError>`: a wedged run (true
//! scheduler deadlock, or a fault schedule that fail-stops every core with
//! no recovery) is a reportable value, not a process abort — the CLI
//! prints it and exits non-zero, bench harnesses decide per-cell.

use crate::coordinator::core::{ServingOpts, ServingRun};
use crate::coordinator::dag::TaoDag;
use crate::coordinator::list_sched::planned_policy;
use crate::coordinator::metrics::lower_bound::{
    model_bound, observed_app_bound, observed_bound, observed_cp_bound,
};
use crate::coordinator::metrics::{
    AppMetrics, RunResult, jain_fairness_index, jain_fairness_total, per_app_metrics,
};
use crate::coordinator::ptt::Ptt;
use crate::coordinator::scheduler::{Policy, QosClass, policy_by_name};
use crate::coordinator::worker::{
    RealEngineOpts, run_dag_real, run_serving_real, run_stream_real,
};
use crate::error::SchedError;
use crate::platform::{Platform, scenarios};
use crate::sim::{SimOpts, run_dag_sim, run_serving_sim, run_stream_sim};
use crate::util::stats;
use crate::workload::{MultiDag, ServingStream, WorkloadStream};
use std::collections::HashSet;

/// Options understood by every backend.
#[derive(Debug, Clone)]
pub struct RunOpts {
    /// Seed for root distribution, steal-victim selection and sim jitter.
    pub seed: u64,
    /// Keep the per-task trace in the result. Disabling it clears
    /// `RunResult::records` (makespan is still reported) — for huge DAGs
    /// where only aggregate timing matters.
    pub trace: bool,
    /// Sample the PTT entry `(type_id, core, width)` after every event —
    /// the Fig 8(a) value trace. Simulated backend only.
    pub ptt_probe: Option<(usize, usize, usize)>,
    /// Pin worker threads to host CPUs (real backend only). Currently a
    /// documented no-op: the offline build omits the libc affinity call,
    /// and this knob stays plumbed so multicore deployments can wire OS
    /// pinning back in at `coordinator::worker::pin_to_cpu`.
    pub pin_threads: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        // The seed matches the simulator's historical default so existing
        // figure outputs are unchanged by the backend refactor.
        RunOpts { seed: 0x51b, trace: true, ptt_probe: None, pin_threads: false }
    }
}

/// Result of one backend run: the engine-independent [`RunResult`] plus
/// probe samples (empty unless the sim backend ran with a probe).
#[derive(Debug, Clone)]
pub struct BackendRun {
    pub result: RunResult,
    /// `(time, PTT value)` samples if a probe was configured.
    pub ptt_samples: Vec<(f64, f64)>,
}

/// Result of one workload-stream run: the combined trace plus the per-app
/// accounting derived from it (slowdowns are filled only by baseline-aware
/// drivers such as [`run_stream_triple`]).
#[derive(Debug, Clone)]
pub struct StreamRun {
    pub result: RunResult,
    pub apps: Vec<AppMetrics>,
    /// `(time, PTT value)` samples if a probe was configured (sim only).
    pub ptt_samples: Vec<(f64, f64)>,
}

impl StreamRun {
    /// Jain fairness index across applications: over `1/slowdown` when
    /// every app carries an isolated baseline (the literature's metric),
    /// otherwise over per-app throughput (tasks per response-time second).
    /// 1.0 = perfectly fair; → `1/n` as one app monopolises the machine.
    /// `None` when no apps ran — an empty run has no fairness to report
    /// (printing `1.00` for it would claim perfection for a no-op).
    pub fn jain_fairness(&self) -> Option<f64> {
        if self.apps.is_empty() {
            return None;
        }
        let xs: Vec<f64> = if self.apps.iter().all(|a| a.slowdown.is_some()) {
            self.apps.iter().map(|a| 1.0 / a.slowdown.unwrap().max(1e-12)).collect()
        } else {
            self.apps
                .iter()
                .map(|a| {
                    if a.n_tasks == 0 {
                        // Zero progress is the *worst* allocation, not the
                        // best — score it near-zero so starvation drags the
                        // index down instead of masquerading as dominance.
                        1e-12
                    } else {
                        a.n_tasks as f64 / a.makespan().max(1e-12)
                    }
                })
                .collect()
        };
        Some(jain_fairness_index(&xs))
    }
}

/// An execution substrate for TAO-DAGs under a scheduling policy.
pub trait ExecutionBackend: Send + Sync {
    /// Canonical backend name (`"sim"` / `"real"`).
    fn name(&self) -> &'static str;

    /// Execute `dag` under `policy` on `plat`, observing `opts`.
    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError>;

    /// Execute a materialised multi-app stream ([`MultiDag`]): every app's
    /// roots are admitted at their arrival time, records are tagged with
    /// `app_id`. The single-DAG [`ExecutionBackend::run`] is the
    /// one-app/arrival-0 special case of this entry point.
    fn run_multi(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError>;

    /// Execute a serving-mode workload ([`MultiDag`] built from a
    /// [`ServingStream`] window): offers go through [`ServingSource`]
    /// backpressure, QoS classes steer shed/delay decisions, and the
    /// fairness feedback loop drives [`Policy::on_fairness`]. Returns the
    /// raw engine outcome; [`run_serving_triple`] layers metrics on top.
    ///
    /// [`ServingSource`]: crate::coordinator::ServingSource
    /// [`Policy::on_fairness`]: crate::coordinator::Policy::on_fairness
    fn run_serving(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
        serving: &ServingOpts,
    ) -> Result<ServingRun, SchedError>;

    /// Execute a workload stream end-to-end: materialise it, run it, and
    /// derive the per-app metrics (no isolated baselines — see
    /// [`run_stream_triple`] for slowdown-aware runs).
    fn run_stream(
        &self,
        stream: &WorkloadStream,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<StreamRun, SchedError> {
        let multi = stream.build();
        // Per-app accounting needs the tagged records even when the caller
        // wants a trace-free result, so honour `trace: false` only after
        // the metrics are derived.
        let traced = RunOpts { trace: true, ..opts.clone() };
        let mut run = self.run_multi(&multi, plat, policy, ptt, &traced)?;
        let apps = per_app_metrics(&run.result, &multi.app_index());
        if !opts.trace {
            run.result.records.clear();
        }
        Ok(StreamRun { result: run.result, apps, ptt_samples: run.ptt_samples })
    }
}

/// Discrete-event execution against the analytic platform model
/// ([`run_dag_sim`]) — deterministic, virtual time.
#[derive(Debug, Default)]
pub struct SimBackend;

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError> {
        let run = run_dag_sim(
            dag,
            plat,
            policy,
            ptt,
            &SimOpts { seed: opts.seed, ptt_probe: opts.ptt_probe, ..Default::default() },
        )?;
        let mut result = run.result;
        if !opts.trace {
            result.records.clear();
        }
        Ok(BackendRun { result, ptt_samples: run.ptt_samples })
    }

    fn run_multi(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError> {
        let run = run_stream_sim(
            &multi.dag,
            &multi.app_of,
            &multi.admissions(),
            plat,
            policy,
            ptt,
            &SimOpts { seed: opts.seed, ptt_probe: opts.ptt_probe, ..Default::default() },
        )?;
        let mut result = run.result;
        if !opts.trace {
            result.records.clear();
        }
        Ok(BackendRun { result, ptt_samples: run.ptt_samples })
    }

    fn run_serving(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
        serving: &ServingOpts,
    ) -> Result<ServingRun, SchedError> {
        run_serving_sim(
            &multi.dag,
            &multi.app_of,
            multi.serving_apps(),
            multi.app_qos(),
            plat,
            policy,
            ptt,
            &SimOpts { seed: opts.seed, ..Default::default() },
            serving,
        )
    }
}

/// Real worker threads on the host ([`run_dag_real`]) — wall time. Uses
/// the platform's topology and **episode schedule** (realized in wall
/// clock by `coordinator::episodes_rt`: interference episodes spawn
/// background spinner threads, affected cores are duty-cycle throttled);
/// the analytic performance model is ignored (the host *is* the model).
#[derive(Debug, Default)]
pub struct RealBackend;

impl ExecutionBackend for RealBackend {
    fn name(&self) -> &'static str {
        "real"
    }

    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError> {
        let mut result = run_dag_real(
            dag,
            &plat.topo,
            policy,
            ptt,
            &RealEngineOpts {
                pin_threads: opts.pin_threads,
                seed: opts.seed,
                episodes: plat.episodes.clone(),
                ..Default::default()
            },
        )?;
        if !opts.trace {
            result.records.clear();
        }
        Ok(BackendRun { result, ptt_samples: Vec::new() })
    }

    fn run_multi(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> Result<BackendRun, SchedError> {
        let mut result = run_stream_real(
            &multi.dag,
            &multi.app_of,
            &multi.admissions(),
            &plat.topo,
            policy,
            ptt,
            &RealEngineOpts {
                pin_threads: opts.pin_threads,
                seed: opts.seed,
                episodes: plat.episodes.clone(),
                ..Default::default()
            },
        )?;
        if !opts.trace {
            result.records.clear();
        }
        Ok(BackendRun { result, ptt_samples: Vec::new() })
    }

    fn run_serving(
        &self,
        multi: &MultiDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
        serving: &ServingOpts,
    ) -> Result<ServingRun, SchedError> {
        run_serving_real(
            &multi.dag,
            &multi.app_of,
            multi.serving_apps(),
            multi.app_qos(),
            &plat.topo,
            policy,
            ptt,
            &RealEngineOpts {
                pin_threads: opts.pin_threads,
                seed: opts.seed,
                episodes: plat.episodes.clone(),
                ..Default::default()
            },
            serving,
        )
    }
}

/// Canonical backend names, in registry order.
pub const BACKEND_NAMES: [&str; 2] = ["sim", "real"];

/// Construct a backend by CLI/config name (with common aliases).
pub fn backend_by_name(name: &str) -> Option<Box<dyn ExecutionBackend>> {
    match name {
        "sim" | "simulated" | "virtual" => Some(Box::new(SimBackend)),
        "real" | "threads" | "native" => Some(Box::new(RealBackend)),
        _ => None,
    }
}

/// Whether a backend name (canonical or alias) selects the simulated
/// backend — the one whose makespans the analytic model bounds apply to.
fn is_sim_backend(name: &str) -> bool {
    matches!(name, "sim" | "simulated" | "virtual")
}

/// Resolve a policy name for one specific `(platform, dag)` run.
///
/// Plan-ahead names (`heft`, `peft`, `dls`, `portfolio` — see
/// [`crate::coordinator::list_sched`]) need to see the whole DAG before
/// the first task runs, which the global registry cannot provide; they
/// get a freshly planned instance here. Online names resolve through the
/// ordinary [`policy_by_name`] registry. `None` for unknown names.
pub fn policy_for_run(
    name: &str,
    plat: &Platform,
    dag: &TaoDag,
) -> Option<Box<dyn Policy>> {
    if let Some(planned) = planned_policy(name, dag, plat) {
        return Some(planned);
    }
    policy_by_name(name, plat.topo.n_cores())
}

/// Run any `(backend × scenario × policy)` triple in one call.
///
/// Resolves all three registries and executes `dag`; errors name the
/// offending registry so CLI surfaces stay helpful. Plan-ahead policies
/// are planned against this DAG before the run ([`policy_for_run`]).
///
/// The result carries a makespan lower bound: the analytic
/// [`model_bound`] for the simulated backend, the trace-derived
/// [`observed_cp_bound`] for wall-clock runs (`None` when the trace was
/// disabled — nothing to bound from).
pub fn run_triple(
    backend: &str,
    scenario: &str,
    policy: &str,
    dag: &TaoDag,
    opts: &RunOpts,
) -> Result<BackendRun, String> {
    let plat = scenarios::by_name(scenario)
        .ok_or_else(|| format!("unknown platform scenario '{scenario}'"))?;
    let policy = policy_for_run(policy, &plat, dag)
        .ok_or_else(|| format!("unknown policy '{policy}'"))?;
    let backend_name = backend;
    let backend =
        backend_by_name(backend).ok_or_else(|| format!("unknown backend '{backend}'"))?;
    let mut run =
        backend.run(dag, &plat, policy.as_ref(), None, opts).map_err(|e| e.to_string())?;
    run.result.bound = if is_sim_backend(backend_name) {
        Some(model_bound(dag, &plat))
    } else if !run.result.records.is_empty() {
        Some(observed_cp_bound(dag, &run.result.records))
    } else {
        None
    };
    Ok(run)
}

/// Run any `(backend × scenario × policy)` triple over a workload stream.
///
/// With `with_baseline`, every admitted app is additionally run *alone* —
/// same backend, platform and policy name, but a fresh policy instance and
/// a fresh PTT — and the per-app slowdown (co-run makespan / isolated
/// makespan) is attached; [`StreamRun::jain_fairness`] then ranks
/// schedulers by how evenly they spread the contention. Baselines
/// regenerate each app's DAG from its recorded [`crate::workload::AdmittedApp::params`],
/// so periodic copies are compared against their own instance.
pub fn run_stream_triple(
    backend: &str,
    scenario: &str,
    policy: &str,
    stream: &WorkloadStream,
    opts: &RunOpts,
    with_baseline: bool,
) -> Result<StreamRun, String> {
    let plat = scenarios::by_name(scenario)
        .ok_or_else(|| format!("unknown platform scenario '{scenario}'"))?;
    let policy_name = policy;
    let backend_name = backend;
    let backend =
        backend_by_name(backend).ok_or_else(|| format!("unknown backend '{backend}'"))?;
    let multi = stream.build();
    // Plan-ahead policies plan the *combined* stream DAG (all apps'
    // components at once, arrivals unseen) — the honest translation of an
    // offline planner to an online admission setting; their per-app
    // baselines below plan each app's DAG alone, like the literature.
    let policy = policy_for_run(policy_name, &plat, &multi.dag)
        .ok_or_else(|| format!("unknown policy '{policy_name}'"))?;
    let traced = RunOpts { trace: true, ..opts.clone() };
    let mut run = backend
        .run_multi(&multi, &plat, policy.as_ref(), None, &traced)
        .map_err(|e| e.to_string())?;
    // Observed bounds from the (always traced) combined run: CP+area on
    // the sim's exact busy intervals, CP-only for wall-clock records.
    let is_sim = is_sim_backend(backend_name);
    run.result.bound = Some(if is_sim {
        observed_bound(&multi.dag, &run.result.records, plat.topo.n_cores())
    } else {
        observed_cp_bound(&multi.dag, &run.result.records)
    });
    let mut apps = per_app_metrics(&run.result, &multi.app_index());
    for metrics in apps.iter_mut() {
        metrics.bound = observed_app_bound(
            &multi.dag,
            &run.result.records,
            metrics.app_id,
            plat.topo.n_cores(),
            is_sim,
        );
    }
    if with_baseline {
        for (metrics, app) in apps.iter_mut().zip(&multi.apps) {
            // Fresh policy instance per baseline: stateful baselines
            // (dHEFT's availability clocks) must not leak between runs.
            let (dag, _) = crate::dag_gen::generate(&app.params);
            let iso_policy =
                policy_for_run(policy_name, &plat, &dag).expect("policy resolved above");
            let iso_opts = RunOpts { trace: false, ptt_probe: None, ..opts.clone() };
            let iso = backend
                .run(&dag, &plat, iso_policy.as_ref(), None, &iso_opts)
                .map_err(|e| e.to_string())?;
            *metrics = metrics.clone().with_isolated(iso.result.makespan);
        }
    }
    if !opts.trace {
        run.result.records.clear();
    }
    Ok(StreamRun { result: run.result, apps, ptt_samples: run.ptt_samples })
}

/// Result of one serving-mode run with derived metrics: the raw engine
/// outcome plus per-admitted-app accounting (shed apps never ran and have
/// no metrics row) and the serving horizon the rates are normalised by.
#[derive(Debug)]
pub struct ServingReport {
    pub run: ServingRun,
    /// Metrics of the *admitted* apps, in `app_id` order.
    pub apps: Vec<AppMetrics>,
    /// QoS class per row of `apps`.
    pub app_qos: Vec<QosClass>,
    /// Serving window length (backend seconds).
    pub horizon: f64,
}

impl ServingReport {
    /// Sustained admission rate: apps actually admitted per horizon second.
    pub fn admissions_per_sec(&self) -> f64 {
        self.run.counters.admitted.iter().sum::<usize>() as f64 / self.horizon
    }

    /// Apps offered by the arrival process (admitted + shed; delay events
    /// re-offer the same app and are not counted here).
    pub fn offered(&self) -> usize {
        self.run.counters.admitted.iter().sum::<usize>()
            + self.run.counters.sheds.iter().sum::<usize>()
    }

    /// p99 per-app slowdown vs isolated baselines; `None` until a
    /// baseline-aware driver filled the slowdowns.
    pub fn p99_slowdown(&self) -> Option<f64> {
        let xs: Vec<f64> = self.apps.iter().filter_map(|a| a.slowdown).collect();
        if xs.is_empty() { None } else { Some(stats::percentile(&xs, 99.0)) }
    }

    /// Per-class SLO attainment: the fraction of the class's admitted apps
    /// whose slowdown meets [`QosClass::slo_slowdown`], indexed by
    /// [`QosClass::index`]. `None` for a class with no slowdown-bearing
    /// apps (not offered, all shed, or no baselines attached).
    pub fn slo_attainment(&self) -> [Option<f64>; 3] {
        let mut met = [0usize; 3];
        let mut total = [0usize; 3];
        for (app, &qos) in self.apps.iter().zip(&self.app_qos) {
            let Some(sd) = app.slowdown else { continue };
            total[qos.index()] += 1;
            if sd <= qos.slo_slowdown() {
                met[qos.index()] += 1;
            }
        }
        std::array::from_fn(|i| {
            if total[i] == 0 { None } else { Some(met[i] as f64 / total[i] as f64) }
        })
    }

    /// Jain fairness at the end of the run: the feedback loop's last
    /// sample when it fired, else the total (non-panicking) index over
    /// per-app throughput. `None` when nothing was admitted — a window
    /// that shed every offer must report `n/a`, not a perfect `1.00`
    /// (`jain_fairness_index(&[]) == 1.0` is a documented total-function
    /// contract for the in-loop feedback, not a claim about empty runs).
    pub fn jain(&self) -> Option<f64> {
        if let Some(&(_, j)) = self.run.fairness.last() {
            return Some(j);
        }
        if self.apps.is_empty() {
            return None;
        }
        let xs: Vec<f64> = self
            .apps
            .iter()
            .map(|a| a.n_tasks as f64 / a.makespan().max(1e-12))
            .collect();
        Some(jain_fairness_total(&xs))
    }
}

/// Run a `(backend × scenario × policy)` triple in serving mode: one
/// bounded window of the open-loop [`ServingStream`], with backpressure on
/// during `[0, horizon)` and a clean drain after. With `with_baseline`,
/// every *admitted* app is additionally run alone (fresh policy instance,
/// fresh PTT — same protocol as [`run_stream_triple`]) so slowdown-derived
/// metrics ([`ServingReport::p99_slowdown`],
/// [`ServingReport::slo_attainment`]) are available.
///
/// `serving.drain_after` is overridden to `horizon` unless the caller set
/// a finite deadline of their own.
#[allow(clippy::too_many_arguments)]
pub fn run_serving_triple(
    backend: &str,
    scenario: &str,
    policy: &str,
    stream: &ServingStream,
    horizon: f64,
    opts: &RunOpts,
    serving: &ServingOpts,
    with_baseline: bool,
) -> Result<ServingReport, String> {
    if !(horizon > 0.0 && horizon.is_finite()) {
        return Err(format!("serving horizon must be positive and finite, got {horizon}"));
    }
    let plat = scenarios::by_name(scenario)
        .ok_or_else(|| format!("unknown platform scenario '{scenario}'"))?;
    let policy_name = policy;
    let backend_name = backend;
    let backend =
        backend_by_name(backend).ok_or_else(|| format!("unknown backend '{backend}'"))?;
    let multi = stream.window(horizon).build();
    // Plan-ahead policies plan the whole offered window up front (the
    // admission layer may still shed some of it).
    let policy = policy_for_run(policy_name, &plat, &multi.dag)
        .ok_or_else(|| format!("unknown policy '{policy_name}'"))?;
    let serving = if serving.drain_after.is_finite() {
        serving.clone()
    } else {
        ServingOpts { drain_after: horizon, ..serving.clone() }
    };
    let mut run = backend
        .run_serving(&multi, &plat, policy.as_ref(), None, opts, &serving)
        .map_err(|e| e.to_string())?;
    if !run.result.records.is_empty() {
        run.result.bound = Some(if is_sim_backend(backend_name) {
            observed_bound(&multi.dag, &run.result.records, plat.topo.n_cores())
        } else {
            observed_cp_bound(&multi.dag, &run.result.records)
        });
    }
    let shed: HashSet<usize> = run.shed_apps.iter().copied().collect();
    let admitted_index: Vec<(usize, String, f64)> = multi
        .app_index()
        .into_iter()
        .filter(|(id, _, _)| !shed.contains(id))
        .collect();
    let mut apps = per_app_metrics(&run.result, &admitted_index);
    let app_qos: Vec<QosClass> = apps.iter().map(|m| multi.apps[m.app_id].qos).collect();
    if with_baseline {
        for metrics in apps.iter_mut() {
            // Fresh policy instance per baseline: stateful policies must
            // not leak serving-run state into their isolated run.
            let (dag, _) = crate::dag_gen::generate(&multi.apps[metrics.app_id].params);
            let iso_policy =
                policy_for_run(policy_name, &plat, &dag).expect("policy resolved above");
            let iso_opts = RunOpts { trace: false, ptt_probe: None, ..opts.clone() };
            let iso = backend
                .run(&dag, &plat, iso_policy.as_ref(), None, &iso_opts)
                .map_err(|e| e.to_string())?;
            *metrics = metrics.clone().with_isolated(iso.result.makespan);
        }
    }
    if !opts.trace {
        run.result.records.clear();
    }
    Ok(ServingReport { run, apps, app_qos, horizon })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PerformanceBased;
    use crate::dag_gen::{DagParams, generate};

    #[test]
    fn backend_names_resolve_with_aliases() {
        for n in ["sim", "simulated", "virtual"] {
            assert_eq!(backend_by_name(n).unwrap().name(), "sim");
        }
        for n in ["real", "threads", "native"] {
            assert_eq!(backend_by_name(n).unwrap().name(), "real");
        }
        assert!(backend_by_name("gpu").is_none());
        for n in BACKEND_NAMES {
            assert!(backend_by_name(n).is_some());
        }
    }

    #[test]
    fn sim_backend_is_equivalent_to_direct_sim_call() {
        let (dag, _) = generate(&DagParams::mix(50, 4.0, 5));
        let plat = scenarios::by_name("tx2").unwrap();
        let via =
            SimBackend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default()).unwrap();
        let direct =
            run_dag_sim(&dag, &plat, &PerformanceBased, None, &SimOpts::default()).unwrap();
        assert_eq!(via.result.makespan.to_bits(), direct.result.makespan.to_bits());
        assert_eq!(via.result.records.len(), direct.result.records.len());
    }

    #[test]
    fn real_backend_completes_and_reports_name() {
        let (dag, _) = generate(&DagParams::mix(30, 4.0, 9));
        let plat = scenarios::by_name("hom2").unwrap();
        let backend = RealBackend;
        assert_eq!(backend.name(), "real");
        let run = backend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default()).unwrap();
        assert_eq!(run.result.n_tasks(), 30);
        assert!(run.result.makespan > 0.0);
        assert!(run.ptt_samples.is_empty());
    }

    #[test]
    fn trace_off_drops_records_but_keeps_makespan() {
        let (dag, _) = generate(&DagParams::mix(40, 4.0, 2));
        let plat = scenarios::by_name("tx2").unwrap();
        let opts = RunOpts { trace: false, ..Default::default() };
        let run = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
        assert!(run.result.records.is_empty());
        assert!(run.result.makespan > 0.0);
    }

    #[test]
    fn probe_flows_through_the_sim_backend() {
        let (dag, _) = generate(&DagParams::single(
            crate::platform::KernelClass::MatMul,
            30,
            2.0,
            3,
        ));
        let plat = scenarios::by_name("tx2").unwrap();
        let opts = RunOpts { ptt_probe: Some((0, 0, 1)), ..Default::default() };
        let run = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
        assert_eq!(run.ptt_samples.len(), 30);
    }

    #[test]
    fn single_app_stream_matches_single_dag_run_bit_for_bit() {
        // Acceptance criterion: `run_stream` with one app arriving at 0 is
        // a strict generalization of `run` — identical makespan bits and
        // identical records (modulo the new app tag) on the sim backend.
        use crate::workload::{AppSpec, WorkloadStream};
        let params = DagParams::mix(60, 4.0, 0xA11CE);
        let stream =
            WorkloadStream::fixed(vec![AppSpec::new("solo", params.clone(), 0.0)], 0);
        let plat = scenarios::by_name("tx2").unwrap();
        let opts = RunOpts { seed: 99, ..Default::default() };
        let via_stream =
            SimBackend.run_stream(&stream, &plat, &PerformanceBased, None, &opts).unwrap();
        let (dag, _) = generate(&params);
        let direct = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts).unwrap();
        assert_eq!(
            via_stream.result.makespan.to_bits(),
            direct.result.makespan.to_bits()
        );
        assert_eq!(via_stream.result.records.len(), direct.result.records.len());
        for (a, b) in via_stream.result.records.iter().zip(&direct.result.records) {
            assert_eq!(a.task, b.task);
            assert_eq!(a.partition, b.partition);
            assert_eq!(a.critical, b.critical);
            assert_eq!(a.t_start.to_bits(), b.t_start.to_bits());
            assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
            assert_eq!(a.app_id, 0);
            assert_eq!(b.app_id, 0);
        }
        // Per-app metrics collapse to the single-DAG aggregates.
        assert_eq!(via_stream.apps.len(), 1);
        assert_eq!(via_stream.apps[0].n_tasks, 60);
        assert_eq!(
            via_stream.apps[0].makespan().to_bits(),
            via_stream.apps[0].completion.to_bits()
        );
    }

    #[test]
    fn stream_run_tags_apps_and_reports_fairness() {
        use crate::workload::{AppSpec, WorkloadStream};
        let stream = WorkloadStream::fixed(
            vec![
                AppSpec::new("a", DagParams::mix(40, 4.0, 1), 0.0),
                AppSpec::new("b", DagParams::mix(40, 4.0, 2), 0.01),
            ],
            3,
        );
        let plat = scenarios::by_name("hom4").unwrap();
        let run = SimBackend
            .run_stream(&stream, &plat, &PerformanceBased, None, &RunOpts::default())
            .unwrap();
        assert_eq!(run.result.records.len(), 80);
        assert_eq!(run.result.app_ids(), vec![0, 1]);
        assert_eq!(run.apps.len(), 2);
        for app in &run.apps {
            assert_eq!(app.n_tasks, 40);
            assert!(app.makespan() > 0.0 && app.makespan().is_finite());
        }
        let j = run.jain_fairness().expect("apps ran");
        assert!(j > 0.0 && j <= 1.0, "{j}");
    }

    #[test]
    fn empty_run_reports_no_fairness() {
        // A run that admitted nothing has no fairness index — it must be
        // `None`/`n/a`, never a perfect 1.00.
        let run = StreamRun {
            result: RunResult {
                policy: "test".into(),
                platform: "test".into(),
                makespan: 0.0,
                records: Vec::new(),
                bound: None,
            },
            apps: Vec::new(),
            ptt_samples: Vec::new(),
        };
        assert_eq!(run.jain_fairness(), None);
    }

    #[test]
    fn run_stream_triple_attaches_isolated_baselines() {
        use crate::workload::scenarios::stream_by_name;
        let stream = stream_by_name("stream-pois8").unwrap().stream(5, true);
        let run = run_stream_triple(
            "sim",
            "stream-pois8",
            "performance",
            &stream,
            &RunOpts::default(),
            true,
        )
        .unwrap();
        assert_eq!(run.apps.len(), 8);
        for app in &run.apps {
            let iso = app.isolated_makespan.expect("baseline attached");
            assert!(iso > 0.0);
            let sd = app.slowdown.expect("slowdown derived");
            // Co-running can only slow an app down (up to scheduler noise).
            assert!(sd > 0.5, "{sd}");
        }
        let j = run.jain_fairness().expect("apps ran");
        assert!(j > 0.0 && j <= 1.0, "{j}");
        // Unknown names surface the offending registry.
        assert!(
            run_stream_triple("nope", "stream-pois8", "performance", &stream, &RunOpts::default(), false)
                .is_err()
        );
        assert!(
            run_stream_triple("sim", "nope", "performance", &stream, &RunOpts::default(), false)
                .is_err()
        );
        assert!(
            run_stream_triple("sim", "stream-pois8", "nope", &stream, &RunOpts::default(), false)
                .is_err()
        );
    }

    #[test]
    fn serving_triple_reports_rates_slos_and_fairness() {
        use crate::workload::{ServingStream, TenantSpec};
        let tenants = vec![
            TenantSpec::new("rt", DagParams::mix(10, 2.0, 1), QosClass::Latency),
            TenantSpec::new("bulk", DagParams::mix(20, 4.0, 2), QosClass::Batch),
            TenantSpec::new("scav", DagParams::mix(10, 2.0, 3), QosClass::BestEffort),
        ];
        let stream = ServingStream::new(tenants, 40.0, 0xCAFE);
        // Tight lanes so backpressure actually fires inside the window.
        let serving = ServingOpts { max_lane_depth: 2, delay_step: 0.005, ..Default::default() };
        let report = run_serving_triple(
            "sim",
            "hom4",
            "ptt-serving",
            &stream,
            1.0,
            &RunOpts::default(),
            &serving,
            true,
        )
        .unwrap();
        // Every admitted app has a metrics row; shed apps have none.
        let admitted: usize = report.run.counters.admitted.iter().sum();
        assert_eq!(admitted, report.apps.len());
        assert_eq!(report.apps.len() + report.run.shed_apps.len(), report.offered());
        assert!(report.admissions_per_sec() > 0.0);
        // QoS ordering invariant: the latency class is never delayed or
        // shed, and batch is never shed (only delayed).
        let c = &report.run.counters;
        assert_eq!(c.delays[QosClass::Latency.index()], 0);
        assert_eq!(c.sheds[QosClass::Latency.index()], 0);
        assert_eq!(c.sheds[QosClass::Batch.index()], 0);
        assert_eq!(c.delays[QosClass::BestEffort.index()], 0);
        // Baselines attached: slowdown-derived metrics are available.
        assert!(report.apps.iter().all(|a| a.slowdown.is_some()));
        assert!(report.p99_slowdown().unwrap() > 0.0);
        for slo in report.slo_attainment().into_iter().flatten() {
            assert!((0.0..=1.0).contains(&slo));
        }
        let j = report.jain().expect("apps admitted");
        assert!(j > 0.0 && j <= 1.0, "{j}");
        // Bit-identical on repeat: the serving sim is deterministic.
        let again = run_serving_triple(
            "sim",
            "hom4",
            "ptt-serving",
            &stream,
            1.0,
            &RunOpts::default(),
            &serving,
            false,
        )
        .unwrap();
        assert_eq!(
            again.run.result.makespan.to_bits(),
            report.run.result.makespan.to_bits()
        );
        assert_eq!(again.run.counters, report.run.counters);
        assert_eq!(again.run.shed_apps, report.run.shed_apps);
    }

    #[test]
    fn run_triple_resolves_all_registries() {
        let (dag, _) = generate(&DagParams::mix(30, 2.0, 1));
        let run = run_triple("sim", "tx2", "performance", &dag, &RunOpts::default()).unwrap();
        assert_eq!(run.result.n_tasks(), 30);
        assert!(run_triple("nope", "tx2", "performance", &dag, &RunOpts::default()).is_err());
        assert!(run_triple("sim", "nope", "performance", &dag, &RunOpts::default()).is_err());
        assert!(run_triple("sim", "tx2", "nope", &dag, &RunOpts::default()).is_err());
    }
}
