//! Unified execution backends: one seam over virtual-time simulation and
//! real-thread execution.
//!
//! The paper's core claim — PTT-guided scheduling adapts to both static
//! heterogeneity and dynamic interference — is only meaningful if the same
//! scheduling code runs identically in virtual time (`crate::sim`) and on
//! real threads (`crate::coordinator::worker`). This module is the seam
//! that enforces it: both engines are reachable through one trait,
//!
//! ```text
//! ExecutionBackend::run(dag, platform, policy, ptt, opts) -> BackendRun
//! ```
//!
//! with one [`RunOpts`] (seed, trace, PTT probe, pinning), so the CLI, the
//! figure harnesses and the conformance tests select a backend *by name*
//! instead of branching on `--real`. Combined with the platform scenario
//! registry ([`crate::platform::scenarios`]), any
//! `(backend × policy × platform)` triple is one call: [`run_triple`].
//!
//! Semantics shared by both backends:
//! - the DAG must be finalized and non-empty;
//! - a fresh PTT is created when `ptt` is `None`; passing a warm table
//!   chains runs (the VGG scalability study relies on this);
//! - the returned trace has one record per executed TAO, sorted by start
//!   time, with partitions valid on the given platform's topology.
//!
//! Differences that remain by design: the simulated backend interprets the
//! platform's performance model and episode schedule in virtual time and
//! is bit-for-bit deterministic under a fixed seed; the real backend runs
//! `topo.n_cores()` worker threads on the host in wall time, so makespans
//! are host-dependent (and `ptt_probe` sampling is sim-only).

use crate::coordinator::dag::TaoDag;
use crate::coordinator::metrics::RunResult;
use crate::coordinator::ptt::Ptt;
use crate::coordinator::scheduler::{Policy, policy_by_name};
use crate::coordinator::worker::{RealEngineOpts, run_dag_real};
use crate::platform::{Platform, scenarios};
use crate::sim::{SimOpts, run_dag_sim};

/// Options understood by every backend.
#[derive(Debug, Clone)]
pub struct RunOpts {
    /// Seed for root distribution, steal-victim selection and sim jitter.
    pub seed: u64,
    /// Keep the per-task trace in the result. Disabling it clears
    /// `RunResult::records` (makespan is still reported) — for huge DAGs
    /// where only aggregate timing matters.
    pub trace: bool,
    /// Sample the PTT entry `(type_id, core, width)` after every event —
    /// the Fig 8(a) value trace. Simulated backend only.
    pub ptt_probe: Option<(usize, usize, usize)>,
    /// Pin worker threads to host CPUs (real backend only). Currently a
    /// documented no-op: the offline build omits the libc affinity call,
    /// and this knob stays plumbed so multicore deployments can wire OS
    /// pinning back in at `coordinator::worker::pin_to_cpu`.
    pub pin_threads: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        // The seed matches the simulator's historical default so existing
        // figure outputs are unchanged by the backend refactor.
        RunOpts { seed: 0x51b, trace: true, ptt_probe: None, pin_threads: false }
    }
}

/// Result of one backend run: the engine-independent [`RunResult`] plus
/// probe samples (empty unless the sim backend ran with a probe).
#[derive(Debug, Clone)]
pub struct BackendRun {
    pub result: RunResult,
    /// `(time, PTT value)` samples if a probe was configured.
    pub ptt_samples: Vec<(f64, f64)>,
}

/// An execution substrate for TAO-DAGs under a scheduling policy.
pub trait ExecutionBackend: Send + Sync {
    /// Canonical backend name (`"sim"` / `"real"`).
    fn name(&self) -> &'static str;

    /// Execute `dag` under `policy` on `plat`, observing `opts`.
    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> BackendRun;
}

/// Discrete-event execution against the analytic platform model
/// ([`run_dag_sim`]) — deterministic, virtual time.
#[derive(Debug, Default)]
pub struct SimBackend;

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> BackendRun {
        let run = run_dag_sim(
            dag,
            plat,
            policy,
            ptt,
            &SimOpts { seed: opts.seed, ptt_probe: opts.ptt_probe },
        );
        let mut result = run.result;
        if !opts.trace {
            result.records.clear();
        }
        BackendRun { result, ptt_samples: run.ptt_samples }
    }
}

/// Real worker threads on the host ([`run_dag_real`]) — wall time. Uses
/// only the platform's topology; the performance model and episodes are
/// ignored (the host *is* the model).
#[derive(Debug, Default)]
pub struct RealBackend;

impl ExecutionBackend for RealBackend {
    fn name(&self) -> &'static str {
        "real"
    }

    fn run(
        &self,
        dag: &TaoDag,
        plat: &Platform,
        policy: &dyn Policy,
        ptt: Option<&Ptt>,
        opts: &RunOpts,
    ) -> BackendRun {
        let mut result = run_dag_real(
            dag,
            &plat.topo,
            policy,
            ptt,
            &RealEngineOpts { pin_threads: opts.pin_threads, seed: opts.seed },
        );
        if !opts.trace {
            result.records.clear();
        }
        BackendRun { result, ptt_samples: Vec::new() }
    }
}

/// Canonical backend names, in registry order.
pub const BACKEND_NAMES: [&str; 2] = ["sim", "real"];

/// Construct a backend by CLI/config name (with common aliases).
pub fn backend_by_name(name: &str) -> Option<Box<dyn ExecutionBackend>> {
    match name {
        "sim" | "simulated" | "virtual" => Some(Box::new(SimBackend)),
        "real" | "threads" | "native" => Some(Box::new(RealBackend)),
        _ => None,
    }
}

/// Run any `(backend × scenario × policy)` triple in one call.
///
/// Resolves all three registries and executes `dag`; errors name the
/// offending registry so CLI surfaces stay helpful.
pub fn run_triple(
    backend: &str,
    scenario: &str,
    policy: &str,
    dag: &TaoDag,
    opts: &RunOpts,
) -> Result<BackendRun, String> {
    let plat = scenarios::by_name(scenario)
        .ok_or_else(|| format!("unknown platform scenario '{scenario}'"))?;
    let policy = policy_by_name(policy, plat.topo.n_cores())
        .ok_or_else(|| format!("unknown policy '{policy}'"))?;
    let backend =
        backend_by_name(backend).ok_or_else(|| format!("unknown backend '{backend}'"))?;
    Ok(backend.run(dag, &plat, policy.as_ref(), None, opts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PerformanceBased;
    use crate::dag_gen::{DagParams, generate};

    #[test]
    fn backend_names_resolve_with_aliases() {
        for n in ["sim", "simulated", "virtual"] {
            assert_eq!(backend_by_name(n).unwrap().name(), "sim");
        }
        for n in ["real", "threads", "native"] {
            assert_eq!(backend_by_name(n).unwrap().name(), "real");
        }
        assert!(backend_by_name("gpu").is_none());
        for n in BACKEND_NAMES {
            assert!(backend_by_name(n).is_some());
        }
    }

    #[test]
    fn sim_backend_is_equivalent_to_direct_sim_call() {
        let (dag, _) = generate(&DagParams::mix(50, 4.0, 5));
        let plat = scenarios::by_name("tx2").unwrap();
        let via = SimBackend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default());
        let direct = run_dag_sim(&dag, &plat, &PerformanceBased, None, &SimOpts::default());
        assert_eq!(via.result.makespan.to_bits(), direct.result.makespan.to_bits());
        assert_eq!(via.result.records.len(), direct.result.records.len());
    }

    #[test]
    fn real_backend_completes_and_reports_name() {
        let (dag, _) = generate(&DagParams::mix(30, 4.0, 9));
        let plat = scenarios::by_name("hom2").unwrap();
        let backend = RealBackend;
        assert_eq!(backend.name(), "real");
        let run = backend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default());
        assert_eq!(run.result.n_tasks(), 30);
        assert!(run.result.makespan > 0.0);
        assert!(run.ptt_samples.is_empty());
    }

    #[test]
    fn trace_off_drops_records_but_keeps_makespan() {
        let (dag, _) = generate(&DagParams::mix(40, 4.0, 2));
        let plat = scenarios::by_name("tx2").unwrap();
        let opts = RunOpts { trace: false, ..Default::default() };
        let run = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts);
        assert!(run.result.records.is_empty());
        assert!(run.result.makespan > 0.0);
    }

    #[test]
    fn probe_flows_through_the_sim_backend() {
        let (dag, _) = generate(&DagParams::single(
            crate::platform::KernelClass::MatMul,
            30,
            2.0,
            3,
        ));
        let plat = scenarios::by_name("tx2").unwrap();
        let opts = RunOpts { ptt_probe: Some((0, 0, 1)), ..Default::default() };
        let run = SimBackend.run(&dag, &plat, &PerformanceBased, None, &opts);
        assert_eq!(run.ptt_samples.len(), 30);
    }

    #[test]
    fn run_triple_resolves_all_registries() {
        let (dag, _) = generate(&DagParams::mix(30, 2.0, 1));
        let run = run_triple("sim", "tx2", "performance", &dag, &RunOpts::default()).unwrap();
        assert_eq!(run.result.n_tasks(), 30);
        assert!(run_triple("nope", "tx2", "performance", &dag, &RunOpts::default()).is_err());
        assert!(run_triple("sim", "nope", "performance", &dag, &RunOpts::default()).is_err());
        assert!(run_triple("sim", "tx2", "nope", &dag, &RunOpts::default()).is_err());
    }
}
