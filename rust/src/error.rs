//! Structured scheduler errors.
//!
//! A wedged run used to abort the whole process: the simulator's deadlock
//! checks were `panic!`s, so one bad policy/scenario combination inside a
//! bench sweep or a long serving session killed everything around it.
//! [`SchedError`] turns those states into values that flow out through
//! [`crate::exec::ExecutionBackend`]; the CLI prints them and exits
//! non-zero, harnesses decide per-cell what to do.

use std::fmt;

/// A scheduling run that cannot make progress.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedError {
    /// No task is running and no arrival is pending, but the DAG is not
    /// complete: a true scheduler deadlock (lost wakeup, unreleased
    /// dependency, or work stranded on a failed core that nobody
    /// reclaimed).
    Deadlock {
        /// Tasks committed before the wedge.
        completed: usize,
        /// Tasks admitted in total.
        total: usize,
        /// Virtual time at which progress stopped.
        t: f64,
        /// Which driver detected it (`dag`, `stream`, `serving`).
        phase: &'static str,
    },
    /// Every core of the machine is fail-stopped with no recovery in
    /// sight: there is no substrate left to run on.
    AllCoresDead {
        /// Virtual time at which the last core died.
        t: f64,
    },
}

impl fmt::Display for SchedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedError::Deadlock { completed, total, t, phase } => write!(
                f,
                "scheduler deadlock ({phase}): no running tasks and no pending arrivals, \
                 but {completed} of {total} tasks complete at t={t:.6}"
            ),
            SchedError::AllCoresDead { t } => {
                write!(f, "every core is fail-stopped at t={t:.6} with no recovery scheduled")
            }
        }
    }
}

impl std::error::Error for SchedError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_wedge() {
        let e = SchedError::Deadlock { completed: 3, total: 10, t: 0.5, phase: "stream" };
        let s = e.to_string();
        assert!(s.contains("deadlock"), "{s}");
        assert!(s.contains("3 of 10"), "{s}");
        assert!(s.contains("stream"), "{s}");
        let s = SchedError::AllCoresDead { t: 1.0 }.to_string();
        assert!(s.contains("fail-stopped"), "{s}");
    }

    #[test]
    fn is_a_std_error() {
        fn takes_err(_: &dyn std::error::Error) {}
        takes_err(&SchedError::AllCoresDead { t: 0.0 });
    }
}
