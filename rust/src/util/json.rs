//! Minimal JSON parser and emitter.
//!
//! The offline crate set contains no `serde` facade, so the config system
//! (`crate::config`) and the bench harness's machine-readable output are built
//! on this self-contained implementation. It supports the full JSON grammar
//! (objects, arrays, strings with escapes incl. `\uXXXX`, numbers, booleans,
//! null) and preserves object key order for stable round-trips.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps emission deterministic (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && matches!(self.src[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.src.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.src[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in configs; map lone
                            // surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.src[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":-7,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::Num(2.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
