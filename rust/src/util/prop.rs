//! Minimal property-based testing engine.
//!
//! `proptest` is not available in the offline crate set, so this module
//! provides the subset the test suite needs: seeded case generation from
//! closures over [`Pcg32`], greedy shrinking via a [`Shrink`] trait, and a
//! failure report that includes the reproducing seed.
//!
//! Usage:
//! ```text
//! use xitao::util::prop::{check, Config};
//! check(Config::default(), "addition commutes",
//!     |rng| (rng.gen_range(1000), rng.gen_range(1000)),
//!     |&(a, b)| {
//!         if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//!     });
//! ```

use super::rng::Pcg32;
use std::fmt::Debug;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses stream `i` of this seed.
    pub seed: u64,
    /// Cap on shrinking steps (guards against pathological shrink graphs).
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x5eed_cafe, max_shrink_steps: 2000 }
    }
}

impl Config {
    pub fn cases(n: usize) -> Config {
        Config { cases: n, ..Default::default() }
    }
}

/// Types that can propose strictly "smaller" candidate values.
pub trait Shrink: Sized {
    /// Candidate simpler values; must not include `self` (or shrinking loops).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        shrink_int(*self as u64).into_iter().map(|v| v as u32).collect()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        shrink_int(*self)
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        shrink_int(*self as u64).into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

fn shrink_int(v: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if v == 0 {
        return out;
    }
    out.push(0);
    out.push(v / 2);
    if v > 1 {
        out.push(v - 1);
    }
    out.dedup();
    out.retain(|&c| c != v);
    out
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for cand in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

/// Run `prop` against `cases` random values from `gen`.
///
/// On failure, greedily shrinks the counterexample and panics with the
/// minimal case, the original case, the failure message and the seed.
pub fn check<T, G, P>(cfg: Config, name: &str, gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: Fn(&mut Pcg32) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case_idx in 0..cfg.cases {
        let mut rng = Pcg32::new(cfg.seed, case_idx as u64);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = first_msg.clone();
            let mut steps = 0;
            'outer: loop {
                for cand in best.shrink() {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {:#x})\n  \
                 original: {input:?}\n  original error: {first_msg}\n  \
                 shrunk:   {best:?}\n  shrunk error:   {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Boolean-property convenience wrapper.
pub fn check_bool<T, G, P>(cfg: Config, name: &str, gen: G, prop: P)
where
    T: Shrink + Clone + Debug,
    G: Fn(&mut Pcg32) -> T,
    P: Fn(&T) -> bool,
{
    check(cfg, name, gen, |t| if prop(t) { Ok(()) } else { Err("returned false".into()) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_clean() {
        check(Config::cases(64), "reverse twice is identity",
            |rng| (0..rng.gen_usize(0, 20)).map(|_| rng.gen_range(100)).collect::<Vec<u32>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v { Ok(()) } else { Err("mismatch".into()) }
            });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check(Config::cases(256), "all values below 10",
                |rng| rng.gen_range(1000),
                |&v| if v < 10 { Ok(()) } else { Err(format!("{v}")) });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy integer shrinking should land on exactly 10.
        assert!(msg.contains("shrunk:   10"), "got: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v: Vec<u32> = vec![5, 6, 7, 8];
        assert!(v.shrink().iter().any(|c| c.len() < 4));
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4u32, 6u32);
        let cands = t.shrink();
        assert!(cands.iter().any(|&(a, _)| a < 4));
        assert!(cands.iter().any(|&(_, b)| b < 6));
    }

    #[test]
    fn shrink_terminates_on_zero() {
        assert!(0u64.shrink().is_empty());
        assert!(!5u64.shrink().contains(&5));
    }
}
