//! Foundation utilities: deterministic RNG, statistics, JSON, table/CSV
//! output, and a minimal property-testing engine (offline stand-in for
//! `proptest`).

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Pcg32;
pub use table::Table;
