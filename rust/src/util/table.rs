//! Text-table and CSV emission for the figure/bench harnesses.
//!
//! Every paper figure regenerator prints an aligned text table (the rows the
//! paper reports) and writes the same data as CSV under `bench_out/` so plots
//! can be recreated externally.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV form under `bench_out/<name>.csv` (dir created on demand)
    /// and return the path written.
    pub fn write_csv(&self, name: &str) -> std::io::Result<String> {
        let dir = Path::new("bench_out");
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut f = fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path.display().to_string())
    }
}

/// Format a float with 3 significant decimals (bench rows).
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a float with 2 decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "20000".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long_header"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["a,b".into(), "q\"uote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"uote\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["only-one".into()]);
    }
}
