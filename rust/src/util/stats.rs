//! Small statistics helpers used by the metrics layer and bench harnesses.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; 0.0 for an empty slice. Panics on non-positive inputs.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator); 0.0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on the sorted sample.
/// `p` in `[0, 100]`; 0.0 for an empty slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    // NaN-last total order (same convention as `jain_fairness_total`): a
    // single NaN slowdown must not abort an entire bench/serving report.
    sorted.sort_by(|a, b| match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => a.partial_cmp(b).unwrap(),
    });
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a sample, used when printing bench rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: if xs.is_empty() { 0.0 } else { min(xs) },
            median: median(xs),
            p95: percentile(xs, 95.0),
            max: if xs.is_empty() { 0.0 } else { max(xs) },
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} sd={:.4} min={:.4} p50={:.4} p95={:.4} max={:.4}",
            self.n, self.mean, self.stddev, self.min, self.median, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_known_value() {
        // Sample sd of [2,4,4,4,5,5,7,9] with n-1 denominator.
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn summary_consistent() {
        let xs = [5.0, 1.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // NaN sorts last: low/mid percentiles are taken over the finite
        // values, and only the extreme tail ever sees the NaN.
        let xs = [10.0, f64::NAN, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 30.0);
        assert!(percentile(&xs, 100.0).is_nan());
        let all_nan = [f64::NAN, f64::NAN];
        assert!(percentile(&all_nan, 50.0).is_nan());
    }
}
