//! Deterministic pseudo-random number generation.
//!
//! The paper's random-DAG generator is seeded so a DAG can be "recreated ...
//! several times for comparison" (§4.2.2); every stochastic component in this
//! repository (DAG shapes, work-stealing victim selection, simulator jitter)
//! therefore draws from an explicit, splittable PCG-XSH-RR instance instead
//! of a global RNG. No external `rand` crate is available offline, so this is
//! a self-contained implementation of the PCG32 reference generator
//! (O'Neill 2014) plus the convenience samplers the rest of the crate needs.

/// A PCG-XSH-RR 64/32 generator: 64-bit state, 32-bit output.
///
/// Small (16 bytes), fast (one multiply per draw) and statistically solid for
/// simulation purposes. Each stream (`inc`) is an independent sequence, which
/// gives us cheap per-worker generators that never contend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    ///
    /// Different `stream` values yield statistically independent sequences
    /// even under the same seed, which is how per-core RNGs are derived.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor using the reference stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator; used to hand each simulated
    /// core / worker thread its own stream deterministically.
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        let seed = self.next_u64();
        Pcg32::new(seed, stream.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1))
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit value (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` via Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "gen_range bound must be positive");
        // Rejection sampling threshold for exact uniformity.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    #[inline]
    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        if span <= u32::MAX as u64 {
            lo + self.gen_range(span as u32) as usize
        } else {
            lo + (self.next_u64() % span) as usize
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "too many collisions: {same}");
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(4);
        for _ in 0..1000 {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_f64_mean_is_centered() {
        let mut rng = Pcg32::seeded(5);
        let n = 20_000;
        let mean = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg32::seeded(9);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_usize_full_range() {
        let mut rng = Pcg32::seeded(10);
        for _ in 0..100 {
            let v = rng.gen_usize(10, 20);
            assert!((10..20).contains(&v));
        }
    }
}
