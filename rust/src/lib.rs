//! # xitao — PTT-based adaptive performance-oriented scheduling
//!
//! Reproduction of *"An Adaptive Performance-oriented Scheduler for Static
//! and Dynamic Heterogeneity"* (Chen, Abduljabbar, Soomro, Pericàs, 2019):
//! a XiTAO-style runtime for mixed-mode parallelism extended with a
//! **Performance Trace Table (PTT)** — a lightweight online model of
//! per-(core, resource-width) task latency that drives criticality-aware,
//! interference-free scheduling with no static platform knowledge.
//!
//! ## Layout
//! - [`platform`] — topology, heterogeneity + contention model, episodes,
//!   and the named scenario registry (`platform::scenarios`).
//! - [`coordinator`] — the paper's contribution: TAOs, TAO-DAGs,
//!   criticality, the PTT, scheduling policies, and the real-thread runtime.
//! - [`sim`] — discrete-event execution of the same coordinator logic on
//!   modelled platforms (TX2, Haswell) in virtual time.
//! - [`exec`] — the `ExecutionBackend` seam unifying [`sim`] and the
//!   real-thread engine behind one `run(dag, platform, policy, ptt, opts)`
//!   call; backends are selected by name.
//! - [`kernels`] — the paper's three benchmark kernels (matmul/sort/copy).
//! - [`dag_gen`] — seeded random TAO-DAG generator (§4.2.2).
//! - [`workload`] — multi-application workload streams: arrival processes,
//!   concurrent DAG admission, per-app accounting (`workload::scenarios`).
//! - [`vgg`] — VGG-16 as a TAO-DAG of GEMM blocks (§4.3).
//! - [`runtime`] — PJRT engine loading the JAX/Pallas AOT artifacts.
//! - [`bench`] — regenerators for every figure in the paper's evaluation.
//! - [`cli`] / [`config`] — argument parsing and JSON run configs.
//! - [`util`] — RNG, stats, JSON, tables, property-testing.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dag_gen;
pub mod error;
pub mod exec;
pub mod kernels;
pub mod platform;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod vgg;
pub mod workload;

pub use error::SchedError;
