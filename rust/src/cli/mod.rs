//! Minimal command-line parsing (no external crates offline).
//!
//! Grammar: `repro <command> [positional...] [--flag value | --switch]`.
//! Flags may also be written `--flag=value`.

use std::collections::{HashMap, HashSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

impl Args {
    /// Parse from raw argv (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(flag.to_string(), iter.next().unwrap());
                } else {
                    out.switches.insert(flag.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    /// Typed flag with default; exits with a message on parse failure.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.flag(name) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("error: invalid value '{s}' for --{name}");
                std::process::exit(2);
            }),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("fig5 one two");
        assert_eq!(a.command.as_deref(), Some("fig5"));
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn flags_both_styles() {
        let a = parse("run --tasks 500 --policy=cats --quick");
        assert_eq!(a.get::<usize>("tasks", 0), 500);
        assert_eq!(a.get_str("policy", ""), "cats");
        assert!(a.switch("quick"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn switch_before_flag_value_ambiguity() {
        // `--quick` followed by another flag stays a switch.
        let a = parse("x --quick --tasks 9");
        assert!(a.switch("quick"));
        assert_eq!(a.get::<usize>("tasks", 0), 9);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get::<u64>("seed", 7), 7);
        assert_eq!(a.get_str("platform", "tx2"), "tx2");
    }
}
