//! The streaming kernel (§4.2.1): a large memory copy. "Each core copies a
//! subset of the data" — rank-sliced, continuously touching main memory
//! (the default 16.8 MB source plus destination = 33.6 MB footprint, far
//! beyond any L2).

use super::shared_buf::SharedBuf;
use crate::coordinator::tao::TaoPayload;
use crate::platform::KernelClass;
use std::sync::Arc;

/// Default byte count from the paper: 16.8 MB.
pub const DEFAULT_BYTES: usize = 16_800_000;

pub struct CopyTao {
    src: Arc<Vec<u8>>,
    dst: SharedBuf<u8>,
}

impl CopyTao {
    pub fn new(bytes: usize, seed: u64) -> CopyTao {
        let mut rng = crate::util::Pcg32::seeded(seed);
        let src: Vec<u8> = (0..bytes).map(|_| rng.next_u32() as u8).collect();
        CopyTao { src: Arc::new(src), dst: SharedBuf::zeroed(bytes) }
    }

    /// Reuse a source buffer allocated by the DAG generator.
    pub fn with_source(src: Arc<Vec<u8>>) -> CopyTao {
        let n = src.len();
        CopyTao { src, dst: SharedBuf::zeroed(n) }
    }

    pub fn source(&self) -> &Arc<Vec<u8>> {
        &self.src
    }

    pub fn output(&self) -> Vec<u8> {
        self.dst.snapshot()
    }
}

impl TaoPayload for CopyTao {
    fn class(&self) -> KernelClass {
        KernelClass::Copy
    }

    fn execute(&self, rank: usize, width: usize) {
        let n = self.src.len();
        let lo = rank * n / width;
        let hi = (rank + 1) * n / width;
        // SAFETY: rank slices are disjoint.
        let dst = unsafe { self.dst.slice_mut(lo, hi) };
        dst.copy_from_slice(&self.src[lo..hi]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copies_width_1() {
        let t = CopyTao::new(10_000, 7);
        t.execute(0, 1);
        assert_eq!(t.output(), **t.source());
    }

    #[test]
    fn copies_width_4_threads() {
        let t = Arc::new(CopyTao::new(100_003, 8)); // odd size
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let t = t.clone();
                std::thread::spawn(move || t.execute(r, 4))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.output(), **t.source());
    }

    #[test]
    fn shared_source_not_cloned() {
        let src = Arc::new(vec![1u8; 64]);
        let t1 = CopyTao::with_source(src.clone());
        let t2 = CopyTao::with_source(src.clone());
        t1.execute(0, 1);
        t2.execute(0, 1);
        assert_eq!(Arc::strong_count(&src), 3);
    }
}
