//! The paper's three benchmark kernels (§4.2.1), implemented as real,
//! width-elastic [`TaoPayload`]s:
//!
//! | kernel   | character          | default working set |
//! |----------|--------------------|---------------------|
//! | [`matmul`] | compute-intensive  | 64×64 f32 (48 KB)  |
//! | [`sort`]   | cache-intensive    | 262 KB (+262 KB scratch) |
//! | [`copy`]   | memory streaming   | 16.8 MB (+16.8 MB dst)   |
//!
//! All three accept any width the scheduler chooses and decompose
//! internally by rank. [`shared_buf`] provides the disjoint-write output
//! abstraction; [`barrier`] the TAO-internal phase barrier used by sort.
//!
//! [`TaoPayload`]: crate::coordinator::tao::TaoPayload

pub mod barrier;
pub mod copy;
pub mod matmul;
pub mod shared_buf;
pub mod sort;

pub use copy::CopyTao;
pub use matmul::MatMulTao;
pub use sort::SortTao;

use crate::coordinator::tao::TaoPayload;
use crate::platform::KernelClass;
use std::sync::Arc;

/// Scaled-down kernel sizes for fast functional tests/examples on the
/// single-core build host (full paper sizes remain available through the
/// type constructors).
#[derive(Debug, Clone, Copy)]
pub struct KernelSizes {
    pub matmul_n: usize,
    pub sort_len: usize,
    pub copy_bytes: usize,
}

impl KernelSizes {
    /// The paper's sizes (§4.2.1).
    pub fn paper() -> KernelSizes {
        KernelSizes {
            matmul_n: matmul::DEFAULT_N,
            sort_len: sort::DEFAULT_LEN,
            copy_bytes: copy::DEFAULT_BYTES,
        }
    }

    /// Small sizes for CI-speed runs.
    pub fn small() -> KernelSizes {
        KernelSizes { matmul_n: 32, sort_len: 4096, copy_bytes: 1 << 16 }
    }

    /// Instantiate a payload of `class` with these sizes.
    pub fn instantiate(&self, class: KernelClass, seed: u64) -> Arc<dyn TaoPayload> {
        match class {
            KernelClass::MatMul => Arc::new(MatMulTao::new(self.matmul_n, seed)),
            KernelClass::Sort => Arc::new(SortTao::new(self.sort_len, seed)),
            KernelClass::Copy => Arc::new(CopyTao::new(self.copy_bytes, seed)),
            KernelClass::Gemm => Arc::new(MatMulTao::new(self.matmul_n * 2, seed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instantiate_all_classes() {
        let sizes = KernelSizes::small();
        for class in KernelClass::ALL {
            let p = sizes.instantiate(class, 1);
            p.execute(0, 1);
        }
    }

    #[test]
    fn paper_sizes_match_section_421() {
        let s = KernelSizes::paper();
        assert_eq!(s.matmul_n, 64);
        assert_eq!(s.sort_len * 4, 262144);
        assert_eq!(s.copy_bytes, 16_800_000);
    }
}
