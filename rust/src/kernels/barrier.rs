//! A width-agnostic spin barrier for TAO-internal phase synchronisation.
//!
//! TAO payloads learn their width only at execution time (the scheduler
//! picks it), so `std::sync::Barrier` — whose count is fixed at
//! construction — does not fit. This barrier is armed by the first arriver
//! of each TAO execution and supports multiple phases (generations).

use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Debug, Default)]
pub struct SpinBarrier {
    /// Arrivals in the current generation.
    arrived: AtomicUsize,
    /// Generation counter; bumping it releases the waiters.
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new() -> SpinBarrier {
        SpinBarrier::default()
    }

    /// Wait until `width` participants have called `wait(width)` for the
    /// current generation. The last arriver resets the count and advances
    /// the generation. Spin-waits with `yield_now` (phases are short and
    /// the host may have fewer cores than workers).
    pub fn wait(&self, width: usize) {
        debug_assert!(width >= 1);
        if width == 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        let n = self.arrived.fetch_add(1, Ordering::AcqRel) + 1;
        if n == width {
            self.arrived.store(0, Ordering::Relaxed);
            self.generation.store(gen + 1, Ordering::Release);
        } else {
            while self.generation.load(Ordering::Acquire) == gen {
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn width_one_is_noop() {
        let b = SpinBarrier::new();
        b.wait(1);
        b.wait(1);
    }

    #[test]
    fn synchronises_phases() {
        let b = Arc::new(SpinBarrier::new());
        let phase_marks = Arc::new(std::sync::Mutex::new(Vec::new()));
        let width = 4;
        let handles: Vec<_> = (0..width)
            .map(|r| {
                let b = b.clone();
                let m = phase_marks.clone();
                std::thread::spawn(move || {
                    m.lock().unwrap().push((0, r));
                    b.wait(width);
                    m.lock().unwrap().push((1, r));
                    b.wait(width);
                    m.lock().unwrap().push((2, r));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let marks = phase_marks.lock().unwrap();
        // Every phase-0 mark precedes every phase-1 mark, etc.
        let pos = |phase: usize| -> Vec<usize> {
            marks
                .iter()
                .enumerate()
                .filter(|(_, &(p, _))| p == phase)
                .map(|(i, _)| i)
                .collect()
        };
        assert!(pos(0).iter().max() < pos(1).iter().min());
        assert!(pos(1).iter().max() < pos(2).iter().min());
    }
}
