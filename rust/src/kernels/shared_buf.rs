//! A shared output buffer written by cooperating TAO ranks.
//!
//! TAO payloads receive `&self` from every participating worker thread but
//! must write disjoint regions of a common output. `SharedBuf` provides
//! exactly that: interior-mutable storage whose safety contract is
//! *disjointness of the requested ranges across concurrent callers* —
//! upheld by the kernels' rank-block decompositions and exercised under
//! threads in the kernel tests.

use std::cell::UnsafeCell;

pub struct SharedBuf<T> {
    data: UnsafeCell<Vec<T>>,
}

// SAFETY: concurrent access is restricted to disjoint ranges by callers of
// `slice_mut` (see module docs); reads happen only after all writers joined.
unsafe impl<T: Send> Sync for SharedBuf<T> {}
unsafe impl<T: Send> Send for SharedBuf<T> {}

impl<T: Copy + Default> SharedBuf<T> {
    pub fn zeroed(len: usize) -> SharedBuf<T> {
        SharedBuf { data: UnsafeCell::new(vec![T::default(); len]) }
    }

    pub fn from_vec(v: Vec<T>) -> SharedBuf<T> {
        SharedBuf { data: UnsafeCell::new(v) }
    }

    pub fn len(&self) -> usize {
        unsafe { (*self.data.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mutable view of `[lo, hi)`.
    ///
    /// # Safety
    /// Concurrent callers must request pairwise-disjoint ranges, and no
    /// reader may overlap an active writer's range.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len());
        let base = (*self.data.get()).as_mut_ptr();
        std::slice::from_raw_parts_mut(base.add(lo), hi - lo)
    }

    /// Snapshot the whole buffer (call after writers joined).
    pub fn snapshot(&self) -> Vec<T> {
        unsafe { (*self.data.get()).clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn disjoint_parallel_writes() {
        let buf: Arc<SharedBuf<u32>> = Arc::new(SharedBuf::zeroed(400));
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let b = buf.clone();
                std::thread::spawn(move || {
                    let s = unsafe { b.slice_mut(r * 100, (r + 1) * 100) };
                    for (i, v) in s.iter_mut().enumerate() {
                        *v = (r * 100 + i) as u32;
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let out = buf.snapshot();
        assert_eq!(out, (0..400).collect::<Vec<u32>>());
    }

    #[test]
    fn from_vec_roundtrip() {
        let b = SharedBuf::from_vec(vec![7u8; 3]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.snapshot(), vec![7, 7, 7]);
    }
}
