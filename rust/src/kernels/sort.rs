//! The cache-intensive kernel (§4.2.1): quick sort of chunks followed by
//! two levels of merge sort. "This kernel has a maximum parallelism of
//! four": the input splits into 4 chunks, each quick-sorted in place, then
//! pairs merge (level 1), then the halves merge (level 2). Width 1, 2 and
//! 4 map ranks onto chunks; the double buffer gives the paper's 2× memory
//! footprint (524 KB total for a 262 KB input).

use super::barrier::SpinBarrier;
use super::shared_buf::SharedBuf;
use crate::coordinator::tao::TaoPayload;
use crate::platform::KernelClass;

/// Default element count ≈ 262 KB of u32 (the paper's input size).
pub const DEFAULT_LEN: usize = 65536;

pub struct SortTao {
    len: usize,
    /// Primary buffer (input, then per-chunk sorted, then final output).
    data: SharedBuf<u32>,
    /// Merge scratch (the "double buffering" of §4.2.1).
    scratch: SharedBuf<u32>,
    barrier: SpinBarrier,
}

impl SortTao {
    pub fn new(len: usize, seed: u64) -> SortTao {
        assert!(len >= 4, "need at least one element per chunk");
        let mut rng = crate::util::Pcg32::seeded(seed);
        let data: Vec<u32> = (0..len).map(|_| rng.next_u32()).collect();
        SortTao {
            len,
            data: SharedBuf::from_vec(data),
            scratch: SharedBuf::zeroed(len),
            barrier: SpinBarrier::new(),
        }
    }

    pub fn from_vec(v: Vec<u32>) -> SortTao {
        assert!(v.len() >= 4);
        let len = v.len();
        SortTao {
            len,
            scratch: SharedBuf::zeroed(len),
            data: SharedBuf::from_vec(v),
            barrier: SpinBarrier::new(),
        }
    }

    pub fn output(&self) -> Vec<u32> {
        self.data.snapshot()
    }

    /// Chunk boundaries: 4 equal-ish chunks.
    fn chunk(&self, i: usize) -> (usize, usize) {
        (i * self.len / 4, (i + 1) * self.len / 4)
    }

    /// Chunks owned by `rank` at `width` (width ∈ {1,2,4} ⇒ 4/width chunks,
    /// other widths degrade gracefully to the owner pattern of width 1/2).
    fn chunks_of(&self, rank: usize, width: usize) -> std::ops::Range<usize> {
        let per = (4 / width.min(4)).max(1);
        let lo = rank * per;
        (lo.min(4))..((lo + per).min(4))
    }

    fn merge_into(dst: &mut [u32], a: &[u32], b: &[u32]) {
        let (mut i, mut j) = (0, 0);
        for slot in dst.iter_mut() {
            if i < a.len() && (j >= b.len() || a[i] <= b[j]) {
                *slot = a[i];
                i += 1;
            } else {
                *slot = b[j];
                j += 1;
            }
        }
    }
}

impl TaoPayload for SortTao {
    fn class(&self) -> KernelClass {
        KernelClass::Sort
    }

    fn execute(&self, rank: usize, width: usize) {
        let width = width.min(4);
        // Phase 1: quick-sort owned chunks in place (pattern-defeating
        // introsort via the stdlib — same spirit, robust pivots).
        for ci in self.chunks_of(rank, width) {
            let (lo, hi) = self.chunk(ci);
            let s = unsafe { self.data.slice_mut(lo, hi) };
            s.sort_unstable();
        }
        self.barrier.wait(width);
        // Phase 2 (merge level 1): chunks (0,1) → scratch lower half by the
        // owner of chunk 0; chunks (2,3) → scratch upper half by the owner
        // of chunk 2.
        let half = self.len / 2;
        let is_lower_merger = rank == 0;
        let is_upper_merger = match width {
            1 => rank == 0,
            2 => rank == 1,
            _ => rank == 2,
        };
        if is_lower_merger {
            let (a0, a1) = (self.chunk(0), self.chunk(1));
            let dst = unsafe { self.scratch.slice_mut(0, a1.1) };
            let a = unsafe { self.data.slice_mut(a0.0, a0.1) };
            let b = unsafe { self.data.slice_mut(a1.0, a1.1) };
            Self::merge_into(dst, a, b);
        }
        if is_upper_merger {
            let (a2, a3) = (self.chunk(2), self.chunk(3));
            let dst = unsafe { self.scratch.slice_mut(half, self.len) };
            let a = unsafe { self.data.slice_mut(a2.0, a2.1) };
            let b = unsafe { self.data.slice_mut(a3.0, a3.1) };
            Self::merge_into(dst, a, b);
        }
        self.barrier.wait(width);
        // Phase 3 (merge level 2): rank 0 merges the halves back into data.
        if rank == 0 {
            let dst = unsafe { self.data.slice_mut(0, self.len) };
            let a = unsafe { self.scratch.slice_mut(0, half) };
            let b = unsafe { self.scratch.slice_mut(half, self.len) };
            Self::merge_into(dst, a, b);
        }
        self.barrier.wait(width);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn is_sorted(v: &[u32]) -> bool {
        v.windows(2).all(|w| w[0] <= w[1])
    }

    fn run_width(len: usize, width: usize) {
        let t = Arc::new(SortTao::new(len, 42));
        let mut input = t.output();
        if width == 1 {
            t.execute(0, 1);
        } else {
            let handles: Vec<_> = (0..width)
                .map(|r| {
                    let t = t.clone();
                    std::thread::spawn(move || t.execute(r, width))
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
        let out = t.output();
        assert!(is_sorted(&out), "width {width} output not sorted");
        input.sort_unstable();
        assert_eq!(out, input, "width {width} must be a permutation sort");
    }

    #[test]
    fn sorts_width_1() {
        run_width(1000, 1);
    }

    #[test]
    fn sorts_width_2() {
        run_width(1000, 2);
    }

    #[test]
    fn sorts_width_4() {
        run_width(1000, 4);
    }

    #[test]
    fn width_above_max_clamps() {
        // Width 8 behaves as 4 for the 4 extra ranks? No — widths come from
        // the topology, clamp means ranks ≥ 4 own no chunks but still hit
        // the barriers... we clamp width to 4 inside execute, so only call
        // with width ≤ 4 ranks. Here: verify the clamp path via width=3 is
        // NOT used by schedulers (widths are divisors), but degrade test:
        run_width(1003, 4);
    }

    #[test]
    fn odd_length_sorted() {
        run_width(997, 2);
    }

    #[test]
    fn default_size_matches_paper() {
        // 65536 × 4 B = 262 KB input; with scratch = 524 KB footprint.
        assert_eq!(DEFAULT_LEN * 4, 262144);
    }
}
