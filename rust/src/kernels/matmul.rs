//! The compute-intensive kernel (§4.2.1): a 64×64 single-precision matrix
//! multiplication. Parallelised exactly as the paper describes — each
//! participating core writes a disjoint block of output rows ("writing of
//! output data is done to separate cache lines for each thread while still
//! sharing the input data").

use super::shared_buf::SharedBuf;
use crate::coordinator::tao::TaoPayload;
use crate::platform::KernelClass;
use std::sync::Arc;

/// Default matrix dimension from the paper.
pub const DEFAULT_N: usize = 64;

pub struct MatMulTao {
    n: usize,
    a: Arc<Vec<f32>>,
    b: Arc<Vec<f32>>,
    c: SharedBuf<f32>,
}

impl MatMulTao {
    /// Create with deterministic pseudo-random inputs derived from `seed`.
    pub fn new(n: usize, seed: u64) -> MatMulTao {
        let mut rng = crate::util::Pcg32::seeded(seed);
        let a: Vec<f32> = (0..n * n).map(|_| rng.gen_f64() as f32).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.gen_f64() as f32).collect();
        MatMulTao { n, a: Arc::new(a), b: Arc::new(b), c: SharedBuf::zeroed(n * n) }
    }

    /// Shared-input constructor (the random-DAG generator reuses input
    /// buffers across tasks to model data reuse, §4.2.2).
    pub fn with_inputs(n: usize, a: Arc<Vec<f32>>, b: Arc<Vec<f32>>) -> MatMulTao {
        assert_eq!(a.len(), n * n);
        assert_eq!(b.len(), n * n);
        MatMulTao { n, a, b, c: SharedBuf::zeroed(n * n) }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Copy of the output (test oracle access).
    pub fn output(&self) -> Vec<f32> {
        self.c.snapshot()
    }

    /// Reference result computed serially (oracle).
    pub fn reference(&self) -> Vec<f32> {
        let n = self.n;
        let mut c = vec![0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                let aik = self.a[i * n + k];
                for j in 0..n {
                    c[i * n + j] += aik * self.b[k * n + j];
                }
            }
        }
        c
    }
}

impl TaoPayload for MatMulTao {
    fn class(&self) -> KernelClass {
        KernelClass::MatMul
    }

    fn execute(&self, rank: usize, width: usize) {
        let n = self.n;
        // Row-block decomposition: rank r owns rows [r·n/w, (r+1)·n/w).
        let lo = rank * n / width;
        let hi = (rank + 1) * n / width;
        // SAFETY: row blocks are disjoint across ranks.
        let c = unsafe { self.c.slice_mut(lo * n, hi * n) };
        for i in lo..hi {
            let crow = &mut c[(i - lo) * n..(i - lo + 1) * n];
            crow.fill(0.0);
            for k in 0..n {
                let aik = self.a[i * n + k];
                let brow = &self.b[k * n..(k + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn width1_matches_reference() {
        let t = MatMulTao::new(16, 1);
        t.execute(0, 1);
        assert_close(&t.output(), &t.reference());
    }

    #[test]
    fn width4_matches_reference() {
        let t = MatMulTao::new(DEFAULT_N, 2);
        for r in 0..4 {
            t.execute(r, 4);
        }
        assert_close(&t.output(), &t.reference());
    }

    #[test]
    fn uneven_width_covers_all_rows() {
        // 16 rows across width 3: blocks 0..5, 5..10, 10..16.
        let t = MatMulTao::new(16, 3);
        for r in 0..3 {
            t.execute(r, 3);
        }
        assert_close(&t.output(), &t.reference());
    }

    #[test]
    fn concurrent_ranks_are_race_free() {
        let t = Arc::new(MatMulTao::new(DEFAULT_N, 4));
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let t = t.clone();
                std::thread::spawn(move || t.execute(r, 4))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_close(&t.output(), &t.reference());
    }

    #[test]
    fn shared_inputs_reused() {
        let a = Arc::new(vec![1f32; 8 * 8]);
        let b = Arc::new(vec![2f32; 8 * 8]);
        let t = MatMulTao::with_inputs(8, a.clone(), b);
        t.execute(0, 1);
        // Every entry is sum of 8 × (1×2) = 16.
        assert!(t.output().iter().all(|&v| (v - 16.0).abs() < 1e-5));
        assert_eq!(Arc::strong_count(&a), 2);
    }
}
