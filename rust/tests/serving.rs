//! Serving-mode integration: the never-draining scheduler under an
//! open-loop multi-tenant arrival stream, on both execution backends.
//!
//! What a *serving* scheduler must get right (and what a batch-mode test
//! never exercises): admission under backpressure, the QoS shed/delay
//! ladder, a clean quiesce once the window closes, exactly-once execution
//! of every admitted task, and bounded queues/memory while the work keeps
//! coming. Asserted shapes only — never wall-clock values.

use std::collections::HashSet;
use std::time::Instant;
use xitao::coordinator::{QosClass, ServingOpts};
use xitao::dag_gen::DagParams;
use xitao::exec::{RunOpts, run_serving_triple};
use xitao::workload::{ServingStream, TenantSpec};

/// One tenant per QoS class, so every rung of the ladder sees arrivals.
fn three_class_tenants(n_tasks: usize, seed: u64) -> Vec<TenantSpec> {
    QosClass::ALL
        .iter()
        .enumerate()
        .map(|(i, &qos)| {
            TenantSpec::new(
                format!("{}-tenant", qos.name()),
                DagParams::mix(n_tasks, 2.0, seed ^ (i as u64 + 1)),
                qos,
            )
        })
        .collect()
}

#[test]
fn real_backend_soak_quiesces_with_exactly_once_execution_and_bounded_queues() {
    // A bounded wall-clock serving window on the real engine: Poisson
    // arrivals at 60 apps/s for 0.4 wall seconds, payload-free DAGs (the
    // soak measures the scheduler, not the kernels). The run must drain
    // cleanly after the horizon instead of hanging on the open-loop
    // source — the bug class this mode exists to catch.
    let stream = ServingStream::new(three_class_tenants(10, 0xBEEF), 60.0, 0xBEEF);
    let serving = ServingOpts::default();
    let wall = Instant::now();
    let report = run_serving_triple(
        "real",
        "hom2",
        "ptt-serving",
        &stream,
        0.4,
        &RunOpts::default(),
        &serving,
        false,
    )
    .expect("serving window runs");
    // Clean quiesce: the driver returned (run_serving_real asserts the
    // engine reported done) and within a sane multiple of the window.
    assert!(wall.elapsed().as_secs_f64() < 30.0, "soak failed to quiesce promptly");
    assert!(report.run.result.makespan > 0.0);

    // Exactly-once: every admitted app's every task has exactly one trace
    // record — nothing lost at admission, nothing double-executed by the
    // steal path, nothing left queued at quiesce.
    let expected: usize = report.apps.iter().map(|a| a.n_tasks).sum();
    assert!(expected > 0, "soak admitted nothing");
    assert_eq!(report.run.result.records.len(), expected);
    let distinct: HashSet<usize> = report.run.result.records.iter().map(|r| r.task).collect();
    assert_eq!(distinct.len(), expected, "a task ran twice");

    // Bookkeeping closes: offered = admitted + shed, and the admitted
    // counter matches the metrics rows.
    let admitted: usize = report.run.counters.admitted.iter().sum();
    assert_eq!(admitted, report.apps.len());
    assert_eq!(report.offered(), admitted + report.run.counters.sheds.iter().sum::<usize>());

    // Bounded queues at this light load: the admission inboxes never grow
    // past the backpressure bound (payload-free tasks drain far faster
    // than 60 apps/s arrive), and the WSQ retired-buffer list stays at
    // the growth-chain bound (≈ log2 of the peak queue depth) instead of
    // accumulating for the lifetime of the serving loop.
    assert!(
        report.run.lane_high_water <= serving.max_lane_depth,
        "inbox high water {} exceeded the lane bound {}",
        report.run.lane_high_water,
        serving.max_lane_depth
    );
    assert!(
        report.run.wsq_retired <= 16,
        "retired WSQ buffers not reclaimed: {}",
        report.run.wsq_retired
    );
}

#[test]
fn backpressure_sheds_and_delays_lower_qos_first() {
    // Overload the sim backend on purpose: 2 lanes, lane bound 1, and
    // 300 offered apps/s of 12-task DAGs — far beyond what the platform
    // drains. The QoS ladder must hold: latency apps are never shed or
    // delayed, batch apps are delayed but never shed, and only besteffort
    // apps are shed. Virtual time keeps this deterministic and fast.
    let stream = ServingStream::new(three_class_tenants(12, 0xFEED), 300.0, 0xFEED);
    let serving = ServingOpts { max_lane_depth: 1, delay_step: 0.004, ..Default::default() };
    let report = run_serving_triple(
        "sim",
        "hom2",
        "ptt-serving",
        &stream,
        0.25,
        &RunOpts { trace: false, ..Default::default() },
        &serving,
        false,
    )
    .expect("overloaded window runs");
    let c = &report.run.counters;
    // The overload actually bit — otherwise the ladder assertions below
    // would pass vacuously.
    assert!(
        c.delays.iter().sum::<usize>() > 0 && c.sheds.iter().sum::<usize>() > 0,
        "overload produced no backpressure events: {c:?}"
    );
    let lat = QosClass::Latency.index();
    let batch = QosClass::Batch.index();
    let be = QosClass::BestEffort.index();
    assert_eq!(c.sheds[lat], 0, "latency app shed");
    assert_eq!(c.delays[lat], 0, "latency app delayed");
    assert_eq!(c.sheds[batch], 0, "batch app shed");
    assert_eq!(c.delays[be], 0, "besteffort apps shed, never delayed");
    assert!(c.sheds[be] > 0, "pressure never reached besteffort sheds");
    assert!(c.admitted[lat] > 0, "no latency app admitted under pressure");
    // Shed apps are exactly the besteffort shed count, and none of them
    // has a metrics row.
    assert_eq!(report.run.shed_apps.len(), c.sheds.iter().sum::<usize>());
    let shed: HashSet<usize> = report.run.shed_apps.iter().copied().collect();
    assert!(report.apps.iter().all(|a| !shed.contains(&a.app_id)));
}

#[test]
fn sim_serving_series_is_deterministic() {
    // Same seed + same horizon ⇒ bit-identical everything: makespan,
    // admission counters, shed set and the fairness time series. This is
    // what makes the serving bench's ramp reproducible.
    let run = || {
        let stream = ServingStream::new(three_class_tenants(10, 42), 80.0, 42);
        run_serving_triple(
            "sim",
            "hom4",
            "ptt-serving",
            &stream,
            0.5,
            &RunOpts { trace: false, ..Default::default() },
            &ServingOpts { max_lane_depth: 4, ..Default::default() },
            false,
        )
        .expect("serving window runs")
    };
    let (a, b) = (run(), run());
    assert_eq!(a.run.result.makespan.to_bits(), b.run.result.makespan.to_bits());
    assert_eq!(a.run.counters, b.run.counters);
    assert_eq!(a.run.shed_apps, b.run.shed_apps);
    assert_eq!(a.run.fairness.len(), b.run.fairness.len());
    for (&(t1, j1), &(t2, j2)) in a.run.fairness.iter().zip(&b.run.fairness) {
        assert_eq!(t1.to_bits(), t2.to_bits());
        assert_eq!(j1.to_bits(), j2.to_bits());
    }
    // The fairness loop actually fired during the window (period 5ms over
    // a 500ms horizon with ≥ 2 live apps almost immediately).
    assert!(!a.run.fairness.is_empty(), "fairness feedback never sampled");
}

#[test]
fn serving_rejects_bad_inputs_with_errors_not_panics() {
    let stream = ServingStream::new(three_class_tenants(8, 1), 50.0, 1);
    let opts = RunOpts::default();
    let serving = ServingOpts::default();
    for (backend, scenario, policy, horizon) in [
        ("gpu", "hom4", "ptt-serving", 1.0),
        ("sim", "riscv", "ptt-serving", 1.0),
        ("sim", "hom4", "nope", 1.0),
        ("sim", "hom4", "ptt-serving", 0.0),
        ("sim", "hom4", "ptt-serving", f64::INFINITY),
    ] {
        let r = run_serving_triple(
            backend, scenario, policy, &stream, horizon, &opts, &serving, false,
        );
        assert!(r.is_err(), "{backend}/{scenario}/{policy}/{horizon} should be rejected");
    }
}
