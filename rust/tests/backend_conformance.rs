//! Cross-backend conformance: the same scheduling code must behave
//! identically — task accounting, placement validity, criticality — whether
//! it runs in virtual time (`sim`) or on real threads (`real`).
//!
//! This is the acceptance gate for the `ExecutionBackend` seam: for a fixed
//! seed and a deterministic DAG, every registered policy completes the same
//! DAG on both backends with identical task-execution counts and only valid
//! placements, across ≥ 3 registered platform scenarios.

use xitao::coordinator::scheduler::policy_by_name;
use xitao::dag_gen::{DagParams, generate};
use xitao::exec::{BACKEND_NAMES, ExecutionBackend, RunOpts, backend_by_name, run_triple};
use xitao::platform::scenarios;

const POLICIES: [&str; 5] = ["performance", "homogeneous", "cats", "dheft", "energy"];
const SCENARIOS: [&str; 4] = ["tx2", "haswell20", "biglittle44", "dvfs8"];

#[test]
fn every_policy_completes_the_same_dag_on_both_backends() {
    for scen in SCENARIOS {
        let plat = scenarios::by_name(scen).expect("registered scenario");
        let (dag, _) = generate(&DagParams::mix(60, 4.0, 0xC0FFEE));
        for pol in POLICIES {
            let mut per_backend = Vec::new();
            for be in BACKEND_NAMES {
                let backend = backend_by_name(be).expect("registered backend");
                let policy =
                    policy_by_name(pol, plat.topo.n_cores()).expect("registered policy");
                let run = backend.run(
                    &dag,
                    &plat,
                    policy.as_ref(),
                    None,
                    &RunOpts { seed: 7, ..Default::default() },
                )
                .unwrap();
                // Every task executed exactly once, every placement valid.
                let mut seen = vec![0u32; dag.len()];
                for r in &run.result.records {
                    seen[r.task] += 1;
                    assert!(
                        plat.topo.is_valid_partition(r.partition),
                        "{scen}/{pol}/{be}: invalid placement {:?}",
                        r.partition
                    );
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "{scen}/{pol}/{be}: execution counts {seen:?}"
                );
                assert!(run.result.makespan > 0.0, "{scen}/{pol}/{be}");
                per_backend.push(run.result.n_tasks());
            }
            assert_eq!(
                per_backend[0], per_backend[1],
                "{scen}/{pol}: task counts differ across backends"
            );
        }
    }
}

#[test]
fn criticality_tagging_is_backend_independent() {
    // Criticality is a DAG property resolved at wake-up time; the set of
    // critical task ids must not depend on the execution substrate.
    let plat = scenarios::by_name("tx2").unwrap();
    let (dag, _) = generate(&DagParams::mix(80, 2.0, 31));
    let crit_ids = |be: &str| -> std::collections::BTreeSet<usize> {
        let backend = backend_by_name(be).unwrap();
        let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
        backend
            .run(&dag, &plat, policy.as_ref(), None, &RunOpts::default())
            .unwrap()
            .result
            .records
            .iter()
            .filter(|r| r.critical)
            .map(|r| r.task)
            .collect()
    };
    assert_eq!(crit_ids("sim"), crit_ids("real"));
}

#[test]
fn run_triple_covers_the_full_registry_product() {
    // (backend × policy × scenario) as one call each; a coarse but complete
    // sweep that any future backend/scenario/policy must keep passing.
    let (dag, _) = generate(&DagParams::mix(24, 4.0, 5));
    for be in BACKEND_NAMES {
        for scen in SCENARIOS {
            for pol in POLICIES {
                let run = run_triple(be, scen, pol, &dag, &RunOpts::default())
                    .unwrap_or_else(|e| panic!("{be}/{scen}/{pol}: {e}"));
                assert_eq!(run.result.n_tasks(), 24, "{be}/{scen}/{pol}");
            }
        }
    }
}

#[test]
fn payload_execution_counts_match_across_backends() {
    // With real payloads attached, the real backend must still execute each
    // TAO exactly once (counted via rank-0 hits), matching the sim trace.
    use std::sync::atomic::Ordering;
    use xitao::dag_gen::fixtures::rank0_counting_chain;

    let plat = scenarios::by_name("biglittle44").unwrap();
    let (dag, hits) = rank0_counting_chain(30, false);

    let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
    let sim = backend_by_name("sim").unwrap();
    let sim_run = sim.run(&dag, &plat, policy.as_ref(), None, &RunOpts::default()).unwrap();
    let real = backend_by_name("real").unwrap();
    let real_run = real.run(&dag, &plat, policy.as_ref(), None, &RunOpts::default()).unwrap();

    assert_eq!(sim_run.result.n_tasks(), real_run.result.n_tasks());
    assert_eq!(hits.load(Ordering::SeqCst), 30, "each TAO ran exactly once for real");
}
