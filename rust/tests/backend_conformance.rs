//! Cross-backend conformance: the same scheduling code must behave
//! identically — task accounting, placement validity, criticality — whether
//! it runs in virtual time (`sim`) or on real threads (`real`).
//!
//! This is the acceptance gate for the `ExecutionBackend` seam: for a fixed
//! seed and a deterministic DAG, every registered policy completes the same
//! DAG on both backends with identical task-execution counts and only valid
//! placements, across ≥ 3 registered platform scenarios.

use xitao::coordinator::scheduler::policy_by_name;
use xitao::dag_gen::{DagParams, generate};
use xitao::exec::{BACKEND_NAMES, ExecutionBackend, RunOpts, backend_by_name, run_triple};
use xitao::platform::scenarios;

const POLICIES: [&str; 5] = ["performance", "homogeneous", "cats", "dheft", "energy"];
const SCENARIOS: [&str; 4] = ["tx2", "haswell20", "biglittle44", "dvfs8"];

#[test]
fn every_policy_completes_the_same_dag_on_both_backends() {
    for scen in SCENARIOS {
        let plat = scenarios::by_name(scen).expect("registered scenario");
        let (dag, _) = generate(&DagParams::mix(60, 4.0, 0xC0FFEE));
        for pol in POLICIES {
            let mut per_backend = Vec::new();
            for be in BACKEND_NAMES {
                let backend = backend_by_name(be).expect("registered backend");
                let policy =
                    policy_by_name(pol, plat.topo.n_cores()).expect("registered policy");
                let run = backend.run(
                    &dag,
                    &plat,
                    policy.as_ref(),
                    None,
                    &RunOpts { seed: 7, ..Default::default() },
                )
                .unwrap();
                // Every task executed exactly once, every placement valid.
                let mut seen = vec![0u32; dag.len()];
                for r in &run.result.records {
                    seen[r.task] += 1;
                    assert!(
                        plat.topo.is_valid_partition(r.partition),
                        "{scen}/{pol}/{be}: invalid placement {:?}",
                        r.partition
                    );
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "{scen}/{pol}/{be}: execution counts {seen:?}"
                );
                assert!(run.result.makespan > 0.0, "{scen}/{pol}/{be}");
                per_backend.push(run.result.n_tasks());
            }
            assert_eq!(
                per_backend[0], per_backend[1],
                "{scen}/{pol}: task counts differ across backends"
            );
        }
    }
}

/// `ptt-elastic` placement, frozen-table variant: delegates every
/// decision to the real policy object but reports `uses_ptt() == false`,
/// so neither engine writes observed times back into the table. With the
/// table frozen, a placement depends only on `(type_id, critical,
/// max_width)` and the pre-trained values — never on wall-clock timing —
/// which is what lets the test demand bit-identical `(leader, width)`
/// vectors from a virtual-time and a real-thread engine.
struct FrozenElastic(Box<dyn xitao::coordinator::Policy>);

impl xitao::coordinator::Policy for FrozenElastic {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn place(
        &self,
        ctx: &xitao::coordinator::PlaceCtx<'_>,
    ) -> xitao::platform::Partition {
        self.0.place(ctx)
    }
    fn uses_ptt(&self) -> bool {
        false
    }
}

#[test]
fn elastic_places_identically_on_both_backends_across_seeds() {
    // A serial chain on single-cluster hom4 with a pre-trained table
    // where (leader 0, width 4) dominates every metric: the root (placed
    // non-critical, local width search) picks it from any admitting core,
    // and every other chain task is critical (global search) and picks it
    // too — so sim and real must produce the *same* (leader, width) for
    // every task, for every seed, and that placement must be wide.
    use xitao::coordinator::dag::TaoDag;
    use xitao::coordinator::ptt::Ptt;
    use xitao::platform::KernelClass;

    let plat = scenarios::by_name("hom4").expect("dynamic hom<N> scenario");
    let mut dag = TaoDag::new();
    let mut prev: Option<usize> = None;
    for _ in 0..20 {
        let t = dag.add_task(KernelClass::MatMul, 0, 1.0);
        if let Some(p) = prev {
            dag.add_edge(p, t);
        }
        prev = Some(t);
    }
    dag.finalize().unwrap();

    let placements = |be: &str, seed: u64| -> Vec<(usize, usize)> {
        let ptt = Ptt::new(1, &plat.topo);
        for p in plat.topo.all_partitions() {
            // (0,4) wins on time AND time×width; everything else is far
            // behind, so no tie-break subtlety is load-bearing.
            let v = if p.leader == 0 && p.width == 4 { 0.5 } else { 10.0 };
            for _ in 0..8 {
                ptt.update(0, p.leader, p.width, v);
            }
        }
        let policy = FrozenElastic(
            policy_by_name("ptt-elastic", plat.topo.n_cores()).expect("registered policy"),
        );
        let backend = backend_by_name(be).expect("registered backend");
        let run = backend
            .run(&dag, &plat, &policy, Some(&ptt), &RunOpts { seed, ..Default::default() })
            .unwrap_or_else(|e| panic!("{be}/{seed}: {e}"));
        let mut v = vec![(usize::MAX, 0usize); dag.len()];
        for r in &run.result.records {
            v[r.task] = (r.partition.leader, r.partition.width);
        }
        v
    };
    for seed in [1u64, 2, 3] {
        let sim = placements("sim", seed);
        let real = placements("real", seed);
        assert_eq!(sim, real, "seed {seed}: (leader, width) vectors differ across backends");
        assert!(
            sim.iter().all(|&(l, w)| l == 0 && w == 4),
            "seed {seed}: trained wide winner not chosen: {sim:?}"
        );
    }
}

#[test]
fn elastic_honors_moldability_caps_on_both_backends() {
    // The same chain with every task forced inelastic must run width 1
    // everywhere on both engines — the cap travels through PlaceCtx, not
    // through any backend-specific channel.
    use xitao::coordinator::dag::TaoDag;
    use xitao::platform::KernelClass;

    let plat = scenarios::by_name("hom4").expect("dynamic hom<N> scenario");
    let mut dag = TaoDag::new();
    let mut prev: Option<usize> = None;
    for _ in 0..16 {
        let t = dag.add_task(KernelClass::MatMul, 0, 1.0);
        if let Some(p) = prev {
            dag.add_edge(p, t);
        }
        prev = Some(t);
    }
    dag.finalize().unwrap();
    let narrow = dag.with_max_width_cap(1);
    for be in BACKEND_NAMES {
        let policy = policy_by_name("ptt-elastic", plat.topo.n_cores()).unwrap();
        let backend = backend_by_name(be).unwrap();
        let run = backend
            .run(&narrow, &plat, policy.as_ref(), None, &RunOpts::default())
            .unwrap_or_else(|e| panic!("{be}: {e}"));
        assert_eq!(run.result.n_tasks(), narrow.len(), "{be}");
        for r in &run.result.records {
            assert_eq!(r.partition.width, 1, "{be}: capped task ran wide: {:?}", r.partition);
        }
    }
}

#[test]
fn criticality_tagging_is_backend_independent() {
    // Criticality is a DAG property resolved at wake-up time; the set of
    // critical task ids must not depend on the execution substrate.
    let plat = scenarios::by_name("tx2").unwrap();
    let (dag, _) = generate(&DagParams::mix(80, 2.0, 31));
    let crit_ids = |be: &str| -> std::collections::BTreeSet<usize> {
        let backend = backend_by_name(be).unwrap();
        let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
        backend
            .run(&dag, &plat, policy.as_ref(), None, &RunOpts::default())
            .unwrap()
            .result
            .records
            .iter()
            .filter(|r| r.critical)
            .map(|r| r.task)
            .collect()
    };
    assert_eq!(crit_ids("sim"), crit_ids("real"));
}

#[test]
fn run_triple_covers_the_full_registry_product() {
    // (backend × policy × scenario) as one call each; a coarse but complete
    // sweep that any future backend/scenario/policy must keep passing.
    let (dag, _) = generate(&DagParams::mix(24, 4.0, 5));
    for be in BACKEND_NAMES {
        for scen in SCENARIOS {
            for pol in POLICIES {
                let run = run_triple(be, scen, pol, &dag, &RunOpts::default())
                    .unwrap_or_else(|e| panic!("{be}/{scen}/{pol}: {e}"));
                assert_eq!(run.result.n_tasks(), 24, "{be}/{scen}/{pol}");
            }
        }
    }
}

#[test]
fn payload_execution_counts_match_across_backends() {
    // With real payloads attached, the real backend must still execute each
    // TAO exactly once (counted via rank-0 hits), matching the sim trace.
    use std::sync::atomic::Ordering;
    use xitao::dag_gen::fixtures::rank0_counting_chain;

    let plat = scenarios::by_name("biglittle44").unwrap();
    let (dag, hits) = rank0_counting_chain(30, false);

    let policy = policy_by_name("performance", plat.topo.n_cores()).unwrap();
    let sim = backend_by_name("sim").unwrap();
    let sim_run = sim.run(&dag, &plat, policy.as_ref(), None, &RunOpts::default()).unwrap();
    let real = backend_by_name("real").unwrap();
    let real_run = real.run(&dag, &plat, policy.as_ref(), None, &RunOpts::default()).unwrap();

    assert_eq!(sim_run.result.n_tasks(), real_run.result.n_tasks());
    assert_eq!(hits.load(Ordering::SeqCst), 30, "each TAO ran exactly once for real");
}
