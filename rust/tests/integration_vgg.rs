//! GEMM-service integration: the AOT artifacts under the Rust runtime.
//!
//! With the `pjrt` feature these exercise the real XLA executables and
//! require `make artifacts` (tests no-op with a notice when the artifact
//! directory is absent, so `cargo test` stays green on a fresh checkout).
//! Without it, the native-fallback service runs the GEMM and TAO-DAG paths
//! end to end; whole-model inference (XLA-only) is skipped.

use std::path::Path;
use std::sync::Arc;
use xitao::coordinator::PerformanceBased;
use xitao::exec::{ExecutionBackend, RunOpts, backend_by_name};
use xitao::platform::Platform;
use xitao::runtime::{PjrtService, VggWeights, build_real_dag, pipeline_infer, synthetic_image};

fn service() -> Option<PjrtService> {
    if cfg!(feature = "pjrt") && !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping PJRT test: run `make artifacts`");
        return None;
    }
    Some(PjrtService::start(Path::new("artifacts")).expect("service start"))
}

/// Whole-model inference exists only as an XLA executable.
fn whole_model_available(svc: &PjrtService) -> bool {
    cfg!(feature = "pjrt") && svc.manifest().vgg.is_some()
}

#[test]
fn gemm_matches_cpu_reference_across_shapes() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let mut rng = xitao::util::Pcg32::seeded(5);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (13, 77, 5), (128, 128, 128), (200, 64, 33)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.gen_f64() as f32 - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gen_f64() as f32 - 0.5).collect();
        let got = h.gemm(&a, &b, m, k, n).unwrap();
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    want[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2, "({m},{k},{n}): {g} vs {w}");
        }
    }
}

#[test]
fn whole_model_and_pipeline_agree() {
    let Some(svc) = service() else { return };
    if !whole_model_available(&svc) {
        eprintln!("skipping: whole-model VGG needs the `pjrt` feature and artifacts");
        return;
    }
    let spec = svc.manifest().vgg.clone().expect("vgg artifact");
    let hw = spec.input_hw;
    let weights = Arc::new(VggWeights::synthetic(hw, 3));
    let image = synthetic_image(hw, 4);
    let h = svc.handle();
    h.vgg_load(weights.flat()).unwrap();
    let whole = h.vgg_infer(&image).unwrap();
    let pipe = pipeline_infer(&weights, &image, &h).unwrap();
    assert_eq!(whole.len(), 1000);
    let scale = whole.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
    for (i, (a, b)) in whole.iter().zip(&pipe).enumerate() {
        assert!(
            (a - b).abs() / scale < 1e-3,
            "logit {i}: whole {a} vs pipeline {b}"
        );
    }
}

#[test]
fn tao_dag_inference_matches_pipeline() {
    let Some(svc) = service() else { return };
    // Input size: from the VGG artifact when present, else the smallest
    // legal input (32) so the native reference GEMM stays fast in debug.
    let hw = svc.manifest().vgg.as_ref().map_or(32, |v| v.input_hw);
    let weights = Arc::new(VggWeights::synthetic(hw, 7));
    let image = synthetic_image(hw, 8);
    let h = svc.handle();
    let pipe = pipeline_infer(&weights, &image, &h).unwrap();
    let (dag, out) = build_real_dag(weights.clone(), image, h, 128);
    let plat = Platform::homogeneous(2);
    let backend = backend_by_name("real").unwrap();
    let res = backend.run(&dag, &plat, &PerformanceBased, None, &RunOpts::default()).unwrap().result;
    assert_eq!(res.n_tasks(), dag.len());
    let logits = out.snapshot();
    let scale = pipe.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
    for (i, (a, b)) in pipe.iter().zip(&logits).enumerate() {
        assert!((a - b).abs() / scale < 1e-3, "logit {i}: {a} vs {b}");
    }
}

#[test]
fn vgg_infer_rejects_bad_inputs() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    // Infer before load (native fallback rejects whole-model outright).
    assert!(h.vgg_infer(&[0.0; 3]).is_err());
    // Wrong parameter count.
    assert!(h.vgg_load(vec![vec![0.0; 4]]).is_err());
}
